"""Fault injection + graceful degradation: plan format stability, the
injector contract, the tier's guarded copy paths, and the scheduler's
recovery semantics end to end.

The robustness contract mirrors the serving stack's identity
discipline: a fault either (a) is absorbed (retries, restore-gate
degradation, quarantine-requeue) leaving every stream greedy
token-identical to the fault-free baseline, or (b) terminates its
session explicitly (aborted / failed / expired status + a terminal
event) with the committed tokens a prefix of the baseline stream —
never a silently wrong token, never a leaked page in either pool.
Unaffected sessions must be byte-identical in all cases.
"""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model
from repro.serving import (FaultInjector, FaultPlan, FaultPlanConfig,
                           FaultSpec, InjectedFault, SessionRequest,
                           SlotScheduler, generate_fault_plan,
                           plan_from_text, plan_to_text, slo_report,
                           validate_plan)
from repro.serving.faults import KINDS
from repro.serving.memory import TieredPageStore, get_policy
from repro.serving.memory.allocator import BlockAllocator
from repro.serving.memory.tiers import TierCopyError
from repro.serving.session import ContinuousResult, SessionResult
from repro.serving.trace import SessionClass

KEY = jax.random.PRNGKey(11)
CFG = get_config("qwen2.5-3b").reduced().replace(
    vocab_size=64, d_model=64, d_ff=128, n_layers=2,
    n_heads=4, n_kv_heads=2, head_dim=16, dtype="float32")

_STATE: dict = {}


# ---------------------------------------------------------- plan format
class TestPlanFormat:
    def test_text_roundtrip_is_byte_stable(self):
        for seed in (0, 7, 123):
            plan = generate_fault_plan(
                FaultPlanConfig(seed=seed, n_faults=10, horizon_s=0.5),
                session_ids=("a", "b", "c"))
            txt = plan_to_text(plan)
            assert plan_from_text(txt) == plan
            assert plan_to_text(plan_from_text(txt)) == txt

    def test_same_seed_same_plan(self):
        cfg = FaultPlanConfig(seed=5, n_faults=12, horizon_s=1.0)
        a = generate_fault_plan(cfg, session_ids=("x", "y"))
        b = generate_fault_plan(cfg, session_ids=("x", "y"))
        assert a == b
        c = generate_fault_plan(
            FaultPlanConfig(seed=6, n_faults=12, horizon_s=1.0),
            session_ids=("x", "y"))
        assert a != c

    def test_specs_are_time_sorted_and_valid(self):
        plan = generate_fault_plan(
            FaultPlanConfig(seed=1, n_faults=20, horizon_s=0.3))
        times = [s.at_s for s in plan.specs]
        assert times == sorted(times)
        validate_plan(plan)              # must not raise

    @pytest.mark.parametrize("spec,msg", [
        (FaultSpec("meteor", 0.1), "unknown kind"),
        (FaultSpec("abort", -0.1), "negative due time"),
        (FaultSpec("abort", 0.1, count=0), "must be >= 1"),
        (FaultSpec("pool_pressure", 0.1), "positive hold duration"),
        (FaultSpec("abort", 0.1, target="a b"), "must be a token"),
    ])
    def test_validate_rejects_bad_specs(self, spec, msg):
        plan = FaultPlan(FaultPlanConfig(), (spec,))
        with pytest.raises(ValueError, match=msg):
            validate_plan(plan)

    def test_validate_rejects_unsorted(self):
        plan = FaultPlan(FaultPlanConfig(), (
            FaultSpec("abort", 0.2), FaultSpec("abort", 0.1)))
        with pytest.raises(ValueError, match="time-sorted"):
            validate_plan(plan)

    def test_parse_requires_header(self):
        with pytest.raises(AssertionError, match="header"):
            plan_from_text("abort t=0.100000 target=- count=1 "
                           "dur=0.000000\n")


# ------------------------------------------------------------- injector
class TestInjector:
    def _plan(self):
        return FaultPlan(FaultPlanConfig(), (
            FaultSpec("save_fail", 0.1, count=2),
            FaultSpec("nan_logits", 0.2, target="s0"),
            FaultSpec("pool_pressure", 0.3, count=2, duration_s=0.01)))

    def test_poll_activates_in_time_order(self):
        inj = FaultInjector(self._plan())
        assert inj.scheduled == 3
        assert inj.poll(0.05) == []
        assert inj.poll(0.1) == [] and inj.save_fails == 2
        due = inj.poll(0.25)
        assert [s.kind for s in due] == ["nan_logits"]
        assert [s.kind for s in inj.poll(10.0)] == ["pool_pressure"]
        assert inj.poll(20.0) == []      # plan exhausted

    def test_copy_fail_budget_is_consumable(self):
        inj = FaultInjector(self._plan())
        inj.poll(0.15)
        assert inj.take_copy_fail("save")
        assert inj.take_copy_fail("save")
        assert not inj.take_copy_fail("save"), "budget of 2 exhausted"
        assert not inj.take_copy_fail("restore"), "never armed"
        assert inj.fired["save_fail"] == 2

    def test_counters_are_stable_keyed(self):
        inj = FaultInjector(self._plan())
        inj.mark("abort")
        inj.mark("abort")
        inj.mark("nan_logits")
        assert inj.counters() == {"nan_logits": 1, "abort": 2}
        assert all(k in KINDS for k in inj.counters())


# ------------------------------------------- tier copy guards (unit)
def _flaky_store(fail_saves=0, fail_restores=0, **kw):
    """TieredPageStore over fake movers that fail the first N calls —
    blobs are (page_id,) sentinels, so restores are checkable without a
    device and injected faults are indistinguishable from transport
    errors (the production arrangement)."""
    state = {"fs": fail_saves, "fr": fail_restores, "restored": []}

    def save_fn(cache, pages):
        if state["fs"] > 0:
            state["fs"] -= 1
            raise InjectedFault("save transport fault")
        return [(np.full((1,), p, np.float32), np.zeros((1,), np.float32))
                for p in pages]

    def restore_fn(cache, pages, blobs):
        if state["fr"] > 0:
            state["fr"] -= 1
            raise InjectedFault("restore transport fault")
        state["restored"].extend(
            (int(b[0][0]), p) for p, b in zip(pages, blobs))
        return cache

    store = TieredPageStore(
        n_slots=2, max_blocks=6, page_size=4, n_pages=10,
        prefix_cache=True, host_pages=kw.pop("host_pages", 8),
        policy=get_policy(kw.pop("policy", "spill")),
        retry_budget=kw.pop("retry_budget", 2),
        save_fn=save_fn, restore_fn=restore_fn, get_cache=lambda: {},
        **kw)
    return store, state


class TestTierGuards:
    def test_save_retry_within_budget(self):
        store, _ = _flaky_store(fail_saves=1)
        pages = store.alloc(2)
        assert store.park("sid", 2, pages, {}) == 2
        assert store.save_retries == 1
        assert store.parked_blocks("sid") == 2 and store.host_used == 2
        store.release(pages)
        fresh = store.alloc(2)
        store.take_parked("sid", 0, fresh, {})
        store.release(fresh)
        assert store.host_used == 0
        assert store.allocator.n_free == store.n_pages - 1

    def test_save_past_budget_degrades_clean(self):
        store, _ = _flaky_store(fail_saves=10, retry_budget=1)
        pages = store.alloc(2)
        assert store.park("sid", 2, pages, {}) is None
        assert store.park_fails == 1 and store.save_retries == 1
        assert store.parked_blocks("sid") == 0
        assert store.host_used == 0, "failed park must not pin blobs"
        store.release(pages)
        assert store.allocator.n_free == store.n_pages - 1

    def test_restore_fail_keeps_entry_and_unwind_balances(self):
        """The satellite regression: a restore past the retry budget
        must leave the parked entry AND the host accounting intact, so
        the caller's unwind (release device pages, drop the parked
        copy) closes both pools — no leaked refcounts, no orphaned host
        blobs."""
        store, _ = _flaky_store(fail_restores=10, retry_budget=1)
        pages = store.alloc(2)
        store.park("sid", 2, pages, {})
        store.release(pages)
        fresh = store.alloc(2)
        with pytest.raises(TierCopyError, match="failed after"):
            store.take_parked("sid", 0, fresh, {})
        assert store.restore_retries == 1
        assert store.parked_blocks("sid") == 2, \
            "bytes are fine — the entry must survive the failed copy"
        assert store.host_used == 2
        store.release(fresh)             # the scheduler's unwind path
        store.drop_parked("sid")
        assert store.host_used == 0
        assert store.allocator.n_free == store.n_pages - 1

    def test_restore_retry_within_budget(self):
        store, state = _flaky_store(fail_restores=1)
        pages = store.alloc(2)
        store.park("sid", 2, pages, {})
        store.release(pages)
        fresh = store.alloc(2)
        store.take_parked("sid", 0, fresh, {})
        assert store.restore_retries == 1 and store.tier_restores == 1
        assert [m[0] for m in state["restored"]] == pages, \
            "restored blobs must be the very pages that were parked"
        store.release(fresh)
        assert store.host_used == 0

    def test_corrupt_parked_blob_caught_by_checksum(self):
        store, _ = _flaky_store()
        pages = store.alloc(2)
        store.park("sid", 2, pages, {})
        store.release(pages)
        assert store.corrupt_parked_blob() == "sid"
        fresh = store.alloc(2)
        with pytest.raises(TierCopyError, match="verify-on-restore"):
            store.take_parked("sid", 0, fresh, {})
        assert store.corrupt_blobs == 1
        store.release(fresh)
        store.drop_parked("sid")
        assert store.host_used == 0
        assert store.allocator.n_free == store.n_pages - 1

    def test_corrupt_host_prefix_blob_is_purged(self):
        store, _ = _flaky_store()
        seq = np.asarray([5] * 8, np.int32)
        pages = store.alloc(2)
        store.register(seq, pages, 2)
        store.release(pages)
        store.prefix.reclaim(99)         # evict both -> host index
        paths = store.host_match(seq, 0, 2)
        assert len(paths) == 2
        h = store._hpath[paths[0]]
        blob = store.host.get(h)
        bad = np.array(blob[0], copy=True)
        bad.view(np.uint8).reshape(-1)[0] ^= 0xFF
        store.host.replace(h, (bad,) + tuple(blob[1:]))
        fresh = store.alloc(2)
        with pytest.raises(TierCopyError, match="checksum"):
            store.restore_host_prefix(paths, fresh, {})
        assert store.corrupt_blobs >= 1
        assert store.host_match(seq, 0, 2) == [], \
            "damaged entries must be purged, not retried forever"
        store.release(fresh)
        store.flush_host()
        assert store.host_used == 0

    def test_verify_off_skips_the_checksum_screen(self):
        store, _ = _flaky_store(verify_checksums=False)
        pages = store.alloc(2)
        store.park("sid", 2, pages, {})
        store.release(pages)
        store.corrupt_parked_blob()
        fresh = store.alloc(2)
        store.take_parked("sid", 0, fresh, {})   # no raise: screen off
        assert store.corrupt_blobs == 0 and store.tier_restores == 1
        store.release(fresh)


# ----------------------------------------------- scheduler integration
def _model():
    if "model" not in _STATE:
        m = Model(CFG)
        _STATE["model"] = (m, m.init(KEY))
    return _STATE["model"]


def _reqs(n=5):
    """Deterministic churn wave: multi-page prompts and budgets that
    keep two residents preempting each other in a small pool."""
    rng = np.random.RandomState(3)
    return [SessionRequest(
        f"s{i}",
        rng.randint(0, CFG.vocab_size, size=8 + 3 * (i % 3)).astype(
            np.int32),
        6 + 2 * (i % 2)) for i in range(n)]


def _serve(reqs, *, plan=None, k=1, **kw):
    model, params = _model()
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 24)
    kw.setdefault("paged", True)
    kw.setdefault("page_size", 4)
    kw.setdefault("n_pages", 8)
    kw.setdefault("prefill_chunk", 4)
    kw.setdefault("prefix_cache", True)
    kw.setdefault("kv_tier", "host")
    kw.setdefault("tier_policy", "spill")
    kw.setdefault("host_pages", 16)
    kw.setdefault("steps_per_tick", k)
    kw.setdefault("timed", False)
    kw.setdefault("shared_programs", True)
    if plan is not None:
        kw.setdefault("fault_injector", FaultInjector(plan))
        kw.setdefault("self_audit", True)
    sched = SlotScheduler(model, params, **kw)
    for r in reqs:
        sched.submit(r)
    return sched, sched.run()


def _baseline(k=1):
    key = ("base", k)
    if key not in _STATE:
        reqs = _reqs()
        sched, res = _serve(reqs, k=k)
        assert res.preemptions > 0, "pool never thrashed: tests inert"
        _STATE[key] = {r.session_id: np.asarray(
            res.tokens_for(r.session_id)) for r in reqs}
    return _reqs(), _STATE[key]


def _plan_of(*specs):
    plan = FaultPlan(FaultPlanConfig(), tuple(specs))
    validate_plan(plan)
    return plan


def _assert_balanced(sched):
    n_pages = sched.store.n_pages
    sched.flush_prefix_cache()
    sched.store.flush_host()
    assert sched.store.allocator.n_free == n_pages - 1, "device page leak"
    assert sched.store.host_used == 0, "host blob leak"
    assert not sched._pressure_holds, "pressure hold survived the run"


class TestSchedulerRecovery:
    def test_no_injector_means_no_fault_machinery(self):
        reqs, _ = _baseline()
        _, res = _serve(reqs)
        assert not res.fault_counts and res.faults_injected == 0
        assert res.quarantines == 0 and res.degraded_restores == 0
        assert res.retry_backoff_s == 0.0

    def test_logit_screen_on_clean_stream_changes_nothing(self):
        reqs, base = _baseline()
        _, res = _serve(reqs, logit_screen=True)
        for sid, toks in base.items():
            np.testing.assert_array_equal(toks, res.tokens_for(sid))
        assert res.quarantines == 0

    def test_restore_fail_storm_degrades_token_identically(self):
        reqs, base = _baseline()
        plan = _plan_of(FaultSpec("restore_fail", 0.0, count=500))
        sched, res = _serve(reqs, plan=plan, retry_budget=1)
        assert res.degraded_restores > 0, \
            "storm never hit a restore — nothing was tested"
        assert res.restore_retries > 0 and res.retry_backoff_s > 0
        assert "restore_fail" in res.fault_counts
        for sid, toks in base.items():
            np.testing.assert_array_equal(
                toks, res.tokens_for(sid),
                err_msg=f"{sid} diverged under restore degradation")
        assert any(e[0] == "degraded" for e in res.events)
        _assert_balanced(sched)

    def test_save_fail_is_absorbed_by_retry(self):
        reqs, base = _baseline()
        plan = _plan_of(FaultSpec("save_fail", 0.0, count=1))
        sched, res = _serve(reqs, plan=plan)
        assert res.save_retries >= 1
        assert res.retry_backoff_s > 0, "retries must charge the clock"
        assert res.fault_counts.get("save_fail") == 1
        for sid, toks in base.items():
            np.testing.assert_array_equal(toks, res.tokens_for(sid))
        _assert_balanced(sched)

    @pytest.mark.parametrize("k", [1, 4])
    def test_nan_quarantine_recovers_identically(self, k):
        reqs, base = _baseline(k)
        plan = _plan_of(FaultSpec("nan_logits", 0.0, target="s0"))
        sched, res = _serve(reqs, plan=plan, k=k)
        assert res.quarantines >= 1
        assert res.fault_counts.get("nan_logits") == 1
        assert res.failed_sessions == 0, "requeue must recover, not drop"
        for sid, toks in base.items():
            np.testing.assert_array_equal(
                toks, res.tokens_for(sid),
                err_msg=f"{sid} diverged after quarantine (K={k})")
        _assert_balanced(sched)

    def test_quarantine_budget_zero_fails_closed(self):
        reqs, base = _baseline()
        plan = _plan_of(FaultSpec("nan_logits", 0.0, target="s1"))
        sched, res = _serve(reqs, plan=plan, quarantine_budget=0)
        assert res.failed_sessions == 1
        sess = res.sessions["s1"]
        assert sess.status == "failed"
        assert any(e[0] == "failed" and e[1] == "s1" for e in res.events)
        got = np.asarray(res.tokens_for("s1"))
        np.testing.assert_array_equal(
            got, base["s1"][:len(got)],
            err_msg="committed prefix of a failed session must match")
        for sid, toks in base.items():
            if sid != "s1":
                np.testing.assert_array_equal(toks, res.tokens_for(sid))
        _assert_balanced(sched)

    def test_targeted_abort_spares_everyone_else(self):
        reqs, base = _baseline()
        plan = _plan_of(FaultSpec("abort", 0.0, target="s2"))
        sched, res = _serve(reqs, plan=plan)
        assert res.aborted_sessions == 1
        assert res.sessions["s2"].status == "aborted"
        assert any(e[0] == "aborted" and e[1] == "s2"
                   for e in res.events)
        got = np.asarray(res.tokens_for("s2"))
        np.testing.assert_array_equal(got, base["s2"][:len(got)])
        for sid, toks in base.items():
            if sid != "s2":
                np.testing.assert_array_equal(
                    toks, res.tokens_for(sid),
                    err_msg=f"{sid} perturbed by s2's disconnect")
        _assert_balanced(sched)

    def test_session_ttl_expires_with_prefix_streams(self):
        reqs, base = _baseline()
        sched, res = _serve(reqs, session_ttl_s=0.01)
        assert res.expired_sessions > 0
        for r in reqs:
            got = np.asarray(res.tokens_for(r.session_id))
            np.testing.assert_array_equal(
                got, base[r.session_id][:len(got)],
                err_msg=f"{r.session_id} emitted wrong tokens pre-TTL")
        _assert_balanced(sched)

    def test_pool_pressure_expires_and_balances(self):
        reqs, base = _baseline()
        plan = _plan_of(
            FaultSpec("pool_pressure", 0.0, count=3, duration_s=0.02))
        sched, res = _serve(reqs, plan=plan)
        assert any(e[0] == "pressure" for e in res.events)
        for sid, toks in base.items():
            np.testing.assert_array_equal(toks, res.tokens_for(sid))
        _assert_balanced(sched)

    def test_mixed_plan_replay_is_deterministic(self):
        reqs = _reqs(4)
        plan = generate_fault_plan(
            FaultPlanConfig(seed=3, n_faults=6, horizon_s=0.3),
            session_ids=[r.session_id for r in reqs])
        runs = []
        for _ in range(2):
            sched, res = _serve(reqs, plan=plan)
            runs.append((res.fault_counts, res.now_s,
                         {r.session_id: list(res.tokens_for(r.session_id))
                          for r in reqs},
                         {r.session_id: res.sessions[r.session_id].status
                          for r in reqs}))
            _assert_balanced(sched)
        assert runs[0] == runs[1], "same plan, same seed: byte-identical"


# ------------------------------------------------------------ self-audit
class TestSelfAudit:
    def test_allocator_check_detects_refcount_damage(self):
        alloc = BlockAllocator(6)
        assert alloc.check() == []
        pages = alloc.alloc(2)
        alloc._refs[pages[0]] = 0        # held page with no holder
        try:
            assert any("refcount 0 but not free" in i
                       for i in alloc.check())
        finally:
            alloc._refs[pages[0]] = 1
        alloc.release(pages)
        assert alloc.check() == []

    def test_store_check_flags_unreferenced_cached_page(self):
        store, _ = _flaky_store()
        seq = np.asarray([9] * 8, np.int32)
        pages = store.alloc(2)
        store.register(seq, pages, 2)
        store.release(pages)
        assert store.check() == []
        store.allocator._refs[pages[0]] = 0
        try:
            assert store.check() != []
        finally:
            store.allocator._refs[pages[0]] = 1

    def test_scheduler_audit_warns_then_fails_closed(self):
        sched, _ = _serve(_reqs(2))
        sched.flush_prefix_cache()
        sched.store.allocator._refs[1] += 1      # damage: free page held
        sched._run_audit()
        assert sched.audit_failures == 1
        assert any(e[0] == "audit" for e in sched.events)
        with pytest.raises(RuntimeError, match="audit failed twice"):
            sched._run_audit()


# ---------------------------------------------- slo_report accounting
def _sess(sid, n, *, klass="chat", status="ok", arrival=0.0, gap=0.01):
    times = arrival + gap * np.arange(1, n + 1)
    return SessionResult(
        session_id=sid, tokens=np.arange(n, dtype=np.int32), slot=0,
        admitted_tick=0, finished_tick=1, step_times_s=[], klass=klass,
        status=status, arrival_s=arrival, token_times_s=times,
        ttft_s=float(times[0] - arrival) if n else None)


def _result(sessions):
    return ContinuousResult(
        sessions={s.session_id: s for s in sessions}, ticks=1,
        decode_steps=1, wall_s=0.1, tokens_per_s=1.0,
        step_cache_size=0, launches_per_step=1.0, events=[])


_CLASSES = {"chat": SessionClass("chat", 1.0,
                                 slo_ttft_s=0.5, slo_tpot_s=0.05)}


class TestSloReportFailedSessions:
    def test_failed_excluded_from_latency_counted_against_slo(self):
        # the aborted session's wild inter-token gaps (9 s) would wreck
        # the TPOT tail if its truncated stream entered the percentiles
        rep = slo_report(_result([
            _sess("a", 8), _sess("b", 8),
            _sess("x", 3, status="aborted", gap=9.0)]), _CLASSES)
        assert rep["sessions"] == 3
        assert rep["failed_sessions"] == 1
        assert rep["statuses"] == {"aborted": 1}
        assert rep["tpot"]["p95"] < 1.0, "aborted stream leaked in"
        assert rep["slo_frac"] == pytest.approx(2 / 3)
        assert rep["slo_sessions"] == 2
        # a dropped session's tokens are not goodput
        assert rep["goodput_tok_s"] == pytest.approx(
            16 / rep["makespan_s"])
        cls = rep["classes"]["chat"]
        assert cls["sessions"] == 3 and cls["failed_sessions"] == 1
        assert cls["slo_frac"] == pytest.approx(2 / 3)
        json.dumps(rep, allow_nan=False)

    def test_all_failed_reports_zero_slo(self):
        rep = slo_report(_result([
            _sess("x", 2, status="expired"),
            _sess("y", 0, status="failed")]), _CLASSES)
        assert rep["sessions"] == 2 and rep["failed_sessions"] == 2
        assert rep["statuses"] == {"expired": 1, "failed": 1}
        assert rep["slo_frac"] == 0.0
        assert rep["ttft"] is None and rep["goodput_tok_s"] == 0.0
        json.dumps(rep, allow_nan=False)

    def test_no_failures_keeps_legacy_shape(self):
        rep = slo_report(_result([_sess("a", 8), _sess("b", 8)]),
                         _CLASSES)
        assert rep["sessions"] == 2
        assert rep["failed_sessions"] == 0 and rep["statuses"] == {}
        assert rep["slo_frac"] == 1.0
        json.dumps(rep, allow_nan=False)
