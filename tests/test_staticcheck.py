"""staticcheck: per-rule fire/quiet fixtures, suppression + baseline
machinery, CLI exit codes, and the seeded PR-9 leak regression."""
import json
import os
import textwrap

import pytest

from repro.analysis.staticcheck.cli import main as cli_main
from repro.analysis.staticcheck.core import (RULES, UNUSED_SUPPRESSION,
                                             check_source, load_baseline,
                                             write_baseline)

FIXTURES = os.path.join(os.path.dirname(__file__), "fixtures",
                        "staticcheck")


def run(snippet, select=None):
    return check_source(textwrap.dedent(snippet), "snippet.py", select)


def rules_of(findings):
    return [f.rule for f in findings]


# --------------------------------------------------------------- registry
def test_registry_has_the_five_rules():
    assert {"hot-sync", "recompile-hazard", "donation-safety",
            "prng-discipline", "refcount-pairing"} <= set(RULES)
    for rule in RULES.values():
        assert rule.invariant


# --------------------------------------------------------------- hot-sync
BAD_HOT_SYNC = """
    import numpy as np
    import jax.numpy as jnp

    def tick(self):  # staticcheck: hotpath
        logits = jnp.ones((4, 8))
        toks = np.asarray(logits)
        return toks
"""


def test_hot_sync_fires_on_asarray():
    findings = run(BAD_HOT_SYNC, ["hot-sync"])
    assert rules_of(findings) == ["hot-sync"]
    assert "np.asarray" in findings[0].message
    assert findings[0].context == "tick"


def test_hot_sync_quiet_without_marker():
    assert run(BAD_HOT_SYNC.replace("# staticcheck: hotpath", ""),
               ["hot-sync"]) == []


def test_hot_sync_quiet_on_host_values():
    assert run("""
        import numpy as np

        def tick(self):  # staticcheck: hotpath
            toks = np.zeros((4, 1), np.int32)
            n = int(toks[0, 0])
            return n
    """, ["hot-sync"]) == []


def test_hot_sync_scalar_and_item_and_timed_gate():
    findings = run("""
        import jax.numpy as jnp

        def tick(self, timed):  # staticcheck: hotpath
            x = jnp.ones(())
            if timed:
                y = float(x)        # allowed: timed instrumentation
            n = int(x)              # flagged
            m = x.item()            # flagged
            return n, m
    """, ["hot-sync"])
    assert len(findings) == 2
    assert {f.line for f in findings} == {8, 9}


def test_hot_sync_conversion_clears_device_tag():
    # after np.asarray rebinds the name, int() on it is host-side
    assert run("""
        import numpy as np
        import jax.numpy as jnp

        def tick(self):  # staticcheck: hotpath
            # staticcheck: disable=hot-sync -- the one sync
            nxt = np.asarray(jnp.ones((4,)))
            return int(nxt[0])
    """, ["hot-sync"]) == []


# ------------------------------------------------------- recompile-hazard
def test_recompile_fires_in_loop_and_comprehension():
    findings = run("""
        import jax

        def build(fns):
            out = []
            for f in fns:
                out.append(jax.jit(f))
            listed = [jax.jit(f) for f in fns]
            return out, listed
    """, ["recompile-hazard"])
    assert len(findings) == 2


def test_recompile_fires_on_immediate_invocation():
    findings = run("""
        import jax

        def call(f, x):
            return jax.jit(f)(x)
    """, ["recompile-hazard"])
    assert len(findings) == 1
    assert "immediately invoked" in findings[0].message


def test_recompile_fires_on_undeclared_scalar_literal():
    findings = run("""
        import jax

        step = jax.jit(lambda x, n: x * n)

        def drive(x):
            return step(x, 3)
    """, ["recompile-hazard"])
    assert len(findings) == 1
    assert "position 1" in findings[0].message


def test_recompile_quiet_when_declared_static():
    assert run("""
        import jax

        step = jax.jit(lambda x, n: x * n, static_argnums=(1,))

        def drive(x):
            return step(x, 3)
    """, ["recompile-hazard"]) == []


def test_recompile_flags_keyword_not_in_static_argnames():
    findings = run("""
        import jax

        step = jax.jit(lambda x, *, k, n: x, static_argnames=("k",))

        def drive(x):
            return step(x, k=2, n=3)
    """, ["recompile-hazard"])
    assert len(findings) == 1
    assert "`n`" in findings[0].message


# -------------------------------------------------------- donation-safety
def test_donation_fires_on_read_after_donating_call():
    findings = run("""
        import jax

        step = jax.jit(lambda p, c, t: (t, c), donate_argnums=(1,))

        def drive(p, cache, tok):
            logits, new_cache = step(p, cache, tok)
            return logits, cache.shape
    """, ["donation-safety"])
    assert len(findings) == 1
    assert "`cache`" in findings[0].message


def test_donation_quiet_when_rebound():
    assert run("""
        import jax

        step = jax.jit(lambda p, c, t: (t, c), donate_argnums=(1,))

        def drive(p, cache, tok):
            logits, cache = step(p, cache, tok)
            return logits, cache.shape
    """, ["donation-safety"]) == []


def test_donation_loop_rebinding_is_safe_but_reuse_is_not():
    good = """
        import jax

        step = jax.jit(lambda p, c, t: (t, c), donate_argnums=(1,))

        def drive(p, cache, toks):
            for t in toks:
                out, cache = step(p, cache, t)
            return cache
    """
    bad = """
        import jax

        step = jax.jit(lambda p, c, t: (t, c), donate_argnums=(1,))

        def drive(p, cache, toks):
            outs = []
            for t in toks:
                outs.append(step(p, cache, t))
            return outs
    """
    assert run(good, ["donation-safety"]) == []
    findings = run(bad, ["donation-safety"])
    assert len(findings) == 1
    assert "loop" in findings[0].message


def test_donation_known_registry_callee():
    findings = run("""
        def tick(self, toks):
            logits, cache = self._progs.step(self.params, self.cache, toks)
            return logits, self.cache["pos"]
    """, ["donation-safety"])
    assert len(findings) == 1
    assert "`self.cache`" in findings[0].message


# -------------------------------------------------------- prng-discipline
def test_prng_fires_on_double_consumption():
    findings = run("""
        import jax

        def gen(seed):
            key = jax.random.PRNGKey(seed)
            a = jax.random.normal(key, (4,))
            b = jax.random.normal(key, (4,))
            return a, b
    """, ["prng-discipline"])
    assert len(findings) == 1
    assert "`key`" in findings[0].message


def test_prng_quiet_with_fold_in_between():
    assert run("""
        import jax

        def gen(seed):
            key = jax.random.PRNGKey(seed)
            a = jax.random.normal(key, (4,))
            key = jax.random.fold_in(key, 1)
            b = jax.random.normal(key, (4,))
            return a, b
    """, ["prng-discipline"]) == []


def test_prng_split_elements_are_independent():
    assert run("""
        import jax

        def init(key):
            ks = jax.random.split(key, 3)
            a = jax.random.normal(ks[0], (4,))
            b = jax.random.normal(ks[1], (4,))
            c = jax.random.normal(ks[2], (4,))
            return a, b, c
    """, ["prng-discipline"]) == []


def test_prng_same_split_element_twice_fires():
    findings = run("""
        import jax

        def init(key):
            ks = jax.random.split(key, 2)
            a = jax.random.normal(ks[0], (4,))
            b = jax.random.normal(ks[0], (4,))
            return a, b
    """, ["prng-discipline"])
    assert len(findings) == 1
    assert "ks[0]" in findings[0].message


def test_prng_loop_without_rederivation_fires():
    findings = run("""
        import jax

        def gen(key, n):
            outs = []
            for i in range(n):
                outs.append(jax.random.normal(key, (4,)))
            return outs
    """, ["prng-discipline"])
    assert len(findings) == 1


def test_prng_exclusive_branches_are_quiet():
    assert run("""
        import jax

        def gen(key, arith):
            if arith:
                x = jax.random.randint(key, (4,), 0, 10)
            else:
                x = jax.random.normal(key, (4,))
            return x
    """, ["prng-discipline"]) == []


# -------------------------------------------------------- refcount-pairing
def test_refcount_pr9_leak_fixture_fires_with_rule_file_line():
    path = os.path.join(FIXTURES, "pr9_restore_leak.py")
    with open(path) as fh:
        src = fh.read()
    findings = check_source(src, path)
    leaks = [f for f in findings if f.rule == "refcount-pairing"]
    assert len(leaks) == 1, [f.render() for f in findings]
    leak_line = next(i + 1 for i, ln in enumerate(src.splitlines())
                     if "LEAK LINE" in ln)
    assert leaks[0].path == path
    assert leaks[0].line == leak_line
    assert leaks[0].context == "Admitter.try_admit_tiered"
    assert "return" in leaks[0].message


def test_refcount_pr9_fixed_fixture_is_quiet():
    path = os.path.join(FIXTURES, "pr9_restore_fixed.py")
    with open(path) as fh:
        src = fh.read()
    assert [f for f in check_source(src, path)
            if f.rule == "refcount-pairing"] == []


def test_refcount_early_return_leak():
    findings = run("""
        def admit(self, sess, need):
            got = self.allocator.alloc(need)
            if got is None:
                return False
            if sess.cancelled:
                return False
            sess.pages = got
            return True
    """, ["refcount-pairing"])
    assert len(findings) == 1
    assert "line 7" in findings[0].message     # the second early return


def test_refcount_release_on_every_path_is_quiet():
    assert run("""
        def admit(self, sess, need):
            got = self.allocator.alloc(need)
            if got is None:
                return False
            if sess.cancelled:
                self.allocator.release(got)
                return False
            sess.pages = got
            return True
    """, ["refcount-pairing"]) == []


def test_refcount_retain_without_release_on_raise_path():
    findings = run("""
        def share(self, pages):
            self.allocator.retain(pages)
            if not self.ok():
                raise RuntimeError("bad")
            self.table.append(pages)
    """, ["refcount-pairing"])
    assert len(findings) == 1
    assert "raise" in findings[0].message


def test_refcount_append_and_return_transfer_ownership():
    assert run("""
        def grab(self, need):
            got = self.allocator.alloc(need)
            if got:
                self.holds.append(got)

        def hand_out(self, need):
            got = self.allocator.alloc(need)
            return got
    """, ["refcount-pairing"]) == []


# ------------------------------------------------ suppressions + baseline
def test_suppression_covers_and_unused_is_flagged():
    suppressed = run("""
        import jax

        def call(f, x):
            # staticcheck: disable=recompile-hazard -- bench harness
            return jax.jit(f)(x)
    """, ["recompile-hazard"])
    assert suppressed == []

    dead = run("""
        def quiet():
            # staticcheck: disable=recompile-hazard -- nothing here
            return 1
    """, ["recompile-hazard"])
    assert rules_of(dead) == [UNUSED_SUPPRESSION]


def test_trailing_suppression_applies_to_its_own_line():
    assert run("""
        import jax

        def call(f, x):
            return jax.jit(f)(x)  # staticcheck: disable=recompile-hazard -- once
    """, ["recompile-hazard"]) == []


def test_parse_error_is_a_finding():
    findings = run("def broken(:\n")
    assert rules_of(findings) == ["parse-error"]


BAD_FILE = """\
import jax


def call(f, x):
    return jax.jit(f)(x)
"""


def _write(tmp_path, name, content):
    p = tmp_path / name
    p.write_text(content)
    return str(p)


def test_cli_exit_codes_and_json(tmp_path, capsys):
    bad = _write(tmp_path, "bad.py", BAD_FILE)
    report = tmp_path / "report.json"
    assert cli_main([bad, "--json", str(report),
                     "--baseline", str(tmp_path / "none.json")]) == 1
    blob = json.loads(report.read_text())
    assert blob["files_scanned"] == 1
    assert [f["rule"] for f in blob["new"]] == ["recompile-hazard"]
    assert blob["new"][0]["fingerprint"]

    good = _write(tmp_path, "good.py", "x = 1\n")
    assert cli_main([good, "--baseline",
                     str(tmp_path / "none.json")]) == 0

    assert cli_main([]) == 2
    assert cli_main([good, "--select", "no-such-rule"]) == 2
    capsys.readouterr()


def test_baseline_grandfathers_with_justification(tmp_path, capsys):
    bad = _write(tmp_path, "bad.py", BAD_FILE)
    baseline = str(tmp_path / "baseline.json")

    # writing a baseline without justifications fails the run...
    assert cli_main([bad, "--baseline", baseline,
                     "--write-baseline"]) == 1
    entries = load_baseline(baseline)
    assert len(entries) == 1
    # ...and scanning against it still fails (unjustified entry)
    assert cli_main([bad, "--baseline", baseline]) == 1

    # justify the entry -> scan passes, finding is grandfathered
    data = json.loads((tmp_path / "baseline.json").read_text())
    data["entries"][0]["justification"] = "bench-only; jit cost measured"
    (tmp_path / "baseline.json").write_text(json.dumps(data))
    assert cli_main([bad, "--baseline", baseline]) == 0
    out = capsys.readouterr()
    assert "1 baselined" in out.err + out.out

    # rewriting keeps the hand-written justification
    from repro.analysis.staticcheck.core import run_paths
    findings, _ = run_paths([bad])
    assert write_baseline(baseline, findings, load_baseline(baseline)) == 0


def test_unused_suppression_is_never_baselineable(tmp_path, capsys):
    src = "def quiet():\n    # staticcheck: disable=hot-sync -- stale\n    return 1\n"
    f = _write(tmp_path, "stale.py", src)
    baseline = str(tmp_path / "baseline.json")
    assert cli_main([f, "--baseline", baseline, "--write-baseline"]) == 1
    # the unused-suppression finding must still fail a scan even when
    # its fingerprint sits in the baseline
    data = json.loads((tmp_path / "baseline.json").read_text())
    for e in data["entries"]:
        e["justification"] = "trying to grandfather a dead suppression"
    (tmp_path / "baseline.json").write_text(json.dumps(data))
    assert cli_main([f, "--baseline", baseline]) == 1
    capsys.readouterr()


def test_repo_is_clean():
    """The acceptance gate: zero findings on src/ (suppressions and
    hotpath markers in the tree are part of the contract)."""
    root = os.path.join(os.path.dirname(__file__), os.pardir, "src")
    from repro.analysis.staticcheck.core import run_paths
    findings, n_files = run_paths([os.path.abspath(root)])
    assert n_files > 50
    assert findings == [], [f.render() for f in findings]
