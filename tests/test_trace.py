"""Trace-driven load harness: seeded generation, byte-stable
serialisation, virtual-time replay through the scheduler, SLO metrics,
adaptive horizon-K and priority-aware preemption.

The contracts pinned here:
  * (config, seed) regenerates a trace byte-for-byte — the checked-in
    golden file under tests/golden/ is the regression anchor;
  * replay is a pure scheduling change: greedy token streams are
    identical across fixed-K, adaptive-K and both preemption policies;
  * latency fields are JSON-safe in timed and untimed runs (no NaN ever
    reaches a report — ``json.dumps(..., allow_nan=False)`` must pass);
  * ContinuousResult counters are per-run, the virtual clock cumulative.
"""
import dataclasses
import json
import pathlib

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model
from repro.serving import (SessionRequest, SlotScheduler,
                           bursty_config, generate_trace, poisson_config,
                           slo_report, trace_from_text, trace_to_text,
                           validate_trace)

KEY = jax.random.PRNGKey(11)
CFG = get_config("qwen2.5-3b").reduced()
GOLDEN = pathlib.Path(__file__).parent / "golden" / "trace_bursty_s7.txt"


def _model():
    m = Model(CFG)
    return m, m.init(KEY)


def _replay(model, params, reqs, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 48)
    kw.setdefault("timed", False)
    sched = SlotScheduler(model, params, **kw)
    for r in reqs:
        sched.submit(r)
    return sched.run()


class TestGeneration:
    def test_seed_determinism_and_roundtrip(self):
        for cfg in (poisson_config(seed=3, n_requests=8),
                    bursty_config(seed=3, n_requests=8)):
            a, b = generate_trace(cfg), generate_trace(cfg)
            ta = trace_to_text(a)
            assert ta == trace_to_text(b)
            # text -> Trace -> text is the identity
            assert trace_to_text(trace_from_text(ta)) == ta

    def test_distinct_seeds_distinct_traces(self):
        t1 = trace_to_text(generate_trace(poisson_config(seed=1)))
        t2 = trace_to_text(generate_trace(poisson_config(seed=2)))
        assert t1 != t2

    def test_schema_validity(self):
        trace = generate_trace(bursty_config(seed=5, n_requests=16))
        last = 0.0
        for r in trace.requests:
            assert r.arrival_s > 0 and r.arrival_s >= last
            last = r.arrival_s
            assert len(r.prompt) >= 1 and r.max_new_tokens >= 1
            assert r.klass in trace.classes
            assert r.priority == trace.classes[r.klass].priority

    def test_validate_rejects_nonmonotone_arrivals(self):
        trace = generate_trace(poisson_config(seed=0, n_requests=4))
        reqs = list(trace.requests)
        reqs[2] = dataclasses.replace(reqs[2], arrival_s=0.0)
        with pytest.raises(ValueError, match="must be > 0"):
            validate_trace(dataclasses.replace(trace,
                                               requests=tuple(reqs)))

    def test_validate_rejects_unknown_class(self):
        trace = generate_trace(poisson_config(seed=0, n_requests=4))
        reqs = list(trace.requests)
        reqs[0] = dataclasses.replace(reqs[0], klass="nosuch")
        with pytest.raises(ValueError, match="unknown class"):
            validate_trace(dataclasses.replace(trace,
                                               requests=tuple(reqs)))

    def test_validate_rejects_duplicate_session_ids(self):
        """Regression: replay keys results by session id — a duplicate
        would silently collide in ``ContinuousResult.sessions``.  The
        validator must name the repeated id."""
        trace = generate_trace(poisson_config(seed=0, n_requests=4))
        reqs = list(trace.requests)
        reqs[2] = dataclasses.replace(
            reqs[2], session_id=reqs[0].session_id)
        with pytest.raises(ValueError,
                           match=f"duplicate session id "
                                 f"'{reqs[0].session_id}'"):
            validate_trace(dataclasses.replace(trace,
                                               requests=tuple(reqs)))

    def test_validate_rejects_negative_arrival(self):
        """Regression: a negative ``arrival_s`` (like 0) would bypass
        the scheduler's trace-release path entirely — the request would
        be admitted immediately instead of replayed.  Must be a clear
        ValueError, not a confusing monotonicity complaint."""
        trace = generate_trace(poisson_config(seed=0, n_requests=4))
        reqs = list(trace.requests)
        reqs[0] = dataclasses.replace(reqs[0], arrival_s=-1.5)
        with pytest.raises(ValueError, match="must be > 0"):
            validate_trace(dataclasses.replace(trace,
                                               requests=tuple(reqs)))

    def test_bursty_means_match_offered_load(self):
        """The on/off modulation must keep the long-run rate ~rate_rps
        (the off-gaps are sized to refund the burst's saved time)."""
        cfg = bursty_config(seed=9, n_requests=400, rate_rps=50.0)
        trace = generate_trace(cfg)
        span = trace.requests[-1].arrival_s
        rate = cfg.n_requests / span
        assert 0.6 * cfg.rate_rps < rate < 1.6 * cfg.rate_rps


class TestGoldenTrace:
    def test_regeneration_is_byte_identical(self):
        """The checked-in golden trace must regenerate byte-for-byte
        from its own header config — any drift in the generator's
        draw order, float formatting, or serialisation layout is a
        breaking change to every saved trace."""
        golden = GOLDEN.read_text()
        trace = trace_from_text(golden)          # parses AND validates
        assert trace_to_text(generate_trace(trace.config)) == golden

    def test_golden_schema(self):
        trace = trace_from_text(GOLDEN.read_text())
        validate_trace(trace)
        assert trace.config.process == "bursty"
        assert len(trace.requests) == trace.config.n_requests == 12


class TestReplay:
    def test_arrivals_released_by_virtual_time(self):
        model, params = _model()
        trace = generate_trace(poisson_config(
            seed=4, n_requests=6, vocab_size=CFG.vocab_size,
            rate_rps=40.0))
        res = _replay(model, params, trace.requests,
                      paged=True, page_size=8)
        assert res.arrivals == len(trace.requests)
        assert len(res.sessions) == len(trace.requests)
        for r in trace.requests:
            s = res.sessions[r.session_id]
            # fresh scheduler: the virtual clock starts at 0, so
            # arrivals land at their trace offsets un-rebased
            assert s.arrival_s == pytest.approx(r.arrival_s)
            assert s.ttft_s is not None and s.ttft_s > 0
            # emission stamps are strictly increasing and start at
            # first-token time >= arrival
            times = s.token_times_s
            assert len(times) == len(s.tokens)
            assert np.all(np.diff(times) > 0)
            assert times[0] >= s.arrival_s

    def test_policy_changes_never_change_streams(self):
        """Fixed-K, adaptive-K and both preemption policies replay the
        same trace to identical greedy token streams."""
        model, params = _model()
        trace = generate_trace(bursty_config(
            seed=6, n_requests=6, vocab_size=CFG.vocab_size,
            rate_rps=40.0, burst_len=3))
        kw = dict(paged=True, page_size=8, max_len=trace.max_len() + 1)
        ref = _replay(model, params, trace.requests,
                      steps_per_tick=1, **kw)
        arms = (dict(steps_per_tick=8),
                dict(steps_per_tick=8, adaptive_k=True),
                dict(steps_per_tick=8, adaptive_k=True,
                     priority_preemption=False))
        for arm in arms:
            res = _replay(model, params, trace.requests, **arm, **kw)
            assert res.arrivals == len(trace.requests)
            for r in trace.requests:
                np.testing.assert_array_equal(
                    ref.tokens_for(r.session_id),
                    res.tokens_for(r.session_id),
                    err_msg=f"{r.session_id} diverged under {arm}")

    def test_adaptive_k_dispatches_multiple_rungs(self):
        model, params = _model()
        trace = generate_trace(bursty_config(
            seed=6, n_requests=8, vocab_size=CFG.vocab_size,
            rate_rps=40.0, burst_len=4))
        res = _replay(model, params, trace.requests, paged=True,
                      page_size=8, max_len=trace.max_len() + 1,
                      steps_per_tick=8, adaptive_k=True)
        assert res.adaptive_k
        assert len(res.horizon_hist) >= 2, \
            f"adaptive policy never varied K: {res.horizon_hist}"
        assert set(res.horizon_hist) <= {1, 2, 4, 8}

    def test_adaptive_k_requires_a_ladder(self):
        model, params = _model()
        with pytest.raises(NotImplementedError):
            SlotScheduler(model, params, n_slots=2, max_len=32,
                          steps_per_tick=1, adaptive_k=True)

    def test_priority_preemption_protects_high_priority(self):
        """Under page pressure the FIFO baseline evicts the youngest
        session even when it is the high-priority one; the
        priority-aware policy evicts the low-priority session instead.
        Streams stay identical either way."""
        model, params = _model()
        reqs = [SessionRequest("low", np.arange(4) % CFG.vocab_size, 16,
                               priority=0),
                SessionRequest("high", np.arange(5) % CFG.vocab_size, 16,
                               priority=1)]
        kw = dict(n_slots=2, max_len=24, paged=True, page_size=4,
                  n_pages=7)
        fifo = _replay(model, params, reqs, priority_preemption=False,
                       **kw)
        prio = _replay(model, params, reqs, priority_preemption=True,
                       **kw)
        fifo_victims = {e[1] for e in fifo.events if e[0] == "preempt"}
        prio_victims = {e[1] for e in prio.events if e[0] == "preempt"}
        assert fifo_victims == {"high"}
        assert prio_victims == {"low"}
        for r in reqs:
            np.testing.assert_array_equal(
                fifo.tokens_for(r.session_id),
                prio.tokens_for(r.session_id),
                err_msg=f"{r.session_id} diverged across "
                        f"preemption policies")

    def test_equal_priorities_degrade_to_youngest_first(self):
        """With every priority equal the two policies pick the same
        victims — priority preemption is a strict generalisation."""
        model, params = _model()
        reqs = [SessionRequest("a", np.arange(4) % CFG.vocab_size, 16),
                SessionRequest("b", np.arange(5) % CFG.vocab_size, 16)]
        kw = dict(n_slots=2, max_len=24, paged=True, page_size=4,
                  n_pages=7)
        fifo = _replay(model, params, reqs, priority_preemption=False,
                       **kw)
        prio = _replay(model, params, reqs, priority_preemption=True,
                       **kw)
        assert [e[1] for e in fifo.events if e[0] == "preempt"] \
            == [e[1] for e in prio.events if e[0] == "preempt"]


class TestLatencyFields:
    def _trace(self, n=5):
        return generate_trace(poisson_config(
            seed=8, n_requests=n, vocab_size=CFG.vocab_size,
            rate_rps=40.0))

    def test_untimed_run_has_no_wall_fields_and_no_nans(self):
        model, params = _model()
        trace = self._trace()
        res = _replay(model, params, trace.requests, timed=False,
                      paged=True, page_size=8)
        for s in res.sessions.values():
            assert s.ttft_wall_s is None        # None, never NaN
            assert s.ttft_s is not None
            assert np.all(np.isfinite(s.token_times_s))
        rep = slo_report(res, trace.classes)
        json.dumps(rep, allow_nan=False)        # raises on any NaN/Inf
        assert rep["ttft_wall"] is None

    def test_timed_run_reports_wall_ttft(self):
        model, params = _model()
        trace = self._trace()
        res = _replay(model, params, trace.requests, timed=True,
                      paged=True, page_size=8)
        walls = [s.ttft_wall_s for s in res.sessions.values()]
        assert all(w is not None and w >= 0 for w in walls)
        rep = slo_report(res, trace.classes)
        json.dumps(rep, allow_nan=False)
        assert rep["ttft_wall"] is not None
        assert rep["ttft_wall"]["p95"] >= 0

    def test_counters_are_per_run_clock_is_cumulative(self):
        """Two traced waves through ONE scheduler: ``arrivals`` and
        ``horizon_hist`` reset per run(), the virtual clock does not —
        and the second wave's arrivals are rebased onto it."""
        model, params = _model()
        sched = SlotScheduler(model, params, n_slots=2, max_len=48,
                              paged=True, page_size=8, timed=False,
                              steps_per_tick=4, adaptive_k=True)
        t1 = self._trace(4)
        for r in t1.requests:
            sched.submit(r)
        res1 = sched.run()
        t2 = generate_trace(poisson_config(
            seed=9, n_requests=3, vocab_size=CFG.vocab_size,
            rate_rps=40.0))
        for r in t2.requests:
            sched.submit(dataclasses.replace(r,
                                             session_id="w2_"
                                             + r.session_id))
        res2 = sched.run()
        assert res1.arrivals == 4 and res2.arrivals == 3
        assert res2.now_s > res1.now_s > 0
        assert sum(res1.horizon_hist.values()) > 0
        assert sum(res2.horizon_hist.values()) > 0
        # second run's macro-ticks only (not cumulative):
        assert sum(res2.horizon_hist.values()) < res2.ticks + 1
        for r in t2.requests:
            s = res2.sessions["w2_" + r.session_id]
            # rebased: arrival offsets are relative to the second run
            assert s.arrival_s == pytest.approx(res1.now_s + r.arrival_s)

    def test_slo_report_math(self):
        """Goodput counts ONLY sessions inside both bounds; a class
        whose bound is impossible contributes zero."""
        model, params = _model()
        trace = self._trace()
        res = _replay(model, params, trace.requests, timed=False,
                      paged=True, page_size=8)
        loose = {n: dataclasses.replace(c, slo_ttft_s=1e3, slo_tpot_s=1e3)
                 for n, c in trace.classes.items()}
        tight = {n: dataclasses.replace(c, slo_ttft_s=1e-9,
                                        slo_tpot_s=1e-9)
                 for n, c in trace.classes.items()}
        rl, rt = slo_report(res, loose), slo_report(res, tight)
        assert rl["slo_frac"] == 1.0 and rt["slo_frac"] == 0.0
        assert rt["goodput_tok_s"] == 0.0
        assert rl["goodput_tok_s"] == pytest.approx(
            rl["tokens_per_s_virtual"])
        total = sum(len(s.tokens) for s in res.sessions.values())
        assert rl["goodput_tok_s"] == pytest.approx(
            total / rl["makespan_s"])
