"""Floor-model validation: exact reproduction of the paper's own numbers
(Table 9, §3.3, §3.4) + hypothesis property tests on the invariants."""

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config, list_configs
from repro.core import floor as fl
from repro.core.hardware import (GPU_A100, GPU_H100, GPU_L4, GPU_L40S,
                                 TPU_V5E)

QWEN = get_config("qwen2.5-7b")
MISTRAL = get_config("mistral-7b-v0.3")
LLAMA = get_config("llama-3.1-8b")


class TestPaperValidation:
    """Every number here is quoted in the paper text."""

    def test_qwen_weight_bytes(self):
        # paper §3.3: W = 15.23 GB decimal
        assert fl.weight_bytes(QWEN) == pytest.approx(15.23e9, rel=0.002)

    def test_mistral_weight_bytes(self):
        assert fl.weight_bytes(MISTRAL) == pytest.approx(14.50e9, rel=0.002)

    def test_llama_weight_bytes(self):
        assert fl.weight_bytes(LLAMA) == pytest.approx(16.06e9, rel=0.002)

    def test_qwen_kv_bytes_per_token(self):
        # paper §3.3: 2*28*4*128*2 = 56 KB per token
        assert fl.kv_bytes_per_token(QWEN) == 2 * 28 * 4 * 128 * 2 == 57344

    def test_mistral_kv_bytes_per_token(self):
        # paper §3.3: 128 KB per token
        assert fl.kv_bytes_per_token(MISTRAL) == 131072

    # paper Table 9 floors (ms), spot-checked across the grid
    @pytest.mark.parametrize("cfg,chip,ctx,expected_ms", [
        (QWEN, GPU_H100, 2048, 4.58),
        (QWEN, GPU_H100, 16384, 4.82),
        (QWEN, GPU_A100, 2048, 7.54),
        (QWEN, GPU_L40S, 2048, 17.78),
        (QWEN, GPU_L4, 2048, 51.17),
        (MISTRAL, GPU_H100, 2048, 4.40),
        (MISTRAL, GPU_L4, 16384, 55.55),
        (LLAMA, GPU_A100, 8192, 8.41),
        (LLAMA, GPU_L40S, 16384, 21.09),
    ])
    def test_table9_floors(self, cfg, chip, ctx, expected_ms):
        cell = fl.floor_cell(cfg, chip, ctx)
        assert cell.t_floor_ms == pytest.approx(expected_ms, rel=0.005)

    def test_r_floor_headline(self):
        # paper Table 1: Qwen H100 ctx=2048 t_obs=16.97ms -> R=0.270
        cell = fl.floor_cell(QWEN, GPU_H100, 2048)
        assert cell.r_floor(16.97e-3) == pytest.approx(0.270, abs=0.002)
        # L4: t_obs=63.15ms -> R=0.810
        cell = fl.floor_cell(QWEN, GPU_L4, 2048)
        assert cell.r_floor(63.15e-3) == pytest.approx(0.810, abs=0.002)

    def test_l4_quant_floor(self):
        # paper Table 7: int4 floor 13.09 ms on L4 (4x weight reduction)
        cell = fl.floor_cell(QWEN, GPU_L4, 2048, weight_dtype_bytes=0.5)
        assert cell.t_floor_ms == pytest.approx(13.09, rel=0.01)


class TestAssignedArchCounts:
    @pytest.mark.parametrize("name,total_b,active_b", [
        ("qwen2-moe-a2.7b", 14.3, 2.7),
        ("llama4-scout-17b-a16e", 107.8, 17.2),
        ("mamba2-2.7b", 2.7, 2.7),
        ("phi4-mini-3.8b", 3.8, 3.8),
        ("olmo-1b", 1.18, 1.18),
        ("internlm2-1.8b", 1.89, 1.89),
        ("qwen2.5-3b", 3.09, 3.09),
        ("zamba2-1.2b", 1.10, 1.10),
    ])
    def test_param_counts(self, name, total_b, active_b):
        cfg = get_config(name)
        assert fl.param_count(cfg) / 1e9 == pytest.approx(total_b, rel=0.03)
        assert fl.active_param_count(cfg) / 1e9 == pytest.approx(active_b, rel=0.03)

    def test_ssm_floor_ctx_independent(self):
        cfg = get_config("mamba2-2.7b")
        f1 = fl.floor_cell(cfg, TPU_V5E, 2048).t_floor_s
        f2 = fl.floor_cell(cfg, TPU_V5E, 524288).t_floor_s
        assert f1 == f2  # the paper's K-growth term degenerates for SSM

    def test_hybrid_kv_slower_growth(self):
        dense = get_config("qwen2.5-3b")
        hybrid = get_config("zamba2-1.2b")
        assert (fl.kv_bytes_per_token(hybrid)
                < fl.kv_bytes_per_token(dense.replace(
                    n_kv_heads=32, head_dim=64, n_layers=38)))


class TestFloorProperties:
    @given(ctx=st.integers(1, 10 ** 6))
    @settings(max_examples=50, deadline=None)
    def test_floor_monotone_in_ctx(self, ctx):
        f1 = fl.floor_cell(QWEN, GPU_H100, ctx).t_floor_s
        f2 = fl.floor_cell(QWEN, GPU_H100, ctx + 1).t_floor_s
        assert f2 >= f1

    @given(ctx=st.integers(1, 10 ** 6),
           bw_a=st.floats(1e9, 1e13), bw_b=st.floats(1e9, 1e13))
    @settings(max_examples=50, deadline=None)
    def test_floor_antitone_in_bandwidth(self, ctx, bw_a, bw_b):
        import dataclasses
        a = dataclasses.replace(GPU_H100, hbm_bw=min(bw_a, bw_b))
        b = dataclasses.replace(GPU_H100, hbm_bw=max(bw_a, bw_b))
        assert (fl.floor_cell(QWEN, a, ctx).t_floor_s
                >= fl.floor_cell(QWEN, b, ctx).t_floor_s)

    @given(ctx=st.integers(1, 10 ** 5), t_obs=st.floats(1e-4, 10))
    @settings(max_examples=50, deadline=None)
    def test_r_floor_bounded_when_obs_above_floor(self, ctx, t_obs):
        cell = fl.floor_cell(QWEN, GPU_L4, ctx)
        t = max(t_obs, cell.t_floor_s)
        assert 0 < cell.r_floor(t) <= 1.0 + 1e-9

    @given(batch=st.integers(1, 512))
    @settings(max_examples=30, deadline=None)
    def test_moe_coverage_interpolation(self, batch):
        """batch-1 streams W_active; large batch approaches W_total."""
        cfg = get_config("qwen2-moe-a2.7b")
        w1 = fl.floor_cell(cfg, TPU_V5E, 2048, batch=1).weight_bytes
        wb = fl.floor_cell(cfg, TPU_V5E, 2048, batch=batch).weight_bytes
        winf = fl.floor_cell(cfg, TPU_V5E, 2048, batch=10 ** 6).weight_bytes
        assert w1 - 1e-6 <= wb <= winf + 1e-6

    @given(st.sampled_from(list_configs()))
    @settings(max_examples=13, deadline=None)
    def test_active_leq_total(self, name):
        cfg = get_config(name)
        assert fl.active_param_count(cfg) <= fl.param_count(cfg)
