"""Serving engine + dispatch-mode (CUDA-Graphs-analogue) tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.dispatch import MODES
from repro.models import Model
from repro.serving import DecodeEngine

KEY = jax.random.PRNGKey(11)
CFG = get_config("qwen2.5-3b").reduced()


def _engine(quant="bf16", cfg=CFG):
    m = Model(cfg)
    params = m.init(KEY)
    return DecodeEngine(m, params, quant_path=quant)


def _prompt(cfg=CFG, B=1, S=16):
    return {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)}


class TestEngine:
    def test_streamed_generation(self):
        eng = _engine()
        res = eng.generate_streamed(_prompt(), max_len=64, n_new=8, timed=True)
        assert res.tokens.shape == (1, 8)
        assert len(res.step_times_s) == 7

    def test_streamed_reports_throughput_untimed(self):
        """tokens_per_s must be real even with timed=False (regression:
        it was nan because it was derived from the gated per-step
        walls); per-step times stay gated on ``timed``."""
        eng = _engine()
        res = eng.generate_streamed(_prompt(), max_len=64, n_new=6)
        assert res.step_times_s == []
        assert np.isfinite(res.tokens_per_s) and res.tokens_per_s > 0

    def test_fused_equals_streamed_greedy(self):
        """One-program lax.scan generation == step-streamed greedy."""
        eng = _engine()
        r1 = eng.generate_streamed(_prompt(), max_len=64, n_new=6)
        r2 = eng.generate_fused(_prompt(), max_len=64, n_new=6)
        assert jnp.array_equal(r1.tokens, r2.tokens)

    def test_batched_decode(self):
        eng = _engine()
        res = eng.generate_streamed(_prompt(B=4), max_len=64, n_new=5)
        assert res.tokens.shape == (4, 5)

    def test_quantized_generation(self):
        eng = _engine("int4_fused")
        res = eng.generate_streamed(_prompt(), max_len=64, n_new=4)
        assert res.tokens.shape == (1, 4)

    def test_ssm_generation(self):
        cfg = get_config("mamba2-2.7b").reduced()
        eng = _engine(cfg=cfg)
        res = eng.generate_streamed(_prompt(cfg), max_len=64, n_new=5)
        assert res.tokens.shape == (1, 5)

    def test_temperature_sampling_reproducible(self):
        eng = _engine()
        r1 = eng.generate_streamed(_prompt(), max_len=64, n_new=5,
                                   temperature=0.8, seed=3)
        r2 = eng.generate_streamed(_prompt(), max_len=64, n_new=5,
                                   temperature=0.8, seed=3)
        assert jnp.array_equal(r1.tokens, r2.tokens)


class TestDispatchModes:
    """The paper's §5 requirement: the A/B touches the launch term and
    ONLY the launch term — all three executors must produce identical
    logits and caches."""

    def _state(self, eng):
        _, cache = eng.prefill(_prompt(), max_len=64)
        tok = jnp.array([[5]], jnp.int32)
        return {"tokens": tok, "cache": cache}

    def test_all_modes_same_logits(self):
        eng = _engine()
        program = eng.step_program(None)
        outs = {}
        for mode in MODES:
            state = self._state(eng)
            run = program.executor(mode)
            out = run(state)
            outs[mode] = np.asarray(out["logits"], np.float32)
        np.testing.assert_allclose(outs["eager"], outs["full_jit"],
                                   atol=1e-2)
        np.testing.assert_allclose(outs["stage_jit"], outs["full_jit"],
                                   atol=1e-2)

    def test_program_matches_production_decode_step(self):
        eng = _engine()
        program = eng.step_program(None)
        state = self._state(eng)
        out = program.executor("full_jit")(state)
        logits_ref, _ = jax.jit(eng.model.decode_step)(
            eng.params, self._state(eng)["cache"], state["tokens"])
        np.testing.assert_allclose(np.asarray(out["logits"], np.float32),
                                   np.asarray(logits_ref, np.float32),
                                   atol=1e-2)

    def test_launch_counts(self):
        from repro.core.dispatch import launch_count
        eng = _engine()
        program = eng.step_program(None)
        assert launch_count(program, "full_jit") == 1
        assert launch_count(program, "stage_jit") == CFG.n_layers + 2
        assert launch_count(program, "eager") == -1

    def test_ring_cache_stage_equivalence_past_wrap(self):
        """Regression: the block stages used the raw position as the
        write offset and a non-ring mask, so a sliding-window cache
        wrapped (pos >= kv_len) clamped every write to the last slot and
        stage_jit/eager silently diverged from full_jit/decode_step."""
        cfg = CFG.replace(sliding_window=8)
        m = Model(cfg)
        params = m.init(KEY)

        def fresh_cache():
            cache = m.init_cache(1, 32)            # kv_len capped to 8
            assert cache["k"].shape[2] == 8
            prompt = jax.random.randint(KEY, (1, 6), 0, cfg.vocab_size)
            _, cache = jax.jit(m.prefill)(params, {"tokens": prompt}, cache)
            return cache

        program = m.step_program(params, fresh_cache())
        runs = {mode: program.executor(mode) for mode in MODES}
        states = {mode: {"tokens": None, "cache": fresh_cache()}
                  for mode in MODES}
        step = jax.jit(m.decode_step)
        ref_cache = fresh_cache()
        for i in range(12):                        # pos 6..17 wraps at 8
            tok = jnp.array([[(3 * i + 1) % cfg.vocab_size]], jnp.int32)
            logits_ref, ref_cache = step(params, ref_cache, tok)
            for mode in MODES:
                states[mode]["tokens"] = tok
                states[mode] = runs[mode](states[mode])
                np.testing.assert_allclose(
                    np.asarray(states[mode]["logits"], np.float32),
                    np.asarray(logits_ref, np.float32), atol=1e-2,
                    err_msg=f"{mode} diverged at step {i} (pos {6 + i})")

    def test_int8_kv_scales_threaded_through_stages(self):
        """Regression: the block stages dropped k_scale/v_scale, so new
        bf16 K/V rows were astype-cast into the int8 cache as garbage
        codes against stale scales.  All three executors must now match
        decode_step's logits and produce a sane quantised cache."""
        from repro.quant import kv as kvq
        m = Model(CFG)
        params = m.init(KEY)

        def fresh_cache():
            cache = m.init_cache(1, 32, kv_dtype=jnp.int8)
            prompt = jax.random.randint(KEY, (1, 6), 0, CFG.vocab_size)
            _, cache = jax.jit(m.prefill)(params, {"tokens": prompt}, cache)
            return cache

        tok = jnp.array([[5]], jnp.int32)
        logits_ref, cache_ref = jax.jit(m.decode_step)(
            params, fresh_cache(), tok)
        dq_ref = np.asarray(kvq.dequantize_kv(
            cache_ref["k"], cache_ref["k_scale"], jnp.float32))
        program = m.step_program(params, fresh_cache())
        for mode in MODES:
            out = program.executor(mode)(
                {"tokens": tok, "cache": fresh_cache()})
            np.testing.assert_allclose(
                np.asarray(out["logits"], np.float32),
                np.asarray(logits_ref, np.float32), atol=1e-2,
                err_msg=f"{mode} logits diverged on int8 cache")
            dq = np.asarray(kvq.dequantize_kv(
                out["cache"]["k"], out["cache"]["k_scale"], jnp.float32))
            # garbage codes against stale scales would be off by O(1);
            # legitimate requantisation noise is bounded by one LSB
            np.testing.assert_allclose(dq, dq_ref, atol=0.05,
                                       err_msg=f"{mode} cache corrupted")

    def test_launch_count_method_regression(self):
        """StepProgram.launch_count (method form) == module function for
        every mode — the paper's launch-term accounting must not drift."""
        from repro.core.dispatch import launch_count
        program = _engine().step_program(None)
        for mode in MODES:
            assert program.launch_count(mode) == launch_count(program, mode)
        assert program.launch_count("full_jit") == 1
        assert program.launch_count("stage_jit") == len(program.stages)
        with pytest.raises(ValueError):
            program.executor("not_a_mode")
