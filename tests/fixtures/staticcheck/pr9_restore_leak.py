"""Seeded fixture: the PR-9 ``TieredPageStore`` restore-failure leak.

This reconstructs the exact shape of the bug fixed in PR 9: pages
allocated for a tiered restore, handed to ``take_parked`` inside a
``try``, and a ``TierCopyError`` handler that drops the parked copy and
bails out WITHOUT releasing the freshly allocated pages — every failed
restore permanently shrinks the pool.  The refcount-pairing rule must
flag the ``alloc`` line (see ``test_staticcheck.py``; the corrected
form lives in ``pr9_restore_fixed.py``).

Scanned as data by the linter tests — never imported.
"""


class TierCopyError(Exception):
    pass


class Admitter:
    def try_admit_tiered(self, head):
        got = self.store.alloc(self.n_restore)        # LEAK LINE
        if got is None:
            return False
        try:
            self.cache = self.store.take_parked(
                head.sid, 0, got, self.cache)
        except TierCopyError:
            self.store.drop_parked(head.sid)
            self.degraded_restores += 1
            return False          # `got` never released on this path
        head.pages = list(got)
        return True
