"""The corrected form of ``pr9_restore_leak.py`` (the PR-9 fix): the
``TierCopyError`` handler releases the allocated pages before bailing,
so the pool balances on the degraded path too.  The refcount-pairing
rule must stay quiet here."""


class TierCopyError(Exception):
    pass


class Admitter:
    def try_admit_tiered(self, head):
        got = self.store.alloc(self.n_restore)
        if got is None:
            return False
        try:
            self.cache = self.store.take_parked(
                head.sid, 0, got, self.cache)
        except TierCopyError:
            self.store.release(got)       # the fix: pool balances
            self.store.drop_parked(head.sid)
            self.degraded_restores += 1
            return False
        head.pages = list(got)
        return True
