"""Per-kernel validation: shape/dtype sweeps, assert_allclose vs the
ref.py pure-jnp oracles (assignment requirement)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.int4_matmul.ops import int4_matmul
from repro.kernels.int4_matmul.ref import int4_matmul_ref, unpack_int4_ref
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.quant import quantize_int4

KEY = jax.random.PRNGKey(42)


class TestInt4Matmul:
    @pytest.mark.parametrize("M,K,N,group", [
        (1, 128, 128, 128),      # decode shape
        (4, 256, 384, 128),
        (16, 64, 96, 64),
        (130, 512, 300, 128),    # non-divisible M/N (padding path)
        (8, 128, 128, 32),       # small groups
    ])
    @pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
    def test_matches_ref(self, M, K, N, group, dtype):
        kx, kw = jax.random.split(jax.random.fold_in(KEY, M * K + N))
        x = jax.random.normal(kx, (M, K), dtype)
        w = jax.random.normal(kw, (K, N), jnp.float32) * 0.1
        qt = quantize_int4(w, group=group)
        ref = int4_matmul_ref(x, qt.data, qt.scales, qt.group)
        out = int4_matmul(x, qt.data, qt.scales, group=qt.group)
        np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                                   rtol=0.02, atol=0.05 * float(jnp.std(ref)))

    def test_pack_unpack_roundtrip(self):
        w = jax.random.normal(KEY, (64, 32), jnp.float32)
        qt = quantize_int4(w, group=32)
        q = unpack_int4_ref(qt.data)
        assert q.shape == (64, 32)
        assert int(jnp.min(q)) >= -8 and int(jnp.max(q)) <= 7

    def test_quantization_error_bounded(self):
        """int4 with per-group scales: elementwise error <= scale/2."""
        w = jax.random.normal(KEY, (256, 128), jnp.float32)
        qt = quantize_int4(w, group=64)
        from repro.quant import dequantize
        wd = dequantize(qt, jnp.float32)
        err = jnp.abs(wd - w)
        bound = jnp.repeat(qt.scales, 64, axis=0) * 0.5 + 1e-6
        assert bool(jnp.all(err <= bound))


class TestDecodeAttention:
    @pytest.mark.parametrize("B,Hq,Hkv,hd,S", [
        (1, 28, 4, 128, 512),    # Qwen-2.5-7B decode shape (paper)
        (2, 8, 2, 64, 256),
        (3, 16, 4, 64, 384),
        (2, 4, 4, 128, 128),     # MHA
        (1, 2, 1, 32, 96),       # MQA, non-divisible S
    ])
    def test_matches_ref(self, B, Hq, Hkv, hd, S):
        ks = jax.random.split(jax.random.fold_in(KEY, B * S + Hq), 3)
        q = jax.random.normal(ks[0], (B, Hq, hd), jnp.bfloat16)
        k = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.bfloat16)
        v = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.bfloat16)
        mask = jnp.arange(S) <= (S * 2) // 3
        ref = decode_attention_ref(q, k, v, mask)
        out = decode_attention(q, k, v, mask=mask, block=128)
        np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(ref),
                                   atol=0.02, rtol=0.02)

    @given(pos=st.integers(0, 255))
    @settings(max_examples=12, deadline=None)
    def test_any_mask_prefix(self, pos):
        B, Hq, Hkv, hd, S = 1, 4, 2, 32, 256
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, Hq, hd), jnp.bfloat16)
        k = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.bfloat16)
        v = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.bfloat16)
        mask = jnp.arange(S) <= pos
        ref = decode_attention_ref(q, k, v, mask)
        out = decode_attention(q, k, v, mask=mask, block=64)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref), atol=0.03)

    def test_length_api(self):
        B, Hq, Hkv, hd, S = 2, 4, 2, 32, 128
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, Hq, hd), jnp.bfloat16)
        k = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.bfloat16)
        v = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.bfloat16)
        a = decode_attention(q, k, v, length=jnp.int32(40))
        b = decode_attention(q, k, v, mask=jnp.arange(S) < 40)
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-3)


class TestRmsnorm:
    @pytest.mark.parametrize("R,D", [(1, 64), (4, 128), (100, 256), (257, 64)])
    @pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
    def test_matches_ref(self, R, D, dtype):
        x = jax.random.normal(KEY, (R, D), dtype)
        w = jax.random.normal(jax.random.fold_in(KEY, 1), (D,), jnp.float32)
        out = rmsnorm(x, w)
        ref = rmsnorm_ref(x, w)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref, np.float32), atol=0.02)

    def test_leading_dims(self):
        x = jax.random.normal(KEY, (2, 3, 64), jnp.bfloat16)
        w = jnp.ones((64,), jnp.float32)
        assert rmsnorm(x, w).shape == (2, 3, 64)
