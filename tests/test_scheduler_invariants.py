"""Property-based scheduler soak: random submit/evict/preempt/finish
sequences must never unbalance the page accounting or grow the
compiled-program set.

Three layers, cheapest first:
  * ``BlockAllocator`` random walks — refcount/free-list balance and
    O(1) double-free detection, pure Python, hundreds of examples;
  * ``PrefixCache`` random walks against a live allocator — cache
    registration/match/reclaim keeps every page accounted for;
  * full ``SlotScheduler`` churn — randomized waves (prompt lengths,
    budgets, priorities, arrival offsets) through module-cached
    schedulers on the paged, prefix-cache, adaptive-horizon,
    host-tiered and chaos (random fault plans interleaved with the
    churn) configs, asserting free-list balance, host-pool
    balance (nothing pinned survives a drain), empty slots, and a
    stable compiled step count after warmup.  Schedulers are cached at module scope
    because jit caches live per instance — a fresh scheduler per
    example would recompile and turn a soak into a compile benchmark.

Runs under real hypothesis when installed (CI) and under the conftest
shim's fixed example set otherwise — the test body is identical.
"""
import random

import jax
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.models import Model
from repro.serving import (BlockAllocator, PrefixCache, SessionRequest,
                           SlotScheduler)

KEY = jax.random.PRNGKey(11)
# tiny dims: the soak measures accounting, not math
CFG = get_config("qwen2.5-3b").reduced().replace(
    vocab_size=64, d_model=64, d_ff=128, n_layers=2,
    n_heads=4, n_kv_heads=2, head_dim=16, dtype="float32")

# prompt lengths drawn from a small set so the prefill program count
# stays bounded across hundreds of examples
PROMPT_LENS = (4, 6, 8)
MAX_LEN = 24


class TestBlockAllocatorProperties:
    @given(seed=st.integers(0, 10**9), n_pages=st.integers(2, 24))
    @settings(max_examples=200, deadline=None)
    def test_random_walk_balance(self, seed, n_pages):
        """Any alloc/retain/release interleaving keeps
        ``n_free + distinct held == n_pages - 1`` and per-page
        refcounts equal to the holder multiset."""
        rng = random.Random(seed)
        alloc = BlockAllocator(n_pages)
        held = []                       # our holds, with multiplicity
        for _ in range(120):
            op = rng.random()
            if op < 0.45:
                got = alloc.alloc(rng.randint(0, 3))
                if got is not None:
                    held.extend(got)
            elif op < 0.65 and held:
                p = rng.choice(held)
                alloc.retain([p])
                held.append(p)
            elif held:
                p = held.pop(rng.randrange(len(held)))
                alloc.release([p])
            distinct = set(held)
            assert alloc.n_free + len(distinct) == n_pages - 1
            for p in distinct:
                assert alloc.refcount(p) == held.count(p)
        alloc.release(held)
        assert alloc.n_free == n_pages - 1

    @given(seed=st.integers(0, 10**9))
    @settings(max_examples=200, deadline=None)
    def test_alloc_all_or_nothing(self, seed):
        rng = random.Random(seed)
        alloc = BlockAllocator(6)       # 5 real pages
        first = alloc.alloc(rng.randint(1, 5))
        free_before = alloc.n_free
        assert alloc.alloc(free_before + rng.randint(1, 3)) is None
        assert alloc.n_free == free_before, \
            "failed alloc must not consume pages"
        alloc.release(first)

    def test_double_free_raises(self):
        alloc = BlockAllocator(4)
        (page,) = alloc.alloc(1)
        alloc.release([page])
        with pytest.raises(AssertionError, match="double free"):
            alloc.release([page])

    def test_release_of_never_allocated_raises(self):
        alloc = BlockAllocator(4)
        with pytest.raises(AssertionError):
            alloc.release([2])

    def test_retain_of_free_page_raises(self):
        alloc = BlockAllocator(4)
        with pytest.raises(AssertionError, match="retain"):
            alloc.retain([1])

    def test_garbage_page_never_handed_out(self):
        alloc = BlockAllocator(5)
        got = alloc.alloc(4)
        assert 0 not in got
        with pytest.raises(AssertionError):
            alloc.release([0])


class TestPrefixCacheProperties:
    PAGE = 4
    VOCAB = 3                           # tiny vocab -> real prefix hits

    def _admit(self, alloc, cache, tokens):
        """The scheduler's admission dance: match, retain the hits as a
        session hold, alloc the rest, register the full pages."""
        matched = cache.match(tokens, self.PAGE)
        n_blocks = len(tokens) // self.PAGE
        fresh = alloc.alloc(n_blocks - len(matched))
        if fresh is None:
            return None
        alloc.retain(matched)
        pages = matched + fresh
        cache.register(tokens, self.PAGE, pages, n_blocks)
        return pages

    @given(seed=st.integers(0, 10**9))
    @settings(max_examples=200, deadline=None)
    def test_random_walk_accounts_for_every_page(self, seed):
        rng = random.Random(seed)
        alloc = BlockAllocator(32)
        cache = PrefixCache(alloc)
        live = []                       # session holds
        for _ in range(12):
            n_tok = rng.randrange(self.PAGE, 5 * self.PAGE)
            tokens = np.asarray([rng.randrange(self.VOCAB)
                                 for _ in range(n_tok)], np.int32)
            pages = self._admit(alloc, cache, tokens)
            if pages is not None:
                live.append(pages)
            if live and rng.random() < 0.5:
                alloc.release(live.pop(rng.randrange(len(live))))
            # every cached page is allocator-held by the cache
            for p in cache.pages():
                assert alloc.refcount(p) >= 1
            # cache + sessions cover every non-free page
            covered = set(cache.pages()).union(*live) if live \
                else set(cache.pages())
            assert len(covered) == alloc.n_pages - 1 - alloc.n_free
        for pages in live:
            alloc.release(pages)
        cache.flush()
        assert len(cache) == 0
        assert alloc.n_free == alloc.n_pages - 1, \
            "flush after all releases must return the whole pool"

    @given(seed=st.integers(0, 10**9))
    @settings(max_examples=100, deadline=None)
    def test_identical_prompts_share_pages(self, seed):
        rng = random.Random(seed)
        alloc = BlockAllocator(32)
        cache = PrefixCache(alloc)
        n_tok = rng.randrange(2 * self.PAGE, 5 * self.PAGE)
        tokens = np.asarray([rng.randrange(self.VOCAB)
                             for _ in range(n_tok)], np.int32)
        first = self._admit(alloc, cache, tokens)
        second = self._admit(alloc, cache, tokens)
        n_blocks = len(tokens) // self.PAGE
        assert second[:n_blocks] == first[:n_blocks], \
            "same prompt must resolve to the same physical pages"
        alloc.release(first)
        alloc.release(second)
        cache.flush()
        assert alloc.n_free == alloc.n_pages - 1

    def test_reclaim_respects_live_holders(self):
        alloc = BlockAllocator(16)
        cache = PrefixCache(alloc)
        tokens = np.asarray([1] * (3 * self.PAGE), np.int32)
        pages = self._admit(alloc, cache, tokens)
        assert cache.reclaimable() == 0     # session still holds them
        alloc.release(pages)
        assert cache.reclaimable() == 3
        assert cache.reclaim(99) == 3
        assert alloc.n_free == alloc.n_pages - 1


# ------------------------------------------------------- scheduler churn
_STATE: dict = {}


def _sched(kind: str) -> SlotScheduler:
    """Module-cached schedulers — jit caches are per instance, so the
    soak must reuse them across examples to stay a soak."""
    if "model" not in _STATE:
        m = Model(CFG)
        _STATE["model"] = m
        _STATE["params"] = m.init(KEY)
    if kind not in _STATE:
        kw = dict(n_slots=2, max_len=MAX_LEN, paged=True, page_size=4,
                  n_pages=9, timed=False)
        if kind == "prefix":
            kw["prefix_cache"] = True
        elif kind == "adaptive":
            kw.update(steps_per_tick=4, adaptive_k=True)
        elif kind == "tiered":
            # host pool smaller than the device pool: parks can fail
            # (the fallback-to-reprefill path soaks too)
            kw.update(prefix_cache=True, kv_tier="host", host_pages=6)
        elif kind == "chaos":
            # tiered config again; each example arms a fresh seeded
            # fault plan on the cached instance (injector is consulted
            # dynamically, so no recompile)
            kw.update(prefix_cache=True, kv_tier="host", host_pages=6)
        _STATE[kind] = SlotScheduler(_STATE["model"], _STATE["params"],
                                     **kw)
    return _STATE[kind]


@pytest.mark.slow
class TestSchedulerChurnSoak:
    @given(seed=st.integers(0, 10**9),
           kind=st.sampled_from(("paged", "prefix", "adaptive", "tiered",
                                 "chaos")),
           n_sessions=st.integers(1, 4),
           gap_s=st.sampled_from((0.0, 0.004, 0.02)))
    @settings(max_examples=200, deadline=None)
    def test_churn_leaves_no_residue(self, seed, kind, n_sessions,
                                     gap_s):
        """One randomized wave (lengths, budgets, priorities, arrival
        offsets) through a long-lived scheduler: afterwards every slot
        is free, the page pool balances against the prefix cache's
        holds, and the compiled step count never grew past warmup.
        The ``chaos`` kind additionally arms a random seeded fault plan
        (serving/faults.py) against the wave — faults may truncate
        streams, but never the accounting."""
        sched = _sched(kind)
        rng = random.Random(seed)
        reqs = []
        for i in range(n_sessions):
            plen = rng.choice(PROMPT_LENS)
            budget = rng.randint(1, MAX_LEN - plen - 1)
            reqs.append(SessionRequest(
                f"c{seed}_{i}",
                np.asarray([rng.randrange(CFG.vocab_size)
                            for _ in range(plen)], np.int32),
                budget, arrival_s=gap_s * (i + 1),
                priority=rng.randint(0, 2)))
        size_before = sched.step_cache_size()
        if kind == "chaos":
            from repro.serving.faults import (FaultInjector,
                                              FaultPlanConfig,
                                              generate_fault_plan)
            plan = generate_fault_plan(
                FaultPlanConfig(seed=seed, n_faults=rng.randint(1, 6),
                                horizon_s=0.5),
                session_ids=[r.session_id for r in reqs])
            sched.fault_injector = FaultInjector(plan)
        for r in reqs:
            sched.submit(r)
        try:
            res = sched.run()
        finally:
            if kind == "chaos":
                # the injector and any unfired fault state must not
                # leak into the next example on the cached instance
                sched.fault_injector = None
                sched._pending_aborts.clear()
                sched._poison.clear()
                sched._pending_corrupts = 0
        # ---- drained: no slot, queue, or arrival residue
        assert sched.free_slots == list(range(sched.n_slots))
        assert not sched.waiting and not sched._pending \
            and not sched._arrivals
        assert not sched._pressure_holds, "pressure hold leaked"
        # gap 0 takes the legacy submit-straight-to-queue path, which
        # is not a timed arrival release; chaos aborts can remove
        # queued requests before release
        if kind == "chaos":
            assert res.arrivals <= len(reqs)
        else:
            assert res.arrivals == (0 if gap_s == 0.0 else len(reqs))
        for r in reqs:
            s = res.sessions[r.session_id]
            if s.status == "ok":
                assert len(res.tokens_for(r.session_id)) \
                    == r.max_new_tokens
            else:
                # terminated by the plan: prefix only, never overrun
                assert kind == "chaos"
                assert len(res.tokens_for(r.session_id)) \
                    <= r.max_new_tokens
        # ---- page accounting balances (cache holds are the only
        # allowed residue, and each cached page has exactly one holder)
        cached = sched.cached_pages or 0
        assert sched.free_pages == sched.n_pages - 1 - cached
        if sched.prefix is not None:
            for p in sched.prefix.pages():
                assert sched.allocator.refcount(p) == 1
        # ---- host pool balances: after a full drain nothing pinned
        # may linger (parked blobs are consumed or dropped on resume,
        # shadows die with their session) — only the unpinned host
        # prefix index is allowed residue
        if sched.tiered:
            hs = sched.store.host_stats()
            assert hs["parked"] == 0, "parked blobs leaked past drain"
            assert hs["shadow"] == 0, "shadow blobs leaked past drain"
            assert hs["used"] == hs["prefix"]
        # ---- compiled-program stability after warmup
        size_after = sched.step_cache_size()
        bound = len(sched.k_ladder) if kind == "adaptive" else 1
        assert size_after <= bound
        if size_before == bound:
            assert size_after == size_before, \
                "steady-state churn recompiled the decode step"

    def test_soak_schedulers_saw_every_config(self):
        """Meta-check: the sampled_from draws covered each scheduler
        kind (the shim's edge-first ordering guarantees this; real
        hypothesis covers it within the example budget)."""
        for kind in ("paged", "prefix", "adaptive", "tiered", "chaos"):
            _sched(kind)
            assert kind in _STATE
