"""Prefix sharing with copy-on-write KV pages over the paged block table.

The contract mirrors the paged cache's own: sharing physical pages
across sessions is a pure MEMORY change — greedy streams are
token-identical to the no-sharing baseline through partial matches,
fully-cached prompts (the CoW replay), chunked prefill, horizon-K
macro-ticks, oversubscription, preemption, and resume — shared pages
are never written (the poisoned-page guard reads them back bit-equal),
and every allocator reference balances: after the sessions drain and
the cache is flushed, the free list is back to its initial state.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model
from repro.serving import (BlockAllocator, DecodeEngine, PrefixCache,
                           SessionRequest, SlotScheduler)

KEY = jax.random.PRNGKey(11)
CFG = get_config("qwen2.5-3b").reduced()
# f32 keeps the CoW replay well-conditioned: the replayed token's logits
# come from the decode path while the baseline's come from prefill —
# identical math, and f32 keeps the greedy argmax far from bf16 ties
# (same rationale as table10/table12)
CFG_F32 = CFG.replace(dtype="float32")


def _engine(cfg=CFG, **kw):
    m = Model(cfg, **kw)
    return DecodeEngine(m, m.init(KEY))


def _fleet(cfg, n, *, page=8, shared_pages=2, base_new=4, dups=0):
    """n sessions sharing a ``shared_pages``-page preamble with distinct
    tails, plus ``dups`` exact page-aligned duplicates (CoW case)."""
    preamble = np.asarray(jax.random.randint(
        KEY, (shared_pages * page,), 0, cfg.vocab_size))
    reqs = []
    for i in range(n):
        k = jax.random.fold_in(KEY, 100 + i)
        tail = np.asarray(jax.random.randint(k, (3 + i,), 0,
                                             cfg.vocab_size))
        reqs.append(SessionRequest(
            f"s{i}", np.concatenate([preamble, tail]), base_new + i % 3))
    for i in range(dups):
        reqs.append(SessionRequest(f"dup{i}", preamble, base_new))
    return reqs


def _assert_identical(reqs, ref, res, what):
    for r in reqs:
        np.testing.assert_array_equal(
            ref.tokens_for(r.session_id), res.tokens_for(r.session_id),
            err_msg=f"{r.session_id} diverged: {what}")


class TestBlockAllocatorRefcounts:
    def test_alloc_retain_release_lifecycle(self):
        a = BlockAllocator(6)
        got = a.alloc(2)
        assert [a.refcount(p) for p in got] == [1, 1]
        a.retain(got)                      # second holder (sharer)
        a.release(got)                     # sharer drops
        assert a.n_free == 3               # still held by the owner
        assert [a.refcount(p) for p in got] == [1, 1]
        a.release(got)                     # owner drops -> freed
        assert a.n_free == 5
        assert [a.refcount(p) for p in got] == [0, 0]

    def test_release_past_zero_rejected(self):
        a = BlockAllocator(4)
        (p,) = a.alloc(1)
        a.release([p])
        with pytest.raises(AssertionError):
            a.release([p])

    def test_retain_of_free_page_rejected(self):
        a = BlockAllocator(4)
        with pytest.raises(AssertionError):
            a.retain([2])

    def test_free_membership_is_set_backed(self):
        """The double-free check must not scan the free list (it used to
        be O(free) per page — quadratic reclaim on big pools)."""
        a = BlockAllocator(5000)
        got = a.alloc(4000)
        a.release(got)                     # fast only if set-backed
        assert a.n_free == 4999
        with pytest.raises(AssertionError):
            a.release([got[0]])


class TestPrefixCacheUnit:
    def _tokens(self, n, seed=0):
        return np.asarray(jax.random.randint(
            jax.random.fold_in(KEY, seed), (n,), 0, 997))

    def test_match_walks_longest_chain(self):
        a = BlockAllocator(10)
        c = PrefixCache(a)
        toks = self._tokens(32)
        pages = a.alloc(4)
        c.register(toks, 8, pages, 4)
        assert c.match(toks, 8) == pages
        assert c.match(toks[:20], 8) == pages[:2]   # page-aligned prefix
        assert c.match(self._tokens(32, seed=1), 8) == []
        # diverging block 2 matches only the common front
        mixed = np.concatenate([toks[:16], self._tokens(16, seed=2)])
        assert c.match(mixed, 8) == pages[:2]

    def test_register_keeps_incumbent_on_duplicate(self):
        a = BlockAllocator(10)
        c = PrefixCache(a)
        toks = self._tokens(16)
        first, second = a.alloc(2), a.alloc(2)
        c.register(toks, 8, first, 2)
        c.register(toks, 8, second, 2)      # concurrent duplicate prefill
        assert c.match(toks, 8) == first
        assert a.refcount(second[0]) == 1   # dup pages gained no cache ref

    def test_reclaim_is_leaf_first_lru(self):
        a = BlockAllocator(10)
        c = PrefixCache(a)
        t1, t2 = self._tokens(16, 1), self._tokens(16, 2)
        p1, p2 = a.alloc(2), a.alloc(2)
        c.register(t1, 8, p1, 2)
        c.register(t2, 8, p2, 2)
        a.release(p1)
        a.release(p2)                       # both chains cache-only now
        c.match(t1, 8)                      # refresh chain 1 -> 2 is LRU
        assert c.reclaim(1) == 1
        assert c.match(t2, 8) == p2[:1]     # chain 2 lost its leaf
        assert c.match(t1, 8) == p1

    def test_parent_pinned_while_child_cached(self):
        """A chain's root page can only leave after its leaf did — the
        leaf's content is reachable only through the parent's chain."""
        a = BlockAllocator(10)
        c = PrefixCache(a)
        toks = self._tokens(24)
        pages = a.alloc(3)
        c.register(toks, 8, pages, 3)
        a.release(pages)
        assert c.reclaimable() == 3
        c.reclaim(1)
        assert c.match(toks, 8) == pages[:2]    # leaf went first
        assert c.flush() == 2
        assert a.n_free == 9

    def test_referenced_pages_survive_flush(self):
        a = BlockAllocator(10)
        c = PrefixCache(a)
        toks = self._tokens(16)
        pages = a.alloc(2)
        c.register(toks, 8, pages, 2)       # owner + cache hold them
        assert c.flush() == 0               # owner still holds -> pinned
        a.release(pages)
        assert c.flush() == 2
        assert a.n_free == 9


class TestPrefixSharingIdentity:
    def test_partial_match_token_identity(self):
        """Shared preamble + distinct tails: matched pages are aliased,
        only tails prefill, streams match the no-sharing baseline."""
        eng = _engine()
        reqs = _fleet(CFG, 6)
        ref = eng.generate_continuous(reqs, n_slots=3, max_len=40,
                                      paged=True, page_size=8)
        res = eng.generate_continuous(reqs, n_slots=3, max_len=40,
                                      paged=True, page_size=8,
                                      prefix_cache=True)
        assert res.step_cache_size == 1
        assert res.prefix_hits == 5          # all but the cold first
        assert res.cow_copies == 0           # tails keep writes private
        assert res.prefill_tokens < ref.prefill_tokens
        assert res.prefix_tokens_saved == 5 * 16
        _assert_identical(reqs, ref, res, "partial match")

    def test_fork_duplicated_prompts_cow(self):
        """Fork: page-aligned duplicates of a served prompt skip prefill
        entirely; the replayed last token's write CoW-faults the last
        shared page.  Unfork: streams equal the no-sharing baseline."""
        eng = _engine(CFG_F32)
        reqs = _fleet(CFG_F32, 2, dups=2)
        ref = eng.generate_continuous(reqs, n_slots=2, max_len=40,
                                      paged=True, page_size=8)
        res = eng.generate_continuous(reqs, n_slots=2, max_len=40,
                                      paged=True, page_size=8,
                                      prefix_cache=True)
        assert res.cow_copies == 2
        assert res.prefix_hits >= 3
        _assert_identical(reqs, ref, res, "fork/unfork")

    def test_pallas_route_token_identity(self):
        cfg = CFG.replace(vocab_size=256, d_model=96, d_ff=192,
                          n_layers=2, n_heads=4, n_kv_heads=2,
                          head_dim=16, dtype="float32")
        eng = _engine(cfg, decode_backend="pallas")
        reqs = _fleet(cfg, 3, dups=1)
        ref = eng.generate_continuous(reqs, n_slots=2, max_len=40,
                                      paged=True, page_size=8)
        res = eng.generate_continuous(reqs, n_slots=2, max_len=40,
                                      paged=True, page_size=8,
                                      prefix_cache=True)
        assert res.cow_copies >= 1
        _assert_identical(reqs, ref, res, "pallas route")

    def test_chunked_prefill_tail_alignment(self):
        """Matched boundary + chunked tail prefill: start positions stay
        page-aligned and the streams are unchanged."""
        eng = _engine()
        reqs = _fleet(CFG, 5)
        ref = eng.generate_continuous(reqs, n_slots=3, max_len=40,
                                      paged=True, page_size=4)
        res = eng.generate_continuous(reqs, n_slots=3, max_len=40,
                                      paged=True, page_size=4,
                                      prefill_chunk=8, prefix_cache=True)
        assert res.prefix_hits >= 4
        _assert_identical(reqs, ref, res, "chunked tail")

    def test_horizon_k_token_identity(self):
        """Sharing under horizon-K fused macro-ticks: the lookahead
        reservation must stay token-identical with aliased pages."""
        eng = _engine(CFG_F32)
        reqs = _fleet(CFG_F32, 5, dups=1)
        ref = eng.generate_continuous(reqs, n_slots=3, max_len=40,
                                      paged=True, page_size=8)
        res = eng.generate_continuous(reqs, n_slots=3, max_len=40,
                                      paged=True, page_size=8,
                                      prefix_cache=True, steps_per_tick=4)
        assert res.step_cache_size == 1
        assert res.cow_copies >= 1
        _assert_identical(reqs, ref, res, "horizon K=4")


class TestSharedPagesNeverWritten:
    def test_poisoned_page_guard(self):
        """Snapshot every cached page after the first wave; a second
        wave that shares them (incl. the CoW replay) must leave their
        K/V bit-unchanged — decode and prefill writes always land in
        private pages."""
        eng = _engine(CFG_F32)
        reqs = _fleet(CFG_F32, 3, dups=1)
        sched = SlotScheduler(eng.model, eng.params, n_slots=2,
                              max_len=40, paged=True, page_size=8,
                              prefix_cache=True)
        for r in reqs:
            sched.submit(r)
        sched.run()
        cached = sched.prefix.pages()
        assert cached, "first wave registered nothing"
        k0 = np.asarray(sched.cache["k"][:, cached], np.float32)
        v0 = np.asarray(sched.cache["v"][:, cached], np.float32)
        import dataclasses
        for r in reqs:                      # second wave: every prompt hits
            sched.submit(dataclasses.replace(r, session_id="w2" + r.session_id))
        res = sched.run()
        assert res.prefix_hits == len(reqs)
        assert res.cow_copies >= 1          # the dup replayed through CoW
        np.testing.assert_array_equal(
            k0, np.asarray(sched.cache["k"][:, cached], np.float32),
            err_msg="a shared K page was written")
        np.testing.assert_array_equal(
            v0, np.asarray(sched.cache["v"][:, cached], np.float32),
            err_msg="a shared V page was written")


class TestRefcountBalance:
    def _drain_and_check(self, sched, reqs, ref, what):
        for r in reqs:
            sched.submit(r)
        res = sched.run()
        _assert_identical(reqs, ref, res, what)
        assert sched.free_slots == list(range(sched.n_slots))
        sched.flush_prefix_cache()
        assert sched.free_pages == sched.n_pages - 1, \
            f"free list unbalanced after {what}"
        assert all(sched.allocator.refcount(p) == 0
                   for p in range(1, sched.n_pages)), \
            f"leaked refcounts after {what}"
        return res

    def test_balance_through_eviction(self):
        eng = _engine()
        reqs = _fleet(CFG, 6)
        ref = eng.generate_continuous(reqs, n_slots=3, max_len=40)
        sched = SlotScheduler(eng.model, eng.params, n_slots=3,
                              max_len=40, paged=True, page_size=8,
                              prefix_cache=True)
        self._drain_and_check(sched, reqs, ref, "eviction churn")

    def test_balance_through_oversubscription_and_reclaim(self):
        """An oversubscribed pool forces the LRU reclaim to eat cached
        pages mid-run; identity and the final balance must survive."""
        eng = _engine()
        reqs = _fleet(CFG, 6)
        ref = eng.generate_continuous(reqs, n_slots=3, max_len=40)
        sched = SlotScheduler(eng.model, eng.params, n_slots=3,
                              max_len=40, paged=True, page_size=8,
                              n_pages=9, prefix_cache=True)
        self._drain_and_check(sched, reqs, ref, "oversubscribed")

    def test_balance_through_preemption(self):
        """Preempted sessions release shared refs, then re-match their
        own cached prefix on resume (re-prefill skipped for the match)."""
        eng = _engine()
        reqs = [SessionRequest("a", np.arange(8) % CFG.vocab_size, 20),
                SessionRequest("b", np.arange(8) % CFG.vocab_size, 20)]
        ref = eng.generate_continuous(reqs, n_slots=2, max_len=40)
        sched = SlotScheduler(eng.model, eng.params, n_slots=2,
                              max_len=40, paged=True, page_size=4,
                              n_pages=1 + 9, prefix_cache=True)
        res = self._drain_and_check(sched, reqs, ref, "preemption")
        assert res.preemptions > 0, "pool was sized to force preemption"
        assert res.prefix_hits > 0, "resume never re-matched its prefix"

    def test_balance_through_horizon_trims(self):
        """EOS/budget trims mid-horizon reclaim lookahead pages; with
        sharing in play the refcounts must still zero out."""
        eng = _engine(CFG_F32)
        reqs = _fleet(CFG_F32, 5, dups=1, base_new=6)
        ref = eng.generate_continuous(reqs, n_slots=2, max_len=40)
        sched = SlotScheduler(eng.model, eng.params, n_slots=2,
                              max_len=40, paged=True, page_size=4,
                              n_pages=1 + 12, prefix_cache=True,
                              steps_per_tick=4)
        self._drain_and_check(sched, reqs, ref, "horizon trims")


class TestSchedulerInvariants:
    def test_prefix_cache_requires_paged(self):
        eng = _engine()
        with pytest.raises(NotImplementedError):
            SlotScheduler(eng.model, eng.params, n_slots=2, max_len=32,
                          prefix_cache=True)

    def test_lru_reclaim_under_pressure(self):
        """A second wave of UNRELATED prompts must be able to evict the
        first wave's cached prefix instead of deadlocking on the gate."""
        eng = _engine()
        wave1 = _fleet(CFG, 3)
        sched = SlotScheduler(eng.model, eng.params, n_slots=2,
                              max_len=40, paged=True, page_size=8,
                              n_pages=11, prefix_cache=True)
        for r in wave1:
            sched.submit(r)
        sched.run()
        assert sched.cached_pages > 0
        wave2 = [SessionRequest(f"u{i}", np.asarray(jax.random.randint(
            jax.random.fold_in(KEY, 900 + i), (24,), 0, CFG.vocab_size)), 3)
            for i in range(3)]
        ref = eng.generate_continuous(wave2, n_slots=2, max_len=40)
        for r in wave2:
            sched.submit(r)
        res = sched.run()
        _assert_identical(wave2, ref, res, "post-reclaim wave")
        assert res.prefix_hits == 0          # nothing matched, only evicted

    def test_fully_cached_admission_in_exhausted_pool(self):
        """Regression: when the ONLY reclaimable pages are the matched
        chain itself, the gate must not pin them all and deadlock — the
        CoW copy may legally consume the last matched page, and failing
        that the match shrinks until the admission fits (degrading to
        the unshared gate's liveness)."""
        eng = _engine(CFG_F32)
        prompt = np.asarray(jax.random.randint(KEY, (16,), 0,
                                               CFG_F32.vocab_size))
        sched = SlotScheduler(eng.model, eng.params, n_slots=1,
                              max_len=24, paged=True, page_size=8,
                              n_pages=3, prefix_cache=True)
        sched.submit(SessionRequest("a", prompt, 1))
        sched.run()                  # both prompt pages now cache-held
        assert sched.free_pages == 0 and sched.cached_pages == 2
        sched.submit(SessionRequest("b", prompt, 1))
        res = sched.run()            # must not RuntimeError on the gate
        np.testing.assert_array_equal(res.tokens_for("a"),
                                      res.tokens_for("b"))
        sched.flush_prefix_cache()
        assert sched.free_pages == 2

    def test_compiled_once_through_sharing_churn(self):
        eng = _engine()
        sched = SlotScheduler(eng.model, eng.params, n_slots=2,
                              max_len=40, paged=True, page_size=8,
                              prefix_cache=True)
        for r in _fleet(CFG, 4, dups=1):
            sched.submit(r)
        sched.run()
        assert sched.step_cache_size() == 1
        import dataclasses
        for r in _fleet(CFG, 4, dups=1):
            sched.submit(dataclasses.replace(r, session_id="w2" + r.session_id))
        sched.run()
        assert sched.step_cache_size() == 1

    def test_event_log_replay_with_sharing(self):
        eng = _engine(CFG_F32)
        sched = SlotScheduler(eng.model, eng.params, n_slots=2,
                              max_len=40, paged=True, page_size=8,
                              prefix_cache=True)
        reqs = _fleet(CFG_F32, 3, dups=2)
        for r in reqs:
            sched.submit(r)
        res = sched.run()
        occupancy = {}
        for ev in res.events:
            kind, sid, slot = ev[0], ev[1], ev[2]
            if kind == "admit":
                assert slot not in occupancy
                occupancy[slot] = sid
            elif kind in ("finish", "preempt"):
                assert occupancy.pop(slot) == sid
        assert not occupancy
        assert len(res.sessions) == len(reqs)


class TestCopyKvPage:
    def test_copies_all_layers_both_tensors(self):
        m = Model(CFG)
        cache = m.init_cache(2, 32, paged=True, page_size=8)
        cache["k"] = cache["k"].at[:, 3].set(1.5)
        cache["v"] = cache["v"].at[:, 3].set(-2.5)
        out = m.copy_kv_page(cache, jnp.int32(3), jnp.int32(5))
        assert np.all(np.asarray(out["k"][:, 5], np.float32) == 1.5)
        assert np.all(np.asarray(out["v"][:, 5], np.float32) == -2.5)
        # source and unrelated pages untouched
        assert np.all(np.asarray(out["k"][:, 3], np.float32) == 1.5)
        assert np.all(np.asarray(out["k"][:, 4], np.float32) == 0)

    def test_rejects_contiguous_cache(self):
        m = Model(CFG)
        cache = m.init_cache(2, 32, slotted=True)
        with pytest.raises(AssertionError):
            m.copy_kv_page(cache, 1, 2)
