"""Fused Pallas paged-decode attention kernel
(kernels/paged_decode_attention): the block-table-aware flash-decoding
sweep that replaces the paged path's ``paged_view`` gather.

Two contracts:
  * kernel-level — matches the gather+SDPA oracle (ref.py) for every
    table10 page size, partial last pages, garbage-sentinel block-table
    entries, and free (length-0) lanes;
  * serving-level — with ``decode_backend="pallas"`` the paged scheduler
    emits greedy streams token-identical to the gather+SDPA reference
    across full backing, chunked prefill, and an oversubscribed pool
    with preemption, still compiled exactly once through churn.

Identity runs in f32: the bf16 SDPA rounds probabilities to bf16 before
the PV dot (backend-specific rounding), while the kernel accumulates in
f32 — at f32 both routes compute the same real-valued function at the
same precision (see benchmarks/table10_paged_kv.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.paged_decode_attention.ops import (paged_decode_attention,
                                                      serving_traffic_bytes,
                                                      traffic_bytes)
from repro.kernels.paged_decode_attention.ref import paged_decode_attention_ref
from repro.models import Model
from repro.serving import DecodeEngine, SessionRequest, SlotScheduler

KEY = jax.random.PRNGKey(23)
CFG = get_config("qwen2.5-3b").reduced().replace(dtype="float32")

# table10's PAGE_SIZES (benchmarks/table10_paged_kv.py) — kept literal
# so the tier-1 suite doesn't import the benchmarks package
TABLE10_PAGE_SIZES = (4, 8, 16)


def _rand_pool(key, n_pages, page, Hkv, hd, dtype=jnp.float32):
    ks = jax.random.split(key, 2)
    k_pool = jax.random.normal(ks[0], (n_pages, page, Hkv, hd), dtype)
    v_pool = jax.random.normal(ks[1], (n_pages, page, Hkv, hd), dtype)
    return k_pool, v_pool


class TestKernelVsRef:
    @pytest.mark.parametrize("page", TABLE10_PAGE_SIZES)
    def test_matches_gather_ref_all_table10_page_sizes(self, page):
        B, Hq, Hkv, hd, max_blocks = 3, 8, 2, 64, 4
        n_pages = 1 + B * max_blocks
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, Hq, hd), jnp.float32)
        k_pool, v_pool = _rand_pool(ks[1], n_pages, page, Hkv, hd)
        bt = jnp.asarray(
            np.random.RandomState(page).permutation(
                np.arange(1, n_pages))[:B * max_blocks]
            .reshape(B, max_blocks), jnp.int32)
        lengths = jnp.asarray([max_blocks * page,        # full allocation
                               2 * page + page // 2,     # partial last page
                               1], jnp.int32)
        out = paged_decode_attention(q, k_pool, v_pool, bt, lengths)
        ref = paged_decode_attention_ref(q, k_pool, v_pool, bt, lengths)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref), atol=1e-5, rtol=1e-5)

    def test_partial_last_page_every_offset(self):
        """Sweep the live length across a page boundary: every partial
        fill of the last page masks exactly the right tail."""
        B, Hq, Hkv, hd, page, max_blocks = 1, 4, 2, 32, 8, 2
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, Hq, hd), jnp.float32)
        k_pool, v_pool = _rand_pool(ks[1], 3, page, Hkv, hd)
        bt = jnp.asarray([[2, 1]], jnp.int32)
        for length in range(1, max_blocks * page + 1):
            lengths = jnp.asarray([length], jnp.int32)
            out = paged_decode_attention(q, k_pool, v_pool, bt, lengths)
            ref = paged_decode_attention_ref(q, k_pool, v_pool, bt, lengths)
            np.testing.assert_allclose(np.asarray(out, np.float32),
                                       np.asarray(ref), atol=1e-5,
                                       err_msg=f"length={length}")

    def test_garbage_sentinel_blocks_never_read(self):
        """Blocks past a slot's allocation park on sentinel page 0.  The
        kernel must skip them entirely: poisoning page 0 with huge junk
        cannot change any lane whose live length stays within its real
        pages."""
        B, Hq, Hkv, hd, page, max_blocks = 2, 4, 2, 32, 4, 4
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, Hq, hd), jnp.float32)
        k_pool, v_pool = _rand_pool(ks[1], 6, page, Hkv, hd)
        bt = jnp.asarray([[3, 5, 0, 0],        # 2 real pages, 2 sentinel
                          [1, 2, 4, 0]], jnp.int32)
        lengths = jnp.asarray([2 * page, 3 * page - 1], jnp.int32)
        clean = paged_decode_attention(q, k_pool, v_pool, bt, lengths)
        poison = 1e9
        k_pool = k_pool.at[0].set(poison)
        v_pool = v_pool.at[0].set(poison)
        out = paged_decode_attention(q, k_pool, v_pool, bt, lengths)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(clean))

    def test_free_lane_returns_zeros(self):
        B, Hq, Hkv, hd, page = 2, 4, 2, 32, 8
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, Hq, hd), jnp.float32)
        k_pool, v_pool = _rand_pool(ks[1], 3, page, Hkv, hd)
        bt = jnp.asarray([[1, 2], [0, 0]], jnp.int32)
        out = paged_decode_attention(q, k_pool, v_pool, bt,
                                     jnp.asarray([page, 0], jnp.int32))
        assert bool(jnp.all(out[1] == 0))
        assert bool(jnp.all(jnp.isfinite(out[0])))

    def test_bf16_pool_close_to_ref(self):
        """The serving dtype: bf16 pool, f32 accumulation — close to the
        f32 oracle at bf16-grade tolerance."""
        B, Hq, Hkv, hd, page = 2, 8, 2, 64, 8
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (B, Hq, hd), jnp.bfloat16)
        k_pool, v_pool = _rand_pool(ks[1], 5, page, Hkv, hd, jnp.bfloat16)
        bt = jnp.asarray([[1, 2], [3, 4]], jnp.int32)
        lengths = jnp.asarray([2 * page, page + 3], jnp.int32)
        out = paged_decode_attention(q, k_pool, v_pool, bt, lengths)
        ref = paged_decode_attention_ref(q, k_pool, v_pool, bt, lengths)
        np.testing.assert_allclose(np.asarray(out, np.float32),
                                   np.asarray(ref), atol=0.03, rtol=0.03)

    def test_traffic_accounting_shows_gather_elimination(self):
        tb = traffic_bytes(6, 8, 2, 64, n_slots=4, max_blocks=4,
                           n_layers=3, kv_bytes=2)
        kv = 2 * 2 * 64 * 2
        assert tb["fused"] == 3 * 6 * 8 * kv
        assert tb["gather_sdpa"] == 3 * 3 * (4 * 4 * 8) * kv
        assert tb["fused"] < tb["gather_sdpa"]

    def test_serving_traffic_derives_kv_bytes_from_dtype(self):
        """The paged cache stores KV at the model dtype: an f32 model
        moves 2x the bytes of a bf16 model for the same block trace."""
        kw = dict(page_size=8, n_slots=4, max_blocks=4)
        f32 = serving_traffic_bytes([6, 6], CFG, **kw)
        bf16 = serving_traffic_bytes([6, 6],
                                     CFG.replace(dtype="bfloat16"), **kw)
        assert f32["fused"] == 2 * bf16["fused"]
        assert f32["gather_sdpa"] == 2 * bf16["gather_sdpa"]


def _requests(n, cfg=CFG, base_len=4, base_new=3):
    reqs = []
    for i in range(n):
        k = jax.random.fold_in(KEY, 100 + i)
        prompt = np.asarray(
            jax.random.randint(k, (base_len + 2 * i,), 0, cfg.vocab_size))
        reqs.append(SessionRequest(f"s{i}", prompt, base_new + i % 4))
    return reqs


class TestServingTokenIdentity:
    """Fused kernel vs gather+SDPA through the full paged scheduler."""

    @classmethod
    def setup_class(cls):
        cls.params = Model(CFG).init(KEY)
        cls.gather = DecodeEngine(Model(CFG), cls.params)
        cls.fused = DecodeEngine(Model(CFG, decode_backend="pallas"),
                                 cls.params)

    def _assert_identical(self, reqs, **kw):
        ref = self.gather.generate_continuous(reqs, **kw)
        res = self.fused.generate_continuous(reqs, **kw)
        assert res.step_cache_size == 1
        for r in reqs:
            np.testing.assert_array_equal(
                ref.tokens_for(r.session_id), res.tokens_for(r.session_id),
                err_msg=f"{r.session_id} diverged fused-vs-gather")
        return ref, res

    @pytest.mark.parametrize("page", TABLE10_PAGE_SIZES)
    def test_full_backing_identity_all_table10_page_sizes(self, page):
        self._assert_identical(_requests(4), n_slots=3, max_len=32,
                               paged=True, page_size=page)

    def test_oversubscribed_pool_after_preemption(self):
        """Decode outgrows the pool -> youngest preempted, re-prefilled;
        the fused route must track the gather route through the whole
        preempt/requeue/re-admit cycle."""
        reqs = [SessionRequest("a", np.arange(4) % CFG.vocab_size, 20),
                SessionRequest("b", np.arange(5) % CFG.vocab_size, 20)]
        ref, res = self._assert_identical(reqs, n_slots=2, max_len=32,
                                          paged=True, page_size=4,
                                          n_pages=1 + 7)
        assert res.preemptions > 0, "pool was sized to force preemption"
        assert res.preemptions == ref.preemptions

    def test_chunked_prefill_identity(self):
        self._assert_identical(_requests(4), n_slots=2, max_len=32,
                               paged=True, page_size=4, prefill_chunk=4)

    def test_step_kv_blocks_traced_and_below_virtual(self):
        """The scheduler's per-step live-block trace (what the fused
        kernel walks) stays below the constant virtual view the gather
        route materialises."""
        res = self.fused.generate_continuous(
            _requests(4), n_slots=3, max_len=32, paged=True, page_size=8)
        assert res.step_kv_blocks and len(res.step_kv_blocks) == \
            res.decode_steps
        virtual_blocks = 3 * (-(-32 // 8))
        assert max(res.step_kv_blocks) <= virtual_blocks
        assert min(res.step_kv_blocks) >= 1

    def test_compiled_once_through_churn(self):
        """StepProgram-style guard: two admission waves through one
        fused-backend paged scheduler — exhaustion, reclaim, backfill —
        and still exactly ONE compiled decode step (page residency and
        block tables are pure data)."""
        sched = SlotScheduler(self.fused.model, self.params, n_slots=2,
                              max_len=32, paged=True, page_size=8,
                              n_pages=5)
        for r in _requests(4):
            sched.submit(r)
        sched.run()
        assert sched.step_cache_size() == 1
        for r in _requests(3, base_len=5, base_new=4):
            sched.submit(SessionRequest(r.session_id + "w2", r.prompt,
                                        r.max_new_tokens))
        sched.run()
        assert sched.step_cache_size() == 1
        assert sched.free_pages == 4
