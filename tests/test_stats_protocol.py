"""Measurement-protocol machinery (paper §3.1/§5/App D) + hypothesis."""
import json

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax.numpy as jnp

from benchmarks.common import _parse_fields, emit, take_results
from repro.core import stats
from repro.core.protocol import measure_cell, run_ab


class TestStats:
    def test_p50(self):
        assert stats.p50([1, 2, 3, 4, 100]) == 3

    def test_cv(self):
        assert stats.cv([10.0, 10.0, 10.0]) == 0.0

    @given(st.lists(st.floats(0.1, 100), min_size=5, max_size=50))
    @settings(max_examples=30, deadline=None)
    def test_bootstrap_ci_contains_mean_mostly(self, xs):
        lo, hi = stats.bootstrap_ci_mean(xs, n_resamples=500, seed=1)
        assert lo <= np.mean(xs) + 1e-9
        assert hi >= np.mean(xs) - 1e-9

    def test_bootstrap_ci_deterministic_in_seed(self):
        xs = [1.0, 2.0, 3.0, 4.0]
        assert (stats.bootstrap_ci_mean(xs, seed=3)
                == stats.bootstrap_ci_mean(xs, seed=3))

    def test_bootstrap_ci_empty_sample(self):
        """An empty sample used to raise ValueError out of
        ``rng.integers(0, 0)``; it has no mean, so the CI is nan."""
        lo, hi = stats.bootstrap_ci_mean([])
        assert np.isnan(lo) and np.isnan(hi)

    def test_bootstrap_ci_singleton_is_the_point(self):
        """Quick benchmark runs with 1 repeat: the bootstrap
        distribution of a singleton is the point itself."""
        assert stats.bootstrap_ci_mean([7.25]) == (7.25, 7.25)

    def test_paired_speedups(self):
        sp = stats.paired_speedups([2.0, 4.0], [1.0, 2.0])
        assert np.allclose(sp, [2.0, 2.0])

    def test_paper_table2_statistics(self):
        """Feed the paper's own N=10 session data through our machinery
        and reproduce its summary row (mean 1.259, CI [1.253, 1.267])."""
        eager = [14.749, 14.721, 14.776, 14.896, 14.800,
                 14.869, 14.847, 15.147, 14.667, 14.812]
        graphed = [11.850, 11.764, 11.770, 11.784, 11.766,
                   11.760, 11.775, 11.763, 11.755, 11.775]
        sp = stats.paired_speedups(eager, graphed)
        assert stats.mean(sp) == pytest.approx(1.259, abs=0.001)
        assert stats.mean(eager) == pytest.approx(14.828, abs=0.002)
        assert stats.cv(eager) == pytest.approx(0.009, abs=0.002)
        assert stats.cv(graphed) == pytest.approx(0.002, abs=0.001)
        lo, hi = stats.bootstrap_ci_mean(sp, seed=0)
        assert lo == pytest.approx(1.253, abs=0.003)
        assert hi == pytest.approx(1.267, abs=0.003)


class TestBenchFieldParsing:
    """The k=v derived-column protocol behind ``run.py --json``."""

    def test_scientific_and_negative_floats(self):
        f = _parse_fields("p99=1.2e-03 dt=-4.5 big=3E+6 frac=.25 n=7")
        assert f == {"p99": 1.2e-03, "dt": -4.5, "big": 3e6,
                     "frac": 0.25, "n": 7.0}

    def test_non_numeric_values_stay_strings(self):
        """``float()`` would happily parse these — the strict matcher
        must not, or NaN/Inf poison the JSON dump and underscore typos
        silently become numbers."""
        f = _parse_fields("a=nan b=inf c=-inf d=1_2 e=1e f=--3 g=ok")
        assert f == {"a": "nan", "b": "inf", "c": "-inf", "d": "1_2",
                     "e": "1e", "f": "--3", "g": "ok"}

    def test_booleans_and_nonpairs(self):
        f = _parse_fields("ok=True bad=False stray k=v=w")
        assert f == {"ok": True, "bad": False, "k": "v=w"}

    def test_round_trip_through_results_registry(self):
        """An emitted row with a scientific-notation latency must come
        back out of the registry as the same float, and the whole record
        must survive a strict (allow_nan=False) JSON dump."""
        take_results()                       # drop other tests' rows
        emit("t/row", 12.5, "p99=1.2e-03 nanlike=nan flag=True")
        rows = take_results()
        assert len(rows) == 1
        dumped = json.dumps(rows, allow_nan=False)
        back = json.loads(dumped)[0]
        assert back["fields"]["p99"] == 1.2e-03
        assert back["fields"]["nanlike"] == "nan"
        assert back["fields"]["flag"] is True


class TestProtocol:
    def test_measure_cell_window(self):
        calls = {"n": 0}

        def step():
            calls["n"] += 1
            return jnp.zeros(())
        res = measure_cell(step, warmup=2, steps=5, name="t")
        assert calls["n"] == 7
        assert len(res.step_times_s) == 5
        assert res.p50_ms >= 0

    def test_run_ab_paired(self):
        def mk_slow(s):
            def f():
                return jnp.ones(200_000).sum()   # more work
            return f

        def mk_fast(s):
            def f():
                return jnp.ones(16).sum()
            return f
        ab = run_ab(mk_slow, mk_fast, n_sessions=2, warmup=1, steps=5,
                    fresh_session=False)
        summary = ab.summary()
        assert summary["n_sessions"] == 2
        assert len(summary["per_session"]) == 2
        assert summary["speedup_ci95"][0] <= summary["mean_speedup"] \
            <= summary["speedup_ci95"][1]
