"""Int8 KV-cache quantisation (repro.quant.kv): numerics + plumbing."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.models import Model
from repro.quant.kv import dequantize_kv, quantize_kv_write

KEY = jax.random.PRNGKey(21)


@given(st.integers(1, 4), st.integers(8, 64))
@settings(max_examples=20, deadline=None)
def test_roundtrip_error_bounded(b, hd):
    x = jax.random.normal(jax.random.fold_in(KEY, b * hd), (b, 3, hd),
                          jnp.bfloat16) * 3
    q, s = quantize_kv_write(x)
    back = dequantize_kv(q, s, jnp.float32)
    # per-vector max-abs scaling: error <= scale/2 (+bf16 noise)
    bound = np.asarray(s)[..., None] * 0.55 + 0.02
    assert np.all(np.abs(np.asarray(back) - np.asarray(x, np.float32)) <= bound)


def test_scales_shape():
    x = jnp.ones((2, 5, 4, 16), jnp.bfloat16)
    q, s = quantize_kv_write(x)
    assert q.shape == x.shape and q.dtype == jnp.int8
    assert s.shape == (2, 5, 4) and s.dtype == jnp.float32


@pytest.mark.parametrize("arch", ["qwen2.5-3b", "qwen2-moe-a2.7b",
                                  "musicgen-large"])
def test_int8_cache_decode_close_to_bf16(arch):
    cfg = get_config(arch).reduced()
    if cfg.family == "moe":
        cfg = cfg.replace(capacity_factor=8.0)
    m = Model(cfg)
    params = m.init(KEY)
    B, S = 2, 24
    shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks else (B, S)
    tokens = jax.random.randint(KEY, shape, 0, cfg.vocab_size)
    ref, _ = m.forward(params, {"tokens": tokens})

    cache = m.init_cache(B, 48, kv_dtype=jnp.int8)
    assert "k_scale" in cache
    _, cache = jax.jit(m.prefill)(params, {"tokens": tokens[:, :S - 1]}, cache)
    ld, cache = jax.jit(m.decode_step)(params, cache, tokens[:, S - 1:])
    err = float(jnp.max(jnp.abs(ld[:, 0].astype(jnp.float32)
                                - ref[:, -1].astype(jnp.float32))))
    assert err < 0.15, err     # int8 KV noise, bounded


def test_folded_scales_equal_dequant_view():
    """sdpa (folded scales) == math backend (dequantised view): the
    algebraic rearrangement is exact up to dtype rounding."""
    cfg = get_config("qwen2.5-3b").reduced()
    params = Model(cfg).init(KEY)
    tokens = jax.random.randint(KEY, (1, 16), 0, cfg.vocab_size)
    outs = {}
    for backend in ("sdpa", "math"):
        m = Model(cfg, decode_backend=backend)
        cache = m.init_cache(1, 32, kv_dtype=jnp.int8)
        _, cache = m.prefill(params, {"tokens": tokens[:, :-1]}, cache)
        ld, _ = m.decode_step(params, cache, tokens[:, -1:])
        outs[backend] = ld.astype(jnp.float32)
    assert float(jnp.max(jnp.abs(outs["sdpa"] - outs["math"]))) < 0.05
