"""Activation hints + sharding strategies + roofline CLI robustness."""
import json

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch import hints
from repro.launch import sharding as shd


class FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 16, "model": 16}


class TestHints:
    def test_noop_without_mesh(self):
        x = jnp.ones((4, 8))
        assert hints.constrain(x, ("dp", "tp")) is x

    def test_tp_divides_requires_mesh(self):
        assert not hints.tp_divides(16)

    def test_dp_all_disables_tp(self):
        hints.enable(FakeMesh(), dp_all=True)
        try:
            assert not hints.tp_divides(16)
            assert hints._resolve("tp", FakeMesh()) is None
            assert hints._resolve("dp", FakeMesh()) == ("data", "model")
        finally:
            hints.disable()

    def test_context_manager_restores(self):
        with hints.activation_hints(None):
            pass
        assert hints._STATE["mesh"] is None


class TestStrategies:
    def test_dp_strategy_replicates_params(self):
        cfg = get_config("olmo-1b")
        plan = shd.ShardingPlan(FakeMesh(), cfg, False, {}, strategy="dp")

        class Leaf:
            shape = (16, 2048, 8192)
        kp = (type("K", (), {"key": "blocks"})(),
              type("K", (), {"key": "mlp"})(),
              type("K", (), {"key": "up"})())
        assert tuple(shd.param_spec(plan, kp, Leaf())) == (None, None, None)

    def test_dp_strategy_batch_over_all_axes(self):
        cfg = get_config("olmo-1b")
        plan = shd.ShardingPlan(FakeMesh(), cfg, False, {}, strategy="dp")
        assert plan.batch_axes == ("data", "model")

    def test_tp_strategy_default(self):
        cfg = get_config("olmo-1b")
        plan = shd.make_plan(cfg, FakeMesh())
        assert plan.strategy == "tp"
        assert plan.batch_axes == ("data",)

    def test_kv_scale_sharding_rule(self, monkeypatch):
        cfg = get_config("qwen2.5-3b")
        plan = shd.ShardingPlan(FakeMesh(), cfg, False, {})
        monkeypatch.setattr(shd.ShardingPlan, "named", lambda self, spec: spec)
        specs = shd.cache_shardings(plan, {
            "k_scale": jax.ShapeDtypeStruct((36, 128, 32768, 2), jnp.float32),
        })
        # batch over data, seq over model; heads (2) replicated
        assert tuple(specs["k_scale"]) == (None, "data", "model", None)


class TestRooflineCLI:
    def test_main_skips_non_ok_cells(self, tmp_path, capsys):
        from repro.analysis import roofline
        ok = {"arch": "a", "shape": "decode_32k", "mesh": "pod",
              "status": "ok", "n_chips": 256,
              "analytic": {"flops": 1e12, "hbm_bytes_per_chip": 1e9,
                           "model_flops": 5e11},
              "collectives": {"total_wire_bytes_per_chip": 1e6}}
        skip = {"arch": "b", "shape": "long_500k", "mesh": "pod",
                "status": "skipped", "reason": "full attention"}
        (tmp_path / "a.json").write_text(json.dumps(ok))
        (tmp_path / "b.json").write_text(json.dumps(skip))
        roofline.main(str(tmp_path), "pod")
        out = capsys.readouterr().out
        assert "| a |" in out and "b" not in out.split("\n")[2]
