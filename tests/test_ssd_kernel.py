"""SSD decode-step kernel: shape/dtype sweeps vs the jnp oracle AND vs
the model's own recurrent decode math (mamba2.mamba_decode_step)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels.ssd_update.ops import ssd_update
from repro.kernels.ssd_update.ref import ssd_update_ref

KEY = jax.random.PRNGKey(33)


@pytest.mark.parametrize("B,H,P,N,G", [
    (1, 80, 64, 128, 1),     # mamba2-2.7b decode shape
    (2, 64, 64, 64, 1),      # zamba2-1.2b decode shape
    (3, 8, 16, 32, 2),       # grouped B/C
    (1, 4, 8, 16, 4),
])
@pytest.mark.parametrize("xdtype", [jnp.bfloat16, jnp.float32])
def test_matches_ref(B, H, P, N, G, xdtype):
    ks = jax.random.split(jax.random.fold_in(KEY, B * H + P), 6)
    h = jax.random.normal(ks[0], (B, H, P, N), jnp.float32)
    x = jax.random.normal(ks[1], (B, H, P), xdtype)
    dt = jax.nn.softplus(jax.random.normal(ks[2], (B, H), jnp.float32))
    A = -jnp.exp(jax.random.normal(ks[3], (H,), jnp.float32))
    Bm = jax.random.normal(ks[4], (B, G, N), xdtype)
    Cm = jax.random.normal(ks[5], (B, G, N), xdtype)

    h_new, y = ssd_update(h, x, dt, A, Bm, Cm)

    rep = H // G
    Bv = jnp.repeat(Bm, rep, axis=1).astype(jnp.float32)
    Cv = jnp.repeat(Cm, rep, axis=1).astype(jnp.float32)
    xdt = x.astype(jnp.float32) * dt[..., None]
    h_ref, y_ref = ssd_update_ref(h, xdt, dt * A[None, :], Bv, Cv)

    np.testing.assert_allclose(np.asarray(h_new), np.asarray(h_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               rtol=1e-3, atol=1e-3)


def test_matches_model_decode_step():
    """Kernel == the SSD inner math of mamba2.mamba_decode_step
    (h' and the pre-gating y, i.e. before the +D*x skip)."""
    from repro.models import mamba2
    cfg = get_config("mamba2-2.7b").reduced()
    p = mamba2.init_mamba(KEY, cfg, jnp.float32)
    B = 2
    H, P, N, G = (cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state,
                  cfg.ssm_groups)
    ks = jax.random.split(KEY, 4)
    h = jax.random.normal(ks[0], (B, H, P, N), jnp.float32)
    xs = jax.random.normal(ks[1], (B, H, P), jnp.float32)
    Bm = jax.random.normal(ks[2], (B, G, N), jnp.float32)
    Cm = jax.random.normal(ks[3], (B, G, N), jnp.float32)
    dt = jax.nn.softplus(jnp.ones((B, H)) * 0.3 + p["dt_bias"])
    A = -jnp.exp(p["A_log"])

    h_k, y_k = ssd_update(h, xs, dt, A, Bm, Cm)

    # replicate the model's decode-step einsum path
    hg = h.reshape(B, G, H // G, P, N)
    xg = (xs * dt[..., None]).reshape(B, G, H // G, P)
    dBx = jnp.einsum("bghp,bgn->bghpn", xg, Bm)
    h_ref = hg * jnp.exp(dt * A).reshape(B, G, H // G)[..., None, None] + dBx
    y_ref = jnp.einsum("bghpn,bgn->bghp", h_ref, Cm).reshape(B, H, P)

    np.testing.assert_allclose(np.asarray(h_k),
                               np.asarray(h_ref.reshape(B, H, P, N)),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref),
                               rtol=1e-3, atol=1e-3)


def test_state_decays_to_input_term():
    """Property: with dt*A -> -inf (full decay), h' == xdt ⊗ B exactly."""
    B, H, P, N = 1, 2, 4, 8
    h = jnp.full((B, H, P, N), 100.0, jnp.float32)
    x = jnp.ones((B, H, P), jnp.float32)
    dt = jnp.full((B, H), 50.0)
    A = jnp.full((H,), -10.0)           # exp(dt*A) == 0
    Bm = jnp.ones((B, 1, N), jnp.float32) * 2
    Cm = jnp.ones((B, 1, N), jnp.float32)
    h_new, y = ssd_update(h, x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(h_new), 100.0 * 0 + 50 * 2,
                               rtol=1e-5)
    np.testing.assert_allclose(np.asarray(y), 100.0 * N, rtol=1e-5)
