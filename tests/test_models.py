"""Per-arch smoke tests (reduced configs, assignment requirement) +
prefill/decode equivalence + decode-backend agreement."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_configs
from repro.models import Model

ALL_ARCHS = list_configs()          # 10 assigned + 3 paper models
KEY = jax.random.PRNGKey(0)


def make_batch(cfg, B=2, S=32, with_labels=True, key=KEY):
    if cfg.n_codebooks:
        toks = jax.random.randint(key, (B, S, cfg.n_codebooks), 0, cfg.vocab_size)
        b = {"tokens": toks}
    elif cfg.family == "vlm":
        b = {"embeds": jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16),
             "positions": jnp.broadcast_to(
                 jnp.arange(S)[None, :, None], (B, S, 3))}
    else:
        b = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if with_labels:
        shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks else (B, S)
        b["labels"] = jax.random.randint(jax.random.fold_in(key, 9),
                                         shape, 0, cfg.vocab_size)
    return b


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_forward_shapes_and_no_nans(name):
    """Assignment: reduced config, one forward step, shapes + no NaNs."""
    cfg = get_config(name).reduced()
    m = Model(cfg)
    params = m.init(KEY)
    batch = make_batch(cfg)
    logits, aux = jax.jit(m.forward)(params, batch)
    B, S = 2, 32
    want = (B, S, cfg.n_codebooks, cfg.vocab_size) if cfg.n_codebooks \
        else (B, S, cfg.vocab_size)
    assert logits.shape == want
    assert not bool(jnp.any(jnp.isnan(logits.astype(jnp.float32))))


@pytest.mark.parametrize("name", ALL_ARCHS)
def test_smoke_train_step(name):
    """Assignment: reduced config, one train step, finite loss + grads."""
    from repro.training import AdamW, jit_train_step, make_train_step
    cfg = get_config(name).reduced()
    m = Model(cfg)
    opt = AdamW(lr=1e-3)
    params = m.init(KEY)
    state = (params, opt.init(params))
    step = jit_train_step(make_train_step(m, opt, remat="blocks"))
    state, metrics = step(state, make_batch(cfg))
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])


@pytest.mark.parametrize("name", ["qwen2.5-3b", "phi4-mini-3.8b",
                                  "qwen2-moe-a2.7b", "llama4-scout-17b-a16e",
                                  "mamba2-2.7b", "zamba2-1.2b",
                                  "musicgen-large", "olmo-1b"])
def test_prefill_decode_matches_forward(name):
    """Token-by-token decode after prefill == full causal forward."""
    cfg = get_config(name).reduced()
    if cfg.family == "moe":
        cfg = cfg.replace(capacity_factor=8.0)   # no drops -> exact match
    m = Model(cfg)
    params = m.init(KEY)
    B, S = 2, 24
    tok_shape = (B, S, cfg.n_codebooks) if cfg.n_codebooks else (B, S)
    tokens = jax.random.randint(KEY, tok_shape, 0, cfg.vocab_size)
    logits_full, _ = m.forward(params, {"tokens": tokens})

    cache = m.init_cache(B, 64)
    lp, cache = jax.jit(m.prefill)(params, {"tokens": tokens[:, :S - 2]}, cache)
    errs = [float(jnp.max(jnp.abs(
        lp[:, 0].astype(jnp.float32) - logits_full[:, S - 3].astype(jnp.float32))))]
    step = jax.jit(m.decode_step)
    for i in (S - 2, S - 1):
        tok = tokens[:, i:i + 1]
        ld, cache = step(params, cache, tok)
        errs.append(float(jnp.max(jnp.abs(
            ld[:, 0].astype(jnp.float32) - logits_full[:, i].astype(jnp.float32)))))
    assert max(errs) < 0.08, errs    # bf16 + f32-SSD accumulation noise


def test_decode_backends_agree():
    """sdpa / math / split_kv / pallas produce the same decode logits."""
    cfg = get_config("qwen2.5-3b").reduced()
    params = Model(cfg).init(KEY)
    B, S = 2, 16
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    outs = {}
    for backend in ("sdpa", "math", "split_kv", "pallas"):
        m = Model(cfg, decode_backend=backend)
        cache = m.init_cache(B, 32)
        _, cache = m.prefill(params, {"tokens": tokens[:, :-1]}, cache)
        ld, _ = m.decode_step(params, cache, tokens[:, -1:])
        outs[backend] = ld.astype(jnp.float32)
    ref = outs["sdpa"]
    for backend, o in outs.items():
        assert float(jnp.max(jnp.abs(o - ref))) < 0.05, backend


def test_sliding_window_ring_cache():
    """Hybrid ring cache (window < ctx) decode matches full forward."""
    cfg = get_config("zamba2-1.2b").reduced()   # window=64 after reduce
    assert cfg.sliding_window == 64
    m = Model(cfg)
    params = m.init(KEY)
    B, S = 1, 40
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size)
    logits_full, _ = m.forward(params, {"tokens": tokens})
    cache = m.init_cache(B, 128)   # ring: kv_len == window == 64
    assert cache["k"].shape[2] == 64
    _, cache = m.prefill(params, {"tokens": tokens[:, :S - 1]}, cache)
    ld, _ = m.decode_step(params, cache, tokens[:, S - 1:])
    err = float(jnp.max(jnp.abs(
        ld[:, 0].astype(jnp.float32) - logits_full[:, -1].astype(jnp.float32))))
    assert err < 0.08, err


def test_mamba_ssd_chunk_invariance():
    """SSD output must not depend on the chunk size (algebraic identity)."""
    from repro.models import mamba2
    cfg = get_config("mamba2-2.7b").reduced()
    p = mamba2.init_mamba(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 64, cfg.d_model), jnp.float32)
    outs = []
    for chunk in (8, 16, 64):
        y, h, _ = mamba2.mamba_forward(p, x, cfg, chunk=chunk)
        outs.append((y, h))
    for y, h in outs[1:]:
        assert jnp.allclose(y, outs[0][0], atol=1e-4)
        assert jnp.allclose(h, outs[0][1], atol=1e-4)


def test_chunked_attention_matches_dense():
    """The q-block-chunked long-context path is exact."""
    from repro.models import attention as A
    cfg = get_config("qwen2.5-3b").reduced()
    m = Model(cfg)
    params = m.init(KEY)
    tokens = jax.random.randint(KEY, (1, 64), 0, cfg.vocab_size)
    ref, _ = m.forward(params, {"tokens": tokens})
    old_thr, old_chunk = A.CHUNKED_ATTN_THRESHOLD, A.CHUNK_Q
    try:
        A.CHUNKED_ATTN_THRESHOLD, A.CHUNK_Q = 32, 16
        got, _ = m.forward(params, {"tokens": tokens})
    finally:
        A.CHUNKED_ATTN_THRESHOLD, A.CHUNK_Q = old_thr, old_chunk
    assert float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                 - ref.astype(jnp.float32)))) < 0.05


def test_moe_capacity_drops_are_bounded():
    """With cf=1.0 and adversarial routing, output stays finite and the
    kept fraction is >= capacity/expected."""
    from repro.models import moe as M
    cfg = get_config("qwen2-moe-a2.7b").reduced().replace(capacity_factor=1.0)
    p = M.init_moe(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (4, 16, cfg.d_model), jnp.float32)
    y, aux = M.moe_forward(p, x, cfg)
    assert y.shape == x.shape
    assert jnp.all(jnp.isfinite(y))
    assert float(aux) > 0


def test_mrope_text_equals_rope():
    """M-RoPE with t==h==w position triples reduces to plain RoPE."""
    from repro.models.common import make_angle_fn
    cfg = get_config("qwen2-vl-2b").reduced()
    plain = cfg.replace(mrope_sections=None)
    S = 16
    pos = jnp.arange(S)[None, :]
    a_mrope = make_angle_fn(cfg)(pos)
    a_plain = make_angle_fn(plain)(pos)
    assert jnp.allclose(a_mrope, a_plain, atol=1e-6)
