"""Quantised KV pages on the paged routes: edge-case contracts.

The int8 paged cache stores codes + per-(token, head) scales as
parallel pool slabs sharing one block table, so every page-granular
mechanism (CoW prefix forks, host-tier park/restore, the garbage
sentinel) must move codes and scales together.  These tests pin the
corners the happy-path identity checks (table15) can miss:

  * a zero K/V vector round-trips exactly through the scale epsilon,
  * the garbage sentinel page is never dequantised into a live lane on
    either route — even when poisoned with the worst representable
    content (codes 127, scale 1e30; finite on purpose, since masked
    probabilities are exact zeros and ``0 * finite == 0`` while
    ``0 * nan`` would hide a real leak as much as reveal one),
  * host-tier blobs carry all four slabs and restore bit-exactly,
  * CoW forks on a shared quantised page copy the scales with the
    codes,
  * chunked prefill equals whole-prompt prefill under int8 (per-token
    quantisation commutes with chunking).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.kernels.paged_decode_attention.ops import paged_decode_attention
from repro.kernels.paged_decode_attention.ref import (
    paged_decode_attention_quant_ref)
from repro.models import Model
from repro.quant.kv import dequantize_kv, quantize_kv_write
from repro.serving import DecodeEngine, SessionRequest, SlotScheduler
from repro.serving.memory import (GARBAGE_PAGE, restore_kv_blobs,
                                  save_kv_blobs)

KEY = jax.random.PRNGKey(11)
CFG = get_config("qwen2.5-3b").reduced().replace(
    vocab_size=256, d_model=96, d_ff=192, n_layers=2, n_heads=4,
    n_kv_heads=2, head_dim=16, dtype="float32")


def _engine(cfg=CFG, **kw):
    m = Model(cfg, **kw.pop("model_kw", {}))
    return DecodeEngine(m, m.init(KEY), **kw)


def _fleet(n, *, page=4, shared_pages=2, base_new=4, dups=1):
    preamble = np.asarray(jax.random.randint(
        KEY, (shared_pages * page,), 0, CFG.vocab_size))
    reqs = []
    for i in range(n):
        k = jax.random.fold_in(KEY, 100 + i)
        tail = np.asarray(jax.random.randint(k, (3 + i,), 0,
                                             CFG.vocab_size))
        reqs.append(SessionRequest(
            f"s{i}", np.concatenate([preamble, tail]), base_new + i % 3))
    for i in range(dups):
        reqs.append(SessionRequest(f"dup{i}", preamble, base_new))
    return reqs


def _assert_identical(reqs, ref, res, what):
    for r in reqs:
        np.testing.assert_array_equal(
            ref.tokens_for(r.session_id), res.tokens_for(r.session_id),
            err_msg=f"{r.session_id} diverged: {what}")


class TestScaleEpsilon:
    def test_zero_vector_roundtrips_exactly(self):
        """An all-zero K/V vector has max|x| == 0; the scale epsilon
        must keep the codes zero and the dequantised value EXACTLY
        zero, not epsilon-sized noise."""
        x = jnp.zeros((2, 3, 2, 16), jnp.bfloat16)
        codes, scales = quantize_kv_write(x)
        np.testing.assert_array_equal(np.asarray(codes), 0)
        assert np.all(np.asarray(scales) > 0)          # finite, no 1/0
        back = dequantize_kv(codes, scales, jnp.float32)
        np.testing.assert_array_equal(np.asarray(back), 0.0)

    def test_mixed_zero_rows_stay_zero(self):
        """Zero rows next to large rows: each (token, head) scales
        independently, so the zero rows still come back exact."""
        x = jnp.zeros((1, 4, 1, 8), jnp.float32).at[0, 1].set(300.0)
        codes, scales = quantize_kv_write(x)
        back = np.asarray(dequantize_kv(codes, scales, jnp.float32))
        np.testing.assert_array_equal(back[0, 0], 0.0)
        np.testing.assert_array_equal(back[0, 2:], 0.0)
        np.testing.assert_allclose(back[0, 1], 300.0, rtol=0.01)


class TestGarbageSentinel:
    def _pools(self, poison):
        k = jax.random.PRNGKey(3)
        n_pages, page, Hkv, hd, B = 5, 4, 2, 16, 2
        ks = [jax.random.fold_in(k, i) for i in range(5)]
        k_pool = jax.random.randint(ks[0], (n_pages, page, Hkv, hd),
                                    -127, 128, jnp.int32).astype(jnp.int8)
        v_pool = jax.random.randint(ks[1], (n_pages, page, Hkv, hd),
                                    -127, 128, jnp.int32).astype(jnp.int8)
        k_sc = jax.random.uniform(ks[2], (n_pages, page, Hkv),
                                  jnp.float32, 0.01, 0.1)
        v_sc = jax.random.uniform(ks[3], (n_pages, page, Hkv),
                                  jnp.float32, 0.01, 0.1)
        if poison:     # worst representable content, finite on purpose
            k_pool = k_pool.at[GARBAGE_PAGE].set(127)
            v_pool = v_pool.at[GARBAGE_PAGE].set(127)
            k_sc = k_sc.at[GARBAGE_PAGE].set(1e30)
            v_sc = v_sc.at[GARBAGE_PAGE].set(1e30)
        else:
            k_pool = k_pool.at[GARBAGE_PAGE].set(0)
            v_pool = v_pool.at[GARBAGE_PAGE].set(0)
            k_sc = k_sc.at[GARBAGE_PAGE].set(0.0)
            v_sc = v_sc.at[GARBAGE_PAGE].set(0.0)
        q = jax.random.normal(ks[4], (B, 4, hd), jnp.float32)
        # slot 0: two live pages then sentinel padding; slot 1: one
        # partially-live page; both routes must never read page 0
        bt = jnp.array([[1, 2, GARBAGE_PAGE],
                        [3, GARBAGE_PAGE, GARBAGE_PAGE]], jnp.int32)
        lengths = jnp.array([7, 3], jnp.int32)
        return q, k_pool, v_pool, k_sc, v_sc, bt, lengths

    def test_poisoned_sentinel_never_dequantised(self):
        clean = self._pools(poison=False)
        dirty = self._pools(poison=True)
        for route in (paged_decode_attention,
                      paged_decode_attention_quant_ref):
            if route is paged_decode_attention:
                a = np.asarray(route(clean[0], clean[1], clean[2],
                                     clean[5], clean[6], clean[3],
                                     clean[4]))
                b = np.asarray(route(dirty[0], dirty[1], dirty[2],
                                     dirty[5], dirty[6], dirty[3],
                                     dirty[4]))
            else:
                a = np.asarray(route(*clean))
                b = np.asarray(route(*dirty))
            assert np.all(np.isfinite(b)), f"{route.__name__}: non-finite"
            np.testing.assert_array_equal(
                a, b, err_msg=f"{route.__name__} read the poisoned "
                              f"sentinel page")


class TestHostTierBlobs:
    def test_park_restore_bit_exact(self):
        """Int8 blobs carry four slabs (codes + scales for K and V) and
        a park/restore round trip is bit-exact on every one."""
        m = Model(CFG)
        cache = m.init_cache(2, 32, paged=True, page_size=4,
                             kv_dtype=jnp.int8)
        keys = ("k", "v", "k_scale", "v_scale")
        rng = np.random.RandomState(5)
        for key in ("k", "v"):
            cache[key] = jnp.asarray(rng.randint(
                -127, 128, cache[key].shape).astype(np.int8))
        for key in ("k_scale", "v_scale"):
            cache[key] = jnp.asarray(rng.uniform(
                1e-3, 1.0, cache[key].shape).astype(np.float32))
        pages = [2, 5, 3]
        save_jit = jax.jit(m.save_kv_pages)
        restore_jit = jax.jit(m.restore_kv_pages)
        blobs = save_kv_blobs(save_jit, cache, pages)
        assert len(blobs) == len(pages)
        assert all(len(b) == 4 for b in blobs)
        assert blobs[0][0].dtype == np.int8
        assert blobs[0][2].dtype == np.float32
        fresh = m.init_cache(2, 32, paged=True, page_size=4,
                             kv_dtype=jnp.int8)
        fresh = restore_kv_blobs(restore_jit, fresh, pages, blobs)
        for key in keys:
            np.testing.assert_array_equal(
                np.asarray(fresh[key][:, pages]),
                np.asarray(cache[key][:, pages]),
                err_msg=f"{key} not bit-exact through park/restore")


class TestQuantisedCoW:
    def test_copy_kv_page_moves_scales(self):
        m = Model(CFG)
        cache = m.init_cache(2, 32, paged=True, page_size=4,
                             kv_dtype=jnp.int8)
        cache["k"] = cache["k"].at[:, 1].set(7)
        cache["k_scale"] = cache["k_scale"].at[:, 1].set(0.5)
        cache["v_scale"] = cache["v_scale"].at[:, 1].set(0.25)
        out = m.copy_kv_page(cache, jnp.int32(1), jnp.int32(2))
        np.testing.assert_array_equal(np.asarray(out["k"][:, 2]), 7)
        np.testing.assert_array_equal(
            np.asarray(out["k_scale"][:, 2]), 0.5)
        np.testing.assert_array_equal(
            np.asarray(out["v_scale"][:, 2]), 0.25)

    def test_cow_fork_on_shared_quantised_page(self):
        """Prefix sharing over int8 pages: the CoW replay (an exact
        page-aligned duplicate prompt) forks codes AND scales, so the
        shared-page run stays token-identical to the private-page
        run."""
        eng = _engine(kv_dtype=jnp.int8)
        reqs = _fleet(3, dups=1)
        ref = eng.generate_continuous(reqs, n_slots=2, max_len=40,
                                      paged=True, page_size=4)
        res = eng.generate_continuous(reqs, n_slots=2, max_len=40,
                                      paged=True, page_size=4,
                                      prefix_cache=True)
        assert res.prefix_hits >= 3
        assert res.cow_copies >= 1
        _assert_identical(reqs, ref, res, "int8 CoW fork")

    def test_shared_quantised_pages_never_written(self):
        """Poisoned-page guard, int8 edition: after a second wave that
        hits every cached prefix, the shared pages' codes and scales
        read back bit-unchanged."""
        eng = _engine(kv_dtype=jnp.int8)
        reqs = _fleet(3, dups=1)
        sched = SlotScheduler(eng.model, eng.params, n_slots=2,
                              max_len=40, paged=True, page_size=4,
                              kv_dtype=jnp.int8, prefix_cache=True)
        for r in reqs:
            sched.submit(r)
        sched.run()
        cached = sched.prefix.pages()
        assert cached, "first wave registered nothing"
        snap = {key: np.asarray(sched.cache[key][:, cached])
                for key in ("k", "v", "k_scale", "v_scale")}
        for r in reqs:
            sched.submit(dataclasses.replace(
                r, session_id="w2" + r.session_id))
        res = sched.run()
        assert res.prefix_hits == len(reqs)
        assert res.cow_copies >= 1
        for key, before in snap.items():
            np.testing.assert_array_equal(
                before, np.asarray(sched.cache[key][:, cached]),
                err_msg=f"a shared {key} page was written")


class TestQuantisedPrefillRoutes:
    def test_chunked_prefill_matches_whole_prompt(self):
        """Per-token quantisation commutes with chunking: chunked int8
        prefill must emit exactly the whole-prompt int8 streams."""
        eng = _engine(kv_dtype=jnp.int8)
        reqs = _fleet(4, dups=0)
        ref = eng.generate_continuous(reqs, n_slots=2, max_len=40,
                                      paged=True, page_size=4)
        res = eng.generate_continuous(reqs, n_slots=2, max_len=40,
                                      paged=True, page_size=4,
                                      prefill_chunk=4)
        _assert_identical(reqs, ref, res, "chunked int8 prefill")

    def test_routes_identical_under_int8(self):
        """f32 model dtype: the fused kernel's in-register codes*scale
        equals the gather route's dequantised f32 view exactly, so the
        two routes' greedy streams must coincide token-for-token."""
        reqs = _fleet(3, dups=0)
        gather = _engine(kv_dtype=jnp.int8)
        pallas = _engine(kv_dtype=jnp.int8,
                         model_kw={"decode_backend": "pallas"})
        a = gather.generate_continuous(reqs, n_slots=2, max_len=40,
                                       paged=True, page_size=4)
        b = pallas.generate_continuous(reqs, n_slots=2, max_len=40,
                                       paged=True, page_size=4)
        _assert_identical(reqs, a, b, "gather vs pallas under int8")
