"""Host-DRAM KV page tier: save/restore fidelity, host-pool accounting,
park/restore token identity on both decode routes, the eviction→resume
interplay between the prefix cache and the tier, and policy arms.

The tier's one correctness contract: restored bytes are the bytes
prefill/decode originally wrote, so a preempted-and-parked session's
greedy stream is token-identical to the re-prefill (single-tier)
baseline — placement policy changes copies, never streams.  Everything
else is accounting: the device free list and the host pool must balance
after every wave, whatever interleaving of parking, prefix eviction,
shadow spills and restores the schedule produced.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model
from repro.serving import SessionRequest, SlotScheduler
from repro.serving.memory import (HostPagePool, TieredPageStore,
                                  get_policy, restore_kv_blobs,
                                  save_kv_blobs)
from repro.serving.memory.tiers import _pad_pow2

KEY = jax.random.PRNGKey(11)
CFG = get_config("qwen2.5-3b").reduced().replace(
    vocab_size=64, d_model=64, d_ff=128, n_layers=2,
    n_heads=4, n_kv_heads=2, head_dim=16, dtype="float32")

_STATE: dict = {}


def _model(backend="sdpa"):
    if backend not in _STATE:
        m = Model(CFG) if backend == "sdpa" else \
            Model(CFG, decode_backend=backend)
        _STATE[backend] = (m, m.init(KEY))
    return _STATE[backend]


def _serve(model, params, reqs, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_len", 24)
    kw.setdefault("paged", True)
    kw.setdefault("page_size", 4)
    kw.setdefault("timed", False)
    kw.setdefault("shared_programs", True)
    sched = SlotScheduler(model, params, **kw)
    for r in reqs:
        sched.submit(r)
    return sched, sched.run()


def _churn_requests(n=5):
    """Deterministic wave sized to thrash a small pool: multi-page
    prompts, budgets long enough that residents preempt each other."""
    rng = np.random.RandomState(3)
    return [SessionRequest(
        f"s{i}",
        rng.randint(0, CFG.vocab_size, size=8 + 3 * (i % 3)).astype(
            np.int32),
        6 + 2 * (i % 2)) for i in range(n)]


# ------------------------------------------------------- page movers
class TestSaveRestore:
    def test_pad_pow2(self):
        assert [_pad_pow2(n) for n in (1, 2, 3, 4, 5, 8, 9)] \
            == [1, 2, 4, 4, 8, 8, 16]

    def test_roundtrip_is_bit_exact(self):
        """save → clobber → restore returns the original page bytes,
        and the garbage-page padding never corrupts a real page."""
        model, params = _model()
        cache = model.init_cache(2, 16, paged=True, page_size=4,
                                 n_pages=8)
        rng = np.random.RandomState(0)
        k0 = rng.randn(*cache["k"].shape).astype(np.float32)
        v0 = rng.randn(*cache["v"].shape).astype(np.float32)
        cache = dict(cache, k=jnp.asarray(k0), v=jnp.asarray(v0))
        save = jax.jit(model.save_kv_pages)
        restore = jax.jit(model.restore_kv_pages)
        pages = [3, 5, 6]                      # pads to 4 with garbage
        blobs = save_kv_blobs(save, cache, pages)
        clobbered = dict(cache, k=jnp.zeros_like(cache["k"]),
                         v=jnp.zeros_like(cache["v"]))
        out = restore_kv_blobs(restore, clobbered, pages, blobs)
        for p in pages:
            np.testing.assert_array_equal(np.asarray(out["k"][:, p]),
                                          k0[:, p])
            np.testing.assert_array_equal(np.asarray(out["v"][:, p]),
                                          v0[:, p])
        # non-restored real pages stay clobbered (zero)
        assert not np.any(np.asarray(out["k"][:, 2]))

    def test_program_count_is_pow2_bounded(self):
        """Distinct compiled save shapes grow with log2 of the run
        length, not linearly — the padding contract."""
        seen = []

        def fake_save(cache, ids):
            ids = np.asarray(ids)
            seen.append(ids.shape[0])
            return (np.zeros((1, ids.shape[0], 4, 2, 2), np.float32),
                    np.zeros((1, ids.shape[0], 4, 2, 2), np.float32))

        for n in range(1, 9):
            save_kv_blobs(fake_save, {}, list(range(1, n + 1)))
        assert set(seen) == {1, 2, 4, 8}


# ------------------------------------------------------ host pool
class TestHostPagePool:
    def _blob(self, i):
        return (np.full((1,), i, np.float32), np.zeros((1,), np.float32))

    def test_pinned_survive_lru_unpinned_die(self):
        pool = HostPagePool(3)
        hp = pool.put(self._blob(0), pinned=True)
        h1 = pool.put(self._blob(1), pinned=False)
        h2 = pool.put(self._blob(2), pinned=False)
        pool.touch(h1)                   # h2 becomes the LRU victim
        dropped = []
        pool.on_drop = dropped.append
        h3 = pool.put(self._blob(3), pinned=False)
        assert dropped == [h2] and pool.dropped == 1
        assert pool.get(hp)[0][0] == 0 and pool.get(h1)[0][0] == 1
        assert pool.get(h3)[0][0] == 3
        with pytest.raises(KeyError):
            pool.get(h2)

    def test_reserve_fails_when_pinned_fill(self):
        pool = HostPagePool(2)
        pool.put(self._blob(0), pinned=True)
        pool.put(self._blob(1), pinned=True)
        assert not pool.reserve(1)
        assert pool.put(self._blob(2), pinned=False) is None
        assert pool.used == 2            # failed put changes nothing

    def test_pop_releases_capacity(self):
        pool = HostPagePool(1)
        h = pool.put(self._blob(7), pinned=True)
        assert pool.free == 0
        assert pool.pop(h)[0][0] == 7
        assert pool.free == 1 and pool.used == 0


# -------------------------------------- store-level eviction interplay
def _fake_store(**kw):
    """TieredPageStore over fake page movers: blobs are (page_id,)
    sentinels, so restores are checkable without a device."""
    moved = {"restored": []}

    def save_fn(cache, pages):
        return [(np.full((1,), p, np.float32), np.zeros((1,), np.float32))
                for p in pages]

    def restore_fn(cache, pages, blobs):
        moved["restored"].extend(
            (int(b[0][0]), p) for p, b in zip(pages, blobs))
        return cache

    store = TieredPageStore(
        n_slots=2, max_blocks=6, page_size=4, n_pages=10,
        prefix_cache=True, host_pages=kw.pop("host_pages", 8),
        policy=get_policy(kw.pop("policy", "spill")),
        save_fn=save_fn, restore_fn=restore_fn, get_cache=lambda: {},
        **kw)
    return store, moved


class TestEvictionResumeInterplay:
    def test_prefix_reclaim_mid_parking_spills_then_resumes(self):
        """The satellite scenario: a session parks, its (now cache-only)
        prefix pages get reclaimed under allocation pressure — each one
        spilling into the host prefix index — and the parked session
        still restores its own pinned blobs intact.  Free list and host
        pool balance afterwards."""
        store, moved = _fake_store()
        seq = np.asarray([1] * 8, np.int32)
        pages = store.alloc(3)                 # 2 full blocks + tail
        store.register(seq, pages, 2)          # prefix cache aliases them
        store.park("sid", 2, pages, {})        # preempt: park full blocks
        store.release(pages)                   # device pages freed
        assert store.parked_blocks("sid") == 2
        assert store.pages_spilled == 2
        # allocation pressure reclaims the cached prefix pages; the
        # eviction hook gives each a second life in the host index
        got = store.alloc(9)                   # > free list alone
        assert got is not None
        assert len(store.host_match(seq, 0, 2)) >= 1
        prefix_spills = store.pages_spilled - 2
        assert prefix_spills >= 1
        store.release(got)
        # resume: device match is gone (k=0), parked blobs restore
        fresh = store.alloc(2)
        store.take_parked("sid", 0, fresh, {})
        assert store.tier_restores == 1
        assert [m[0] for m in moved["restored"]] == pages[:2], \
            "restored blobs must be the very pages that were parked"
        store.release(fresh)
        store.flush_prefix()
        store.flush_host()
        assert store.allocator.n_free == store.n_pages - 1
        assert store.host_used == 0

    def test_host_index_restore_consumes_entry(self):
        store, moved = _fake_store()
        seq = np.asarray([2] * 8, np.int32)
        pages = store.alloc(2)
        store.register(seq, pages, 2)
        store.release(pages)
        store.prefix.reclaim(99)               # evict both -> host index
        paths = store.host_match(seq, 0, 2)
        assert len(paths) == 2
        fresh = store.alloc(2)
        store.restore_host_prefix(paths, fresh, {})
        assert store.host_prefix_hits == 2
        assert store.host_match(seq, 0, 2) == [], "entry must be consumed"
        store.release(fresh)
        store.flush_host()
        assert store.host_used == 0

    def test_prefer_device_never_touches_the_host(self):
        store, moved = _fake_store(policy="prefer-device")
        seq = np.asarray([3] * 8, np.int32)
        pages = store.alloc(2)
        store.register(seq, pages, 2)
        store.release(pages)
        store.prefix.reclaim(99)               # hook not wired: no spill
        assert store.pages_spilled == 0 and store.host_used == 0
        assert store.host_match(seq, 0, 2) == []

    def test_double_park_asserts(self):
        store, _ = _fake_store()
        pages = store.alloc(2)
        store.park("sid", 2, pages, {})
        with pytest.raises(AssertionError, match="parked twice"):
            store.park("sid", 2, pages, {})

    def test_park_fails_clean_when_host_full(self):
        store, _ = _fake_store(host_pages=1)
        a = store.alloc(2)
        assert store.park("a", 2, a, {}) is None   # needs 2, cap 1
        assert store.park_fails == 1
        assert store.parked_blocks("a") == 0 and store.host_used == 0

    def test_shadow_spill_consumed_by_park(self):
        store, moved = _fake_store(policy="lookahead")
        pages = store.alloc(3)
        store.shadow_spill("sid", [0, 1], pages[:2], {})
        assert store.pages_spilled == 2
        copied_now = store.park("sid", 3, pages, {})
        assert copied_now == 1, "park must only copy the un-shadowed page"
        fresh = store.alloc(3)
        store.take_parked("sid", 0, fresh, {})
        assert [m[0] for m in moved["restored"]] == pages, \
            "shadow blobs must restore as the pages they shadowed"
        store.release(pages)
        store.release(fresh)
        assert store.host_used == 0


# ------------------------------------------------- end-to-end identity
class TestParkRestoreIdentity:
    @pytest.mark.parametrize("backend", ["sdpa", "pallas"])
    def test_tier_arms_token_identical_to_single_tier(self, backend):
        """Forced preemption churn through a small pool: every tier
        policy replays the exact greedy streams of the single-tier
        baseline, the spill arms actually migrate, and both memory
        pools balance afterwards."""
        model, params = _model(backend)
        reqs = _churn_requests()
        kw = dict(n_pages=8, prefill_chunk=4, prefix_cache=True)
        sched, base = _serve(model, params, reqs, **kw)
        assert base.preemptions > 0, "pool never pressured: test is void"
        sched.flush_prefix_cache()
        assert sched.store.allocator.n_free == 7
        spilled = {}
        for arm in ("prefer-device", "spill", "lookahead"):
            sched, res = _serve(model, params, reqs, kv_tier="host",
                                tier_policy=arm, host_pages=24, **kw)
            for r in reqs:
                np.testing.assert_array_equal(
                    base.tokens_for(r.session_id),
                    res.tokens_for(r.session_id),
                    err_msg=f"{r.session_id} diverged under {arm} "
                            f"({backend})")
            store = sched.store
            sched.flush_prefix_cache()
            store.flush_host()
            assert store.allocator.n_free == 7, f"page leak ({arm})"
            assert store.host_used == 0, f"host leak ({arm})"
            spilled[arm] = res.pages_spilled
            if arm == "prefer-device":
                assert res.pages_spilled == 0 and res.tier_restores == 0
                assert res.prefill_tokens == base.prefill_tokens, \
                    "control arm must re-prefill exactly like single-tier"
            else:
                assert res.pages_spilled > 0 and res.tier_restores > 0
                assert res.prefill_tokens < base.prefill_tokens, \
                    f"{arm}: restores did not replace re-prefill work"

    def test_resume_without_device_match_restores_parked(self):
        """Same churn with the prefix cache OFF: resumes cannot lean on
        a device match, so every tiered resume must come from parked
        blobs — the pure park/restore path."""
        model, params = _model()
        reqs = _churn_requests(4)
        kw = dict(n_pages=8, prefill_chunk=4)
        _, base = _serve(model, params, reqs, **kw)
        assert base.preemptions > 0
        sched, res = _serve(model, params, reqs, kv_tier="host",
                            host_pages=24, **kw)
        assert res.tier_restores > 0
        for r in reqs:
            np.testing.assert_array_equal(
                base.tokens_for(r.session_id),
                res.tokens_for(r.session_id),
                err_msg=f"{r.session_id} diverged (no prefix cache)")
        assert sched.store.allocator.n_free == 7
        sched.store.flush_host()
        assert sched.store.host_used == 0

    def test_tiny_host_pool_degrades_to_reprefill(self):
        """A 1-page host pool can rarely park; failed parks must fall
        back to plain re-prefill with identical streams and no leak."""
        model, params = _model()
        reqs = _churn_requests(4)
        kw = dict(n_pages=8, prefill_chunk=4)
        _, base = _serve(model, params, reqs, **kw)
        sched, res = _serve(model, params, reqs, kv_tier="host",
                            host_pages=1, **kw)
        for r in reqs:
            np.testing.assert_array_equal(
                base.tokens_for(r.session_id),
                res.tokens_for(r.session_id),
                err_msg=f"{r.session_id} diverged under a full host pool")
        sched.store.flush_host()
        assert sched.store.host_used == 0
        assert sched.store.allocator.n_free == 7
