"""HLO collective parser + analytic model + roofline assembly."""
import pytest

from repro.analysis import analytic
from repro.analysis.hlo import (CollectiveOp, _shape_bytes,
                                collective_summary, parse_collectives)
from repro.analysis.roofline import build_row, markdown_table
from repro.configs import SHAPES, get_config


class TestHloParser:
    def test_shape_bytes(self):
        assert _shape_bytes("bf16[8,128]") == 8 * 128 * 2
        assert _shape_bytes("f32[4]") == 16
        assert _shape_bytes("(bf16[2,2], f32[2])") == 8 + 8
        assert _shape_bytes("u8[100]") == 100

    def test_parse_simple_allreduce(self):
        hlo = """
HloModule m
ENTRY %main (a: f32[16,16]) -> f32[16,16] {
  %a = f32[16,16] parameter(0)
  %ar = f32[16,16] all-reduce(%a), replica_groups={{0,1,2,3}}, to_apply=%add
}
"""
        ops = parse_collectives(hlo, n_devices=4)
        assert len(ops) == 1
        assert ops[0].kind == "all-reduce"
        assert ops[0].group_size == 4
        # ring all-reduce: 2*(n-1)/n * bytes
        assert ops[0].wire_bytes_per_chip == pytest.approx(
            2 * 3 / 4 * 16 * 16 * 4)

    def test_while_body_multiplier(self):
        hlo = """
HloModule m
%region_1.10 (arg: (s32[], f32[8])) -> (s32[], f32[8]) {
  %ar = f32[8] all-reduce(%x), replica_groups={{0,1}}, to_apply=%add
}
ENTRY %main () -> f32[8] {
  %w = (s32[], f32[8]) while(%init), condition=%region_2.20, body=%region_1.10
  %ar2 = f32[8] all-reduce(%y), replica_groups={{0,1}}, to_apply=%add
}
"""
        ops = parse_collectives(hlo, n_devices=2, loop_multiplier=24)
        mult = {o.computation: o.multiplier for o in ops}
        assert mult["region_1.10"] == 24
        assert [o for o in ops if o.multiplier == 1]
        s = collective_summary(ops)
        assert s["by_kind"]["all-reduce"]["count"] == 25

    def test_collective_cost_model(self):
        ag = CollectiveOp("all-gather", 1000, 4, "e", 1)
        rs = CollectiveOp("reduce-scatter", 250, 4, "e", 1)
        ar = CollectiveOp("all-reduce", 1000, 4, "e", 1)
        # AR == AG(result) + RS(same logical tensor) wire bytes
        assert ar.wire_bytes_per_chip == pytest.approx(
            ag.wire_bytes_per_chip + rs.wire_bytes_per_chip)


class TestAnalytic:
    def test_decode_flops_scale_with_batch(self):
        cfg = get_config("qwen2.5-3b")
        f1 = analytic.decode_flops(cfg, 1, 2048)
        f2 = analytic.decode_flops(cfg, 2, 2048)
        assert f2 == pytest.approx(2 * f1)

    def test_train_flops_vs_model_flops(self):
        cfg = get_config("olmo-1b")
        est = analytic.estimate(cfg, SHAPES["train_4k"], n_chips=256,
                                tp=16, dp=16)
        # 6ND <= total (remat adds a fwd; attention adds seq^2 term)
        assert est.model_flops < est.flops < 3 * est.model_flops

    def test_ssm_decode_ctx_invariant(self):
        cfg = get_config("mamba2-2.7b")
        assert (analytic.decode_flops(cfg, 1, 2048)
                == analytic.decode_flops(cfg, 1, 524288))


class TestRoofline:
    def _cell(self):
        return {
            "arch": "olmo-1b", "shape": "decode_32k", "mesh": "pod",
            "n_chips": 256,
            "analytic": {"flops": 851e9, "hbm_bytes_per_chip": 2.29e9,
                         "model_flops": 301e9},
            "collectives": {"total_wire_bytes_per_chip": 9.0e6},
        }

    def test_build_row(self):
        r = build_row(self._cell())
        assert r.dominant == "memory"
        assert r.memory_t == pytest.approx(2.29e9 / 819e9)
        assert r.compute_t == pytest.approx(851e9 / (256 * 197e12))
        assert r.collective_t == pytest.approx(9.0e6 / 50e9)
        assert 0 < r.useful_ratio < 1

    def test_markdown_table(self):
        md = markdown_table([build_row(self._cell())])
        assert "olmo-1b" in md and "memory" in md
