"""Sharding-plan + multi-device tests.

Multi-device cases run in a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=8 so the main pytest
process keeps its single CPU device (assignment requirement: the flag
must not leak into smoke tests/benches).
"""
import json
import os
import subprocess
import sys
import textwrap


from repro.configs import get_config
from repro.launch import sharding as shd
from repro.launch.mesh import make_production_mesh  # noqa: F401 (import safety)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_subprocess(code: str) -> dict:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               JAX_PLATFORMS="cpu",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_mesh_import_does_not_touch_devices():
    """Importing mesh.py must not initialise jax devices."""
    code = textwrap.dedent("""
        import json, sys
        import repro.launch.mesh  # noqa
        import jax
        # jax not yet initialised: device count resolves to 8 ONLY if the
        # flag was respected (i.e. nothing initialised the backend early)
        print(json.dumps({"n": jax.device_count()}))
    """)
    assert run_subprocess(code)["n"] == 8


def test_small_mesh_train_step_runs():
    """A real sharded train step executes on a 4x2 fake-device mesh and
    matches the single-device loss."""
    code = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.models import Model
        from repro.launch import sharding as shd
        from repro.launch.mesh import make_test_mesh
        from repro.launch.hints import activation_hints
        from repro.training import AdamW, make_train_step, synthetic_batch
        from repro.training.data import DataCursor

        cfg = get_config("internlm2-1.8b").reduced()
        m = Model(cfg)
        opt = AdamW(lr=1e-3)
        params = m.init(jax.random.PRNGKey(0))
        state = (params, opt.init(params))
        batch = synthetic_batch(cfg, DataCursor(0, 0), batch=8, seq_len=32)
        step = make_train_step(m, opt, remat="blocks")

        # single device reference
        (p1, _), m1 = jax.jit(step)(state, batch)

        mesh = make_test_mesh(data=4, model=2)
        plan = shd.make_plan(cfg, mesh)
        p_sh = shd.params_shardings(plan, jax.eval_shape(lambda: params))
        o_sh = shd.opt_state_shardings(plan, jax.eval_shape(opt.init, params))
        b_sh = shd.batch_shardings(
            plan, jax.eval_shape(lambda: batch))
        with mesh, activation_hints(mesh):
            fn = jax.jit(step, in_shardings=((p_sh, o_sh), b_sh))
            (p2, _), m2 = fn(state, batch)
        print(json.dumps({
            "loss1": float(m1["loss"]), "loss2": float(m2["loss"]),
            "n_shards": len(jax.tree_util.tree_leaves(p2)[0].sharding.device_set),
        }))
    """)
    r = run_subprocess(code)
    assert abs(r["loss1"] - r["loss2"]) < 0.05
    assert r["n_shards"] == 8


def test_decode_step_seq_sharded_cache():
    """Decode with a sequence-sharded KV cache matches single-device."""
    code = textwrap.dedent("""
        import json
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.models import Model
        from repro.launch import sharding as shd
        from repro.launch.mesh import make_test_mesh
        from repro.launch.hints import activation_hints

        cfg = get_config("qwen2.5-3b").reduced()
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                    cfg.vocab_size)
        cache = m.init_cache(2, 64)
        _, cache = m.prefill(params, {"tokens": tokens}, cache)
        ref, _ = m.decode_step(params, cache, tokens[:, :1])

        mesh = make_test_mesh(data=2, model=4)
        plan = shd.make_plan(cfg, mesh)
        p_sh = shd.params_shardings(plan, jax.eval_shape(lambda: params))
        c_sh = shd.cache_shardings(plan, jax.eval_shape(lambda: cache))
        with mesh, activation_hints(mesh):
            fn = jax.jit(m.decode_step, in_shardings=(p_sh, c_sh, None))
            out, _ = fn(params, cache, tokens[:, :1])
        err = float(jnp.max(jnp.abs(out.astype(jnp.float32)
                                    - ref.astype(jnp.float32))))
        print(json.dumps({"err": err}))
    """)
    assert run_subprocess(code)["err"] < 0.05


def test_elastic_checkpoint_remesh():
    """A checkpoint saved unsharded restores onto a 8-device mesh
    (elastic re-mesh path) and produces the same loss."""
    code = textwrap.dedent("""
        import json, tempfile
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.models import Model
        from repro.launch import sharding as shd
        from repro.launch.mesh import make_test_mesh
        from repro.training import AdamW, save, restore, synthetic_batch
        from repro.training.data import DataCursor

        cfg = get_config("olmo-1b").reduced()
        m = Model(cfg)
        opt = AdamW(lr=1e-3)
        params = m.init(jax.random.PRNGKey(0))
        state = (params, opt.init(params))
        batch = synthetic_batch(cfg, DataCursor(0, 0), batch=8, seq_len=16)
        loss_ref = float(m.loss(params, batch)[0])

        with tempfile.TemporaryDirectory() as d:
            save(d, 1, state)
            mesh = make_test_mesh(data=2, model=4)
            plan = shd.make_plan(cfg, mesh)
            like = jax.eval_shape(lambda: state)
            shardings = (shd.params_shardings(plan, like[0]),
                         shd.opt_state_shardings(plan, like[1]))
            state2, _ = restore(d, like, shardings=shardings)
            with mesh:
                loss2 = float(m.loss(state2[0], batch)[0])
        print(json.dumps({"ref": loss_ref, "remesh": loss2}))
    """)
    r = run_subprocess(code)
    # bf16 loss under a re-sharded contraction order differs by ~1 ulp
    # (|Δ|/loss ≈ 2^-9); bound relatively, not at fp32-grade 1e-3
    assert abs(r["ref"] - r["remesh"]) / abs(r["ref"]) < 1e-2


class TestPlanRules:
    def test_divisibility_fallback_recorded(self):
        """mamba2 vocab 50280 %16 != 0 -> embed shards d_model instead."""
        code_free = get_config("mamba2-2.7b")
        # plan without touching real devices: use abstract mesh via
        # make_production_mesh is device-bound; emulate with test mesh in
        # subprocess instead — here just check the spec logic with a
        # fake mesh-like object.
        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}
        plan = shd.ShardingPlan(FakeMesh(), code_free, False, {})
        class Leaf:
            shape = (50280, 2560)
        spec = shd.param_spec(plan, (type("K", (), {"key": "embed"})(),), Leaf())
        assert tuple(spec) == (None, "model")

    def test_moe_ep_vs_tp(self):
        class FakeMesh:
            axis_names = ("data", "model")
            shape = {"data": 16, "model": 16}

        class Leaf:
            shape = (24, 60, 2048, 1408)   # stacked qwen2-moe experts

        plan = shd.ShardingPlan(FakeMesh(), get_config("qwen2-moe-a2.7b"),
                                False, {})
        kp = (type("K", (), {"key": "blocks"})(),
              type("K", (), {"key": "moe"})(),
              type("K", (), {"key": "w_up"})())
        spec = shd.param_spec(plan, kp, Leaf())
        assert tuple(spec) == (None, None, None, "model")   # TP inside experts

        class Leaf4:
            shape = (48, 16, 5120, 8192)   # llama4: E=16 -> EP
        plan4 = shd.ShardingPlan(FakeMesh(), get_config("llama4-scout-17b-a16e"),
                                 False, {})
        spec4 = shd.param_spec(plan4, kp, Leaf4())
        assert tuple(spec4) == (None, "model", None, None)
