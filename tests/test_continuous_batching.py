"""Continuous-batching session scheduler over the slotted KV cache.

Equivalence: decoding K churning sessions through a fixed slot pool must
be token-identical to K independent batch-1 ``generate_streamed`` runs
(greedy), with the decode step compiled exactly once — the paper's
one-compiled-program requirement carried into multi-user serving.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model
from repro.models import attention as attn
from repro.serving import DecodeEngine, SessionRequest, SlotScheduler

KEY = jax.random.PRNGKey(11)
CFG = get_config("qwen2.5-3b").reduced()


def _engine(cfg=CFG):
    m = Model(cfg)
    return DecodeEngine(m, m.init(KEY))


def _requests(n, cfg=CFG, base_len=4, base_new=3):
    """n sessions with mixed prompt lengths and token budgets."""
    reqs = []
    for i in range(n):
        k = jax.random.fold_in(KEY, 100 + i)
        prompt = np.asarray(
            jax.random.randint(k, (base_len + 2 * i,), 0, cfg.vocab_size))
        reqs.append(SessionRequest(f"s{i}", prompt, base_new + i % 4))
    return reqs


class TestSlottedPrimitives:
    def test_decode_mask_per_slot(self):
        m = attn.decode_mask(jnp.array([0, 3, 5]), 6)
        assert m.shape == (3, 6)
        np.testing.assert_array_equal(
            np.asarray(m),
            [[1, 0, 0, 0, 0, 0], [1, 1, 1, 1, 0, 0], [1, 1, 1, 1, 1, 1]])

    def test_decode_mask_scalar_unchanged(self):
        m = attn.decode_mask(jnp.int32(2), 5)
        assert m.shape == (5,)
        np.testing.assert_array_equal(np.asarray(m), [1, 1, 1, 0, 0])

    def test_kv_write_per_slot_matches_loop(self):
        dst = jnp.zeros((3, 8, 2, 4))
        new = jax.random.normal(KEY, (3, 1, 2, 4))
        pos = jnp.array([0, 5, 7])
        out = attn._kv_write(dst, new, pos)
        ref = np.zeros((3, 8, 2, 4))
        for b in range(3):
            ref[b, int(pos[b])] = np.asarray(new[b, 0])
        np.testing.assert_allclose(np.asarray(out), ref)

    def test_prefill_into_slot_isolates_rows(self):
        m = Model(CFG)
        params = m.init(KEY)
        cache = m.init_cache(3, 32, slotted=True)
        assert cache["pos"].shape == (3,)
        tokens = jax.random.randint(KEY, (1, 6), 0, CFG.vocab_size)
        logits, cache = m.prefill_into_slot(params, {"tokens": tokens},
                                            cache, jnp.int32(1))
        assert logits.shape == (1, 1, CFG.vocab_size)
        np.testing.assert_array_equal(np.asarray(cache["pos"]), [0, 6, 0])
        k = np.asarray(cache["k"], np.float32)
        assert np.any(k[:, 1, :6] != 0)          # slot 1 prefilled
        assert np.all(k[:, 0] == 0) and np.all(k[:, 2] == 0)

    def test_slotted_cache_rejects_ssm(self):
        m = Model(get_config("mamba2-2.7b").reduced())
        with pytest.raises(NotImplementedError):
            m.init_cache(2, 32, slotted=True)


class TestContinuousEquivalence:
    def test_matches_independent_batch1_greedy(self):
        """6 sessions churning through 3 slots == 6 batch-1 runs."""
        eng = _engine()
        reqs = _requests(6)
        res = eng.generate_continuous(reqs, n_slots=3, max_len=32)
        assert res.step_cache_size == 1    # zero recompiles after warmup
        for req in reqs:
            ref = eng.generate_streamed(
                {"tokens": jnp.asarray(req.prompt)[None, :]},
                max_len=32, n_new=req.max_new_tokens)
            np.testing.assert_array_equal(
                np.asarray(ref.tokens[0]), res.tokens_for(req.session_id),
                err_msg=f"{req.session_id} diverged from batch-1 decode")

    def test_single_token_session(self):
        """A 1-token session completes at admission (prefill logits)."""
        eng = _engine()
        req = _requests(1, base_new=1)[0]
        res = eng.generate_continuous([req], n_slots=2, max_len=32)
        ref = eng.generate_streamed(
            {"tokens": jnp.asarray(req.prompt)[None, :]}, max_len=32,
            n_new=1)
        np.testing.assert_array_equal(np.asarray(ref.tokens[0]),
                                      res.tokens_for(req.session_id))

    def test_more_slots_than_sessions(self):
        eng = _engine()
        reqs = _requests(2)
        res = eng.generate_continuous(reqs, n_slots=4, max_len=32)
        assert set(res.sessions) == {"s0", "s1"}


class TestSchedulerInvariants:
    def _run(self, n_slots=2, n=5):
        eng = _engine()
        sched = SlotScheduler(eng.model, eng.params, n_slots=n_slots,
                              max_len=32)
        reqs = _requests(n)
        for r in reqs:
            sched.submit(r)
        return sched, sched.run(), reqs

    def test_no_slot_double_assignment(self):
        """Replaying the event log, an admit must hit a free slot."""
        _, res, _ = self._run()
        occupancy = {}
        for ev in res.events:
            kind, sid, slot = ev[0], ev[1], ev[2]
            if kind == "admit":
                assert slot not in occupancy, (
                    f"slot {slot} double-assigned to {sid} while "
                    f"{occupancy.get(slot)} active")
                occupancy[slot] = sid
            elif kind == "finish":
                assert occupancy.pop(slot) == sid
        assert not occupancy                 # eviction freed everything

    def test_eviction_frees_capacity(self):
        sched, res, _ = self._run(n_slots=2, n=5)
        assert sched.free_slots == [0, 1]    # drained pool is all-free
        # capacity was respected at every point in the run
        live = 0
        for ev in res.events:
            live += {"admit": 1, "finish": -1}.get(ev[0], 0)
            assert 0 <= live <= 2
        assert len(res.sessions) == 5        # everyone was served

    def test_backfill_preserves_fifo_admission(self):
        _, res, reqs = self._run(n_slots=2, n=5)
        admits = [ev[1] for ev in res.events if ev[0] == "admit"]
        assert admits == [r.session_id for r in reqs]

    def test_step_compiled_once_across_churn(self):
        """Two full admission waves through one scheduler: the decode
        step must lower exactly once (constant shapes, no per-churn
        recompiles) — checked via the jit executable-cache size."""
        eng = _engine()
        sched = SlotScheduler(eng.model, eng.params, n_slots=2, max_len=32)
        for r in _requests(4):
            sched.submit(r)
        sched.run()
        assert sched.step_cache_size() == 1
        for r in _requests(3, base_len=5, base_new=4):
            req = SessionRequest(r.session_id + "w2", r.prompt,
                                 r.max_new_tokens)
            sched.submit(req)
        sched.run()
        assert sched.step_cache_size() == 1
        assert sched.decode_steps > 0

    def test_run_twice_field_semantics(self):
        """Regression for the per-run/cumulative drift on
        ``ContinuousResult``: per-run fields must reset at every
        ``run()`` call while the cumulative group keeps growing (the
        documented contract on the dataclass)."""
        eng = _engine()
        sched = SlotScheduler(eng.model, eng.params, n_slots=2, max_len=32)
        wave1 = _requests(4)
        for r in wave1:
            sched.submit(r)
        r1 = sched.run()
        wave2 = [SessionRequest(r.session_id + "w2", r.prompt,
                                r.max_new_tokens) for r in _requests(2)]
        for r in wave2:
            sched.submit(r)
        r2 = sched.run()
        # cumulative group: grows across calls
        assert len(r1.sessions) == 4 and len(r2.sessions) == 6
        assert r2.decode_steps > r1.decode_steps
        assert len(r2.events) > len(r1.events)
        # per-run group: covers only its own call
        assert r1.ticks + r2.ticks == sched.tick_count
        assert r2.dispatches == r2.decode_steps - r1.decode_steps
        w2_tokens = sum(len(r2.tokens_for(r.session_id)) for r in wave2)
        assert r2.run_tokens == w2_tokens
        assert r2.prefill_tokens == sum(len(r.prompt) for r in wave2)
        assert r2.host_dispatch_s <= r2.wall_s
        assert r2.preemptions == 0 and r2.cow_copies == 0

    def test_run_twice_field_semantics_paged(self):
        """Same contract through the paged counters (step_kv_blocks,
        preemptions, prefix stats)."""
        eng = _engine()
        sched = SlotScheduler(eng.model, eng.params, n_slots=2, max_len=32,
                              paged=True, page_size=8,
                              prefix_cache=True)
        for r in _requests(3, base_len=8):   # >= one full page each
            sched.submit(r)
        r1 = sched.run()
        for r in _requests(3, base_len=8):
            sched.submit(SessionRequest(r.session_id + "w2", r.prompt,
                                        r.max_new_tokens))
        r2 = sched.run()
        # wave 2 replays wave 1's prompts: every admission hits the
        # cache, and per-run stats cover only wave 2
        assert r1.prefix_hits == 0
        assert r2.prefix_hits == 3
        assert r2.prefill_tokens < r1.prefill_tokens
        assert len(r2.step_kv_blocks) == r2.dispatches
        assert r2.run_tokens == sum(
            len(s.tokens) for sid, s in r2.sessions.items()
            if sid.endswith("w2"))


class TestContinuousDispatchModes:
    """The dispatch A/B hooks survive into continuous serving: all three
    executors produce token-identical streams on the live workload."""

    def test_modes_token_identical(self):
        eng = _engine()
        outs = {}
        for mode in ("full_jit", "stage_jit", "eager"):
            res = eng.generate_continuous(_requests(3), n_slots=2,
                                          max_len=32, dispatch_mode=mode)
            outs[mode] = {sid: r.tokens.tolist()
                          for sid, r in res.sessions.items()}
        assert outs["stage_jit"] == outs["full_jit"]
        assert outs["eager"] == outs["full_jit"]

    def test_launches_per_step(self):
        eng = _engine()
        r_full = eng.generate_continuous(_requests(2), n_slots=2,
                                         max_len=32)
        assert r_full.launches_per_step == 1
        r_stage = eng.generate_continuous(_requests(2), n_slots=2,
                                          max_len=32,
                                          dispatch_mode="stage_jit")
        assert r_stage.launches_per_step == CFG.n_layers + 2
