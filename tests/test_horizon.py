"""Horizon-K fused decode: K decode steps per compiled macro-tick.

The contract is the paper's one carried across steps: fusing the
per-token host round-trip away (lax.scan over decode_step with
on-device sampling) must be a pure scheduling change — greedy streams
token-identical to K=1 on every route (contiguous, paged gather, paged
pallas), through EOS mid-horizon, page-pool oversubscription, and
preemption, with exactly ONE compiled multi-step program per
(backend, K) surviving session churn.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model
from repro.serving import DecodeEngine, SessionRequest, SlotScheduler

KEY = jax.random.PRNGKey(11)
CFG = get_config("qwen2.5-3b").reduced()


def _engine(cfg=CFG, **kw):
    m = Model(cfg, **kw)
    return DecodeEngine(m, m.init(KEY))


def _requests(n, cfg=CFG, base_len=4, base_new=3):
    """n sessions with mixed prompt lengths and token budgets."""
    reqs = []
    for i in range(n):
        k = jax.random.fold_in(KEY, 100 + i)
        prompt = np.asarray(
            jax.random.randint(k, (base_len + 2 * i,), 0, cfg.vocab_size))
        reqs.append(SessionRequest(f"s{i}", prompt, base_new + i % 4))
    return reqs


def _assert_identical(reqs, ref, res, what):
    for r in reqs:
        np.testing.assert_array_equal(
            ref.tokens_for(r.session_id), res.tokens_for(r.session_id),
            err_msg=f"{r.session_id} diverged: {what}")


class TestDecodeStepsPrimitive:
    """Model.decode_steps against hand-stepped decode_step."""

    def test_masked_lanes_are_device_noops(self):
        """A lane with steps_left=0 must not move: cache rows untouched,
        position frozen, emitted tokens clamped to its input."""
        m = Model(CFG)
        params = m.init(KEY)
        cache = m.init_cache(3, 32, slotted=True)
        toks = jax.random.randint(KEY, (1, 6), 0, CFG.vocab_size)
        logits, cache = m.prefill_into_slot(params, {"tokens": toks},
                                            cache, jnp.int32(0))
        t0 = int(jnp.argmax(logits[:, -1], -1)[0])
        tok_mat = np.zeros((3, 1), np.int32)
        tok_mat[0, 0] = t0

        # reference: hand-stepped greedy on lane 0
        cache_ref = dict(cache)
        cur = jnp.asarray(tok_mat)
        ref = []
        for _ in range(4):
            lg, cache_ref = m.decode_step(params, cache_ref, cur)
            nxt = jnp.argmax(lg[:, -1], -1).astype(jnp.int32)
            ref.append(int(nxt[0]))
            cur = cur.at[0, 0].set(nxt[0])

        out, cache_ms = m.decode_steps(
            params, cache, jnp.asarray(tok_mat), KEY,
            jnp.array([4, 0, 0], jnp.int32), horizon=4)
        assert np.asarray(out)[0].tolist() == ref
        np.testing.assert_array_equal(np.asarray(cache_ms["pos"]),
                                      [10, 0, 0])
        # masked lanes: zero-initialised rows still zero
        k = np.asarray(cache_ms["k"], np.float32)
        assert np.all(k[:, 1:] == 0)

    def test_partial_budget_clamps_and_freezes(self):
        """steps_left < horizon: the lane stops mid-horizon — later
        emitted tokens repeat the last real one, pos stops advancing."""
        m = Model(CFG)
        params = m.init(KEY)
        cache = m.init_cache(2, 32, slotted=True)
        toks = jax.random.randint(KEY, (1, 5), 0, CFG.vocab_size)
        logits, cache = m.prefill_into_slot(params, {"tokens": toks},
                                            cache, jnp.int32(0))
        tok_mat = np.zeros((2, 1), np.int32)
        tok_mat[0, 0] = int(jnp.argmax(logits[:, -1], -1)[0])
        out, cache2 = m.decode_steps(
            params, cache, jnp.asarray(tok_mat), KEY,
            jnp.array([2, 0], jnp.int32), horizon=5)
        row = np.asarray(out)[0]
        assert np.all(row[2:] == row[1]), "post-budget tokens not clamped"
        assert int(np.asarray(cache2["pos"])[0]) == 5 + 2

    def test_eos_requires_masking(self):
        m = Model(CFG)
        params = m.init(KEY)
        cache = m.init_cache(1, 16, slotted=True)
        with pytest.raises(NotImplementedError):
            m.decode_steps(params, cache, jnp.zeros((1, 1), jnp.int32),
                           KEY, None, horizon=2, eos_id=3)

    def test_active_rejected_on_ssm(self):
        cfg = get_config("mamba2-2.7b").reduced()
        m = Model(cfg)
        params = m.init(KEY)
        cache = m.init_cache(2, 16)
        with pytest.raises(NotImplementedError):
            m.decode_step(params, cache, jnp.zeros((2, 1), jnp.int32),
                          active=jnp.ones((2,), bool))


class TestHorizonTokenIdentity:
    """K>1 macro-ticks == K=1 stepping, greedy, per route."""

    def test_contiguous(self):
        eng = _engine()
        reqs = _requests(6)
        ref = eng.generate_continuous(reqs, n_slots=3, max_len=32)
        for K in (2, 4):
            res = eng.generate_continuous(reqs, n_slots=3, max_len=32,
                                          steps_per_tick=K)
            assert res.step_cache_size == 1
            assert res.dispatches < ref.dispatches
            _assert_identical(reqs, ref, res, f"contiguous K={K}")

    def test_paged_gather(self):
        eng = _engine()
        reqs = _requests(6)
        ref = eng.generate_continuous(reqs, n_slots=3, max_len=32,
                                      paged=True, page_size=8)
        res = eng.generate_continuous(reqs, n_slots=3, max_len=32,
                                      paged=True, page_size=8,
                                      steps_per_tick=4)
        assert res.step_cache_size == 1
        _assert_identical(reqs, ref, res, "paged-gather K=4")
        # total live-block traffic must match K=1's accounting
        assert sum(res.step_kv_blocks) == sum(ref.step_kv_blocks)

    def test_paged_pallas(self):
        # f32 so the fused-kernel route is compared at one precision
        # (table10 rationale); tiny dims keep interpret mode fast
        cfg = CFG.replace(vocab_size=256, d_model=96, d_ff=192,
                          n_layers=2, n_heads=4, n_kv_heads=2,
                          head_dim=16, dtype="float32")
        eng = _engine(cfg, decode_backend="pallas")
        reqs = _requests(4, cfg=cfg)
        ref = eng.generate_continuous(reqs, n_slots=2, max_len=32,
                                      paged=True, page_size=8)
        res = eng.generate_continuous(reqs, n_slots=2, max_len=32,
                                      paged=True, page_size=8,
                                      steps_per_tick=4)
        assert res.step_cache_size == 1
        _assert_identical(reqs, ref, res, "paged-pallas K=4")


class TestEosMidHorizon:
    def _eos_for(self, eng, reqs):
        """Pick a token that appears mid-stream in the no-EOS baseline,
        so declaring it EOS forces a mid-horizon trim."""
        base = eng.generate_continuous(reqs, n_slots=3, max_len=32)
        for r in reqs:
            toks = base.tokens_for(r.session_id)
            if len(toks) >= 3:
                return int(toks[1])
        raise AssertionError("no session long enough to donate an EOS")

    def test_trims_exactly_and_matches_k1(self):
        eng = _engine()
        reqs = _requests(6, base_new=5)
        eos = self._eos_for(eng, reqs)
        ref = eng.generate_continuous(reqs, n_slots=3, max_len=32,
                                      eos_id=eos)
        res = eng.generate_continuous(reqs, n_slots=3, max_len=32,
                                      eos_id=eos, steps_per_tick=4)
        _assert_identical(reqs, ref, res, f"eos={eos} K=4")
        trimmed = 0
        for r in reqs:
            toks = res.tokens_for(r.session_id)
            assert len(toks) <= r.max_new_tokens
            # EOS never appears except as the terminator
            hits = np.flatnonzero(toks == eos)
            if hits.size:
                assert hits[0] == len(toks) - 1, "tokens past EOS kept"
                trimmed += 1
        assert trimmed >= 1, "EOS never fired — test is vacuous"

    def test_paged_eos_reclaims_lookahead_pages(self):
        """A session ending on EOS mid-horizon had pages reserved for
        its full granted horizon; eviction must return ALL of them."""
        eng = _engine()
        reqs = _requests(5, base_new=6)
        eos = self._eos_for(eng, reqs)
        sched = SlotScheduler(eng.model, eng.params, n_slots=2,
                              max_len=32, paged=True, page_size=4,
                              steps_per_tick=4, eos_id=eos)
        for r in reqs:
            sched.submit(r)
        res = sched.run()
        assert sched.free_pages == sched.n_pages - 1   # balanced free-list
        assert sched.free_slots == [0, 1]
        ref = eng.generate_continuous(reqs, n_slots=2, max_len=32,
                                      eos_id=eos)
        _assert_identical(reqs, ref, res, "paged eos K=4")


class TestHorizonPagedPressure:
    def test_oversubscribed_identity_and_balance(self):
        """Lookahead reservation under an oversubscribed pool: grants
        shrink / younger sessions get preempted, streams stay identical
        to K=1 contiguous, and every page returns to the free list."""
        eng = _engine()
        reqs = _requests(6)
        ref = eng.generate_continuous(reqs, n_slots=3, max_len=32)
        sched = SlotScheduler(eng.model, eng.params, n_slots=3,
                              max_len=32, paged=True, page_size=8,
                              n_pages=7, steps_per_tick=4)
        for r in reqs:
            sched.submit(r)
        res = sched.run()
        assert res.step_cache_size == 1
        assert sched.free_pages == 6
        _assert_identical(reqs, ref, res, "oversubscribed K=4")

    def test_preemption_round_trips(self):
        """Decode outgrowing the pool mid-macro-tick horizon preempts
        the youngest session; its re-prefilled stream is unchanged."""
        eng = _engine()
        reqs = [SessionRequest("a", np.arange(4) % CFG.vocab_size, 20),
                SessionRequest("b", np.arange(5) % CFG.vocab_size, 20)]
        ref = eng.generate_continuous(reqs, n_slots=2, max_len=32)
        res = eng.generate_continuous(reqs, n_slots=2, max_len=32,
                                      paged=True, page_size=4,
                                      n_pages=1 + 7, steps_per_tick=4)
        assert res.preemptions > 0, "pool was sized to force preemption"
        assert res.step_cache_size == 1
        _assert_identical(reqs, ref, res, "preemption K=4")

    def test_chunked_prefill_interleaves_with_macro_ticks(self):
        eng = _engine()
        reqs = _requests(5)
        ref = eng.generate_continuous(reqs, n_slots=3, max_len=32)
        res = eng.generate_continuous(reqs, n_slots=3, max_len=32,
                                      paged=True, page_size=4,
                                      prefill_chunk=4, steps_per_tick=4)
        assert res.step_cache_size == 1
        _assert_identical(reqs, ref, res, "chunked prefill K=4")


class TestHorizonSchedulerInvariants:
    def test_compiled_once_across_macro_ticks_and_churn(self):
        """Two admission waves through one horizon-4 scheduler: the
        multi-step program must lower exactly once."""
        eng = _engine()
        sched = SlotScheduler(eng.model, eng.params, n_slots=2,
                              max_len=32, steps_per_tick=4)
        for r in _requests(4):
            sched.submit(r)
        sched.run()
        assert sched.step_cache_size() == 1
        for r in _requests(3, base_len=5, base_new=4):
            sched.submit(SessionRequest(r.session_id + "w2", r.prompt,
                                        r.max_new_tokens))
        sched.run()
        assert sched.step_cache_size() == 1

    def test_dispatch_count_amortised(self):
        """Lockstep sessions: decode dispatches shrink by exactly K."""
        eng = _engine()
        reqs = [SessionRequest(f"u{i}",
                               np.asarray(jax.random.randint(
                                   jax.random.fold_in(KEY, i), (6,), 0,
                                   CFG.vocab_size)), 9)
                for i in range(2)]
        ref = eng.generate_continuous(reqs, n_slots=2, max_len=32)
        res = eng.generate_continuous(reqs, n_slots=2, max_len=32,
                                      steps_per_tick=4)
        assert ref.dispatches == 8            # 8 decode tokens each
        assert res.dispatches == 2            # ceil(8 / 4)
        _assert_identical(reqs, ref, res, "lockstep K=4")

    def test_rejects_staged_dispatch(self):
        eng = _engine()
        with pytest.raises(NotImplementedError):
            SlotScheduler(eng.model, eng.params, n_slots=2, max_len=32,
                          steps_per_tick=4, dispatch_mode="stage_jit")

    def test_event_log_replay_with_horizon(self):
        """Occupancy/accounting replay holds under macro-ticks too."""
        eng = _engine()
        sched = SlotScheduler(eng.model, eng.params, n_slots=2,
                              max_len=32, paged=True, page_size=4,
                              n_pages=1 + 7, steps_per_tick=4)
        reqs = [SessionRequest("a", np.arange(4) % CFG.vocab_size, 18),
                SessionRequest("b", np.arange(5) % CFG.vocab_size, 18),
                SessionRequest("c", np.arange(6) % CFG.vocab_size, 6)]
        for r in reqs:
            sched.submit(r)
        res = sched.run()
        occupancy = {}
        for ev in res.events:
            kind, sid, slot = ev[0], ev[1], ev[2]
            if kind == "admit":
                assert slot not in occupancy
                occupancy[slot] = sid
            elif kind in ("finish", "preempt"):
                assert occupancy.pop(slot) == sid
        assert not occupancy
        assert len(res.sessions) == 3

    def test_untimed_run_skips_step_walls(self):
        eng = _engine()
        reqs = _requests(2)
        res = eng.generate_continuous(reqs, n_slots=2, max_len=32,
                                      steps_per_tick=4, timed=False)
        assert all(not s.step_times_s for s in res.sessions.values())
        assert np.isfinite(res.tokens_per_s) and res.tokens_per_s > 0


class TestEngineUnification:
    def test_fused_generation_matches_streamed(self):
        """generate_fused now rides the same multi-step program family
        the scheduler dispatches — still greedy-identical to the
        step-streamed loop."""
        eng = _engine()
        pr = {"tokens": jax.random.randint(KEY, (1, 12), 0,
                                           CFG.vocab_size)}
        r1 = eng.generate_streamed(pr, max_len=48, n_new=6)
        r2 = eng.generate_fused(pr, max_len=48, n_new=6)
        assert jnp.array_equal(r1.tokens, r2.tokens)
