"""Training substrate: optimizer sanity, checkpoint roundtrip,
fault-tolerant restart bit-identity, data-pipeline determinism."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.models import Model
from repro.training import (AdamW, DataLoader, Preemption, cosine_schedule,
                            jit_train_step, make_train_step, restore,
                            run_training, save, synthetic_batch)
from repro.training.data import DataCursor
from repro.training.optimizer import global_norm

KEY = jax.random.PRNGKey(0)
CFG = get_config("internlm2-1.8b").reduced()


def _init(model, opt):
    params = model.init(KEY)
    return (params, opt.init(params))


class TestOptimizer:
    def test_adamw_converges_quadratic(self):
        opt = AdamW(lr=0.1, weight_decay=0.0, clip_norm=None)
        params = {"w": jnp.array([5.0, -3.0])}
        state = opt.init(params)
        for _ in range(200):
            g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            params, state, _ = opt.update(g, state, params)
        assert float(jnp.max(jnp.abs(params["w"]))) < 0.05

    def test_clip_norm(self):
        opt = AdamW(lr=1e-3, clip_norm=1.0)
        params = {"w": jnp.zeros(4)}
        state = opt.init(params)
        g = {"w": jnp.full(4, 100.0)}
        _, _, m = opt.update(g, state, params)
        assert float(m["grad_norm"]) == pytest.approx(200.0, rel=1e-4)

    @given(st.integers(1, 1000))
    @settings(max_examples=20, deadline=None)
    def test_cosine_schedule_bounds(self, step):
        lr = cosine_schedule(1e-3, warmup=50, total=1000)(jnp.int32(step))
        assert 0 < float(lr) <= 1e-3 + 1e-9

    def test_preserves_param_dtype(self):
        opt = AdamW(lr=1e-3)
        params = {"w": jnp.ones(4, jnp.bfloat16)}
        state = opt.init(params)
        new, _, _ = opt.update({"w": jnp.ones(4, jnp.bfloat16)}, state, params)
        assert new["w"].dtype == jnp.bfloat16
        assert state.mu["w"].dtype == jnp.float32


class TestData:
    def test_deterministic_in_cursor(self):
        b1 = synthetic_batch(CFG, DataCursor(3, 17), batch=4, seq_len=16)
        b2 = synthetic_batch(CFG, DataCursor(3, 17), batch=4, seq_len=16)
        assert jnp.array_equal(b1["tokens"], b2["tokens"])

    def test_disjoint_shards(self):
        b0 = synthetic_batch(CFG, DataCursor(0, 0), batch=8, seq_len=16,
                             shard=0, shard_count=2)
        b1 = synthetic_batch(CFG, DataCursor(0, 0), batch=8, seq_len=16,
                             shard=1, shard_count=2)
        assert not jnp.array_equal(b0["tokens"], b1["tokens"])
        assert b0["tokens"].shape == (4, 16)

    def test_labels_are_next_tokens(self):
        b = synthetic_batch(CFG, DataCursor(0, 0), batch=2, seq_len=16)
        assert b["labels"].shape == b["tokens"].shape

    def test_learnable_mode_decreases_loss(self):
        """arith mode has real structure: a few steps must reduce loss."""
        model = Model(CFG)
        opt = AdamW(lr=3e-3)
        step = jit_train_step(make_train_step(model, opt, remat="none"))
        state = _init(model, opt)
        loader = DataLoader(CFG, batch=8, seq_len=32, seed=0, mode="arith")
        losses = []
        for _ in range(30):
            state, m = step(state, next(loader))
            losses.append(float(m["loss"]))
        assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2


class TestCheckpoint:
    def test_roundtrip_bf16(self, tmp_path):
        tree = {"a": jnp.ones((3, 5), jnp.bfloat16) * 1.5,
                "b": {"c": jnp.arange(4, dtype=jnp.int32)}}
        save(str(tmp_path), 7, tree, cursor={"step": 7})
        like = jax.eval_shape(lambda: tree)
        out, manifest = restore(str(tmp_path), like)
        assert manifest["step"] == 7
        assert out["a"].dtype == jnp.bfloat16
        assert jnp.array_equal(out["a"], tree["a"])
        assert jnp.array_equal(out["b"]["c"], tree["b"]["c"])

    def test_keep_last_k(self, tmp_path):
        tree = {"a": jnp.zeros(2)}
        for s in (1, 2, 3, 4, 5):
            save(str(tmp_path), s, tree, keep=2)
        steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
        assert steps == ["step_00000004", "step_00000005"]

    def test_atomic_latest_pointer(self, tmp_path):
        tree = {"a": jnp.zeros(2)}
        save(str(tmp_path), 3, tree)
        from repro.training import latest_step
        assert latest_step(str(tmp_path)) == 3


class TestFaultTolerance:
    def _run(self, ckpt_dir, failure_hook=None, steps=10):
        model = Model(CFG)
        opt = AdamW(lr=1e-3)
        step = jit_train_step(make_train_step(model, opt))
        loader = DataLoader(CFG, batch=4, seq_len=16, seed=5)
        return run_training(
            train_step=step, init_state=lambda: _init(model, opt),
            loader=loader, ckpt_dir=ckpt_dir, total_steps=steps,
            ckpt_every=3, failure_hook=failure_hook)

    def test_restart_bit_identical(self, tmp_path):
        r_clean = self._run(str(tmp_path / "a"))
        armed = {"on": True}

        def boom(step):
            if step == 5 and armed["on"]:
                armed["on"] = False
                raise Preemption(step)
        r_faulty = self._run(str(tmp_path / "b"), failure_hook=boom)
        assert r_faulty.restarts == 1
        assert (r_clean.metrics_history[-1]["loss"]
                == r_faulty.metrics_history[-1]["loss"])

    def test_gives_up_after_max_restarts(self, tmp_path):
        def always_boom(step):
            raise Preemption(step)
        with pytest.raises(Preemption):
            self._run(str(tmp_path / "c"), failure_hook=always_boom)


def test_grad_compression_trains():
    model = Model(CFG)
    opt = AdamW(lr=1e-3)
    step = jit_train_step(make_train_step(model, opt, grad_compression="int8"))
    state = _init(model, opt)
    loader = DataLoader(CFG, batch=4, seq_len=16, seed=1)
    for _ in range(3):
        state, m = step(state, next(loader))
    assert jnp.isfinite(m["loss"])


def test_microbatching_matches_full_batch_grads():
    """Gradient accumulation == full-batch gradients (linearity)."""
    model = Model(CFG)
    loss = lambda p, b: model.loss(p, b)[0]
    params = model.init(KEY)
    batch = synthetic_batch(CFG, DataCursor(0, 0), batch=8, seq_len=16)
    g_full = jax.grad(loss)(params, batch)

    def split(x):
        return x.reshape(4, 2, *x.shape[1:])
    mb = jax.tree_util.tree_map(split, batch)
    g_acc = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    for i in range(4):
        bi = jax.tree_util.tree_map(lambda x: x[i], mb)
        gi = jax.grad(loss)(params, bi)
        g_acc = jax.tree_util.tree_map(lambda a, g: a + g.astype(jnp.float32) / 4,
                                       g_acc, gi)
    n_full, n_acc = global_norm(g_full), global_norm(g_acc)
    assert float(jnp.abs(n_full - n_acc) / n_full) < 0.02
