"""scripts/bench_trajectory.py (the CI artifact merger) and the
benchmarks/run.py registry self-audit — both CI-load-bearing, both
previously untested."""
import importlib.util
import json
import os
import sys

import pytest

_SPEC = importlib.util.spec_from_file_location(
    "bench_trajectory",
    os.path.join(os.path.dirname(__file__), os.pardir, "scripts",
                 "bench_trajectory.py"))
bench_trajectory = importlib.util.module_from_spec(_SPEC)
_SPEC.loader.exec_module(bench_trajectory)


def report(tables, failed=(), quick=True):
    return {"quick": quick, "only": None,
            "tables": {name: {"ok": name not in failed, "seconds": 0.1,
                              "rows": rows}
                       for name, rows in tables.items()},
            "failed": list(failed)}


def write_report(path, tables, failed=()):
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(report(tables, failed)))
    return str(path)


# ---------------------------------------------------------------- collect
def test_collect_mixes_files_and_artifact_dirs(tmp_path):
    f1 = write_report(tmp_path / "bench_table9.json", {"table9": []})
    # artifact-download layout: nested per-job directories
    f2 = write_report(
        tmp_path / "artifacts" / "job-1" / "bench_table10.json",
        {"table10": []})
    f3 = write_report(
        tmp_path / "artifacts" / "job-2" / "bench_table11.json",
        {"table11": []})
    got = bench_trajectory.collect([f1, str(tmp_path / "artifacts")])
    assert got == [f1, f2, f3]


# ------------------------------------------------------------------ merge
def test_merge_unions_tables_and_failures(tmp_path):
    f1 = write_report(tmp_path / "a" / "bench_table9.json",
                      {"table9": [{"name": "x"}]})
    f2 = write_report(tmp_path / "b" / "bench_table10.json",
                      {"table10": [{"name": "y"}]}, failed=["table10"])
    snap = bench_trajectory.merge([f1, f2])
    assert set(snap["tables"]) == {"table9", "table10"}
    assert snap["sources"]["table9"] == f1
    assert snap["failed"] == ["table10"]


def test_merge_duplicate_table_keeps_later_file(tmp_path, capsys):
    f1 = write_report(tmp_path / "a" / "bench_table9.json",
                      {"table9": [{"name": "old"}]})
    f2 = write_report(tmp_path / "b" / "bench_table9.json",
                      {"table9": [{"name": "new"}]})
    snap = bench_trajectory.merge([f1, f2])
    assert snap["tables"]["table9"]["rows"] == [{"name": "new"}]
    assert snap["sources"]["table9"] == f2
    assert "in both" in capsys.readouterr().err


# ------------------------------------------------------------------- main
def run_main(monkeypatch, tmp_path, argv):
    monkeypatch.chdir(tmp_path)
    monkeypatch.setattr(sys, "argv", ["bench_trajectory.py"] + argv)
    return bench_trajectory.main()


def test_main_exit_2_on_empty(monkeypatch, tmp_path, capsys):
    assert run_main(monkeypatch, tmp_path, []) == 2
    assert "nothing to merge" in capsys.readouterr().err
    assert not list(tmp_path.glob("BENCH_*.json"))


def test_main_writes_dated_snapshot(monkeypatch, tmp_path):
    write_report(tmp_path / "bench_table9.json",
                 {"table9": [{"name": "x"}, {"name": "y"}]})
    out = tmp_path / "snaps"
    out.mkdir()
    assert run_main(monkeypatch, tmp_path,
                    ["--date", "2026-08-09", "--out", str(out)]) == 0
    snap = json.loads((out / "BENCH_2026-08-09.json").read_text())
    assert snap["date"] == "2026-08-09"
    assert len(snap["tables"]["table9"]["rows"]) == 2
    assert snap["failed"] == []


def test_main_exit_1_on_failed_tables(monkeypatch, tmp_path):
    write_report(tmp_path / "bench_table16.json", {"table16": []},
                 failed=["table16"])
    assert run_main(monkeypatch, tmp_path,
                    ["--date", "2026-08-09"]) == 1
    # the snapshot is still written — a failed table is data, not noise
    snap = json.loads((tmp_path / "BENCH_2026-08-09.json").read_text())
    assert snap["failed"] == ["table16"]


# -------------------------------------------------- run.py registry audit
run_mod = pytest.importorskip("benchmarks.run")


def test_registry_audit_clean_on_repo():
    assert run_mod.registry_audit(description_names=run_mod.DESCRIPTIONS)\
        == []


def test_registry_audit_reports_each_drift(tmp_path):
    (tmp_path / "table1_thing.py").touch()
    (tmp_path / "table2_other.py").touch()
    (tmp_path / "common.py").touch()          # non-table module: ignored
    problems = run_mod.registry_audit(
        suite_names={"table1", "table3"},
        description_names={"table1", "table3"},
        module_dir=str(tmp_path))
    # table2 on disk but undescribed; table3 described but no module
    assert len(problems) == 2
    assert any(p.startswith("table2:") and "DESCRIPTIONS" in p
               for p in problems)
    assert any(p.startswith("table3:") for p in problems)

    problems = run_mod.registry_audit(
        suite_names={"table1"},
        description_names={"table1", "table2"},
        module_dir=str(tmp_path))
    # table2 described but not registered as a suite
    assert any("not in the suites registry" in p for p in problems)

    problems = run_mod.registry_audit(
        suite_names={"table1", "table2", "tableX"},
        description_names={"table1", "table2"},
        module_dir=str(tmp_path))
    assert any(p.startswith("tableX:") and "no --list description" in p
               for p in problems)


def test_run_list_exits_zero_and_prints_registry(monkeypatch, capsys):
    monkeypatch.setattr(sys, "argv", ["run.py", "--list"])
    run_mod.main()          # would sys.exit(2) on registry drift
    out = capsys.readouterr().out
    for name in run_mod.DESCRIPTIONS:
        assert name in out
