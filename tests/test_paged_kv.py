"""Paged KV cache: slot -> block-table -> page-pool indirection.

Token identity is the contract: the same session mix served through the
paged scheduler — at full backing, oversubscribed, chunk-prefilled, or
preempted — must emit exactly the tokens the contiguous slotted
scheduler emits, with the paged decode step compiled exactly once
through churn, page exhaustion, and reclaim.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model
from repro.models import attention as attn
from repro.serving import (BlockAllocator, DecodeEngine, SessionRequest,
                           SlotScheduler, jit_cache_size)

KEY = jax.random.PRNGKey(11)
CFG = get_config("qwen2.5-3b").reduced()


def _engine(cfg=CFG):
    m = Model(cfg)
    return DecodeEngine(m, m.init(KEY))


def _requests(n, cfg=CFG, base_len=4, base_new=3):
    """n sessions with mixed prompt lengths and token budgets."""
    reqs = []
    for i in range(n):
        k = jax.random.fold_in(KEY, 100 + i)
        prompt = np.asarray(
            jax.random.randint(k, (base_len + 2 * i,), 0, cfg.vocab_size))
        reqs.append(SessionRequest(f"s{i}", prompt, base_new + i % 4))
    return reqs


class TestBlockAllocator:
    def test_free_list_lifecycle(self):
        a = BlockAllocator(5)          # page 0 reserved
        assert a.n_free == 4
        got = a.alloc(3)
        assert len(got) == 3 and 0 not in got
        assert a.n_free == 1
        assert a.alloc(2) is None      # under-supplied: no change
        assert a.n_free == 1
        a.release(got)
        assert a.n_free == 4

    def test_garbage_page_never_handed_out(self):
        a = BlockAllocator(4)
        assert sorted(a.alloc(3)) == [1, 2, 3]

    def test_double_free_rejected(self):
        a = BlockAllocator(4)
        (p,) = a.alloc(1)
        a.release([p])
        with pytest.raises(AssertionError):
            a.release([p])


class TestPagedCache:
    def test_layout(self):
        m = Model(CFG)
        cache = m.init_cache(3, 32, paged=True, page_size=8)
        L, n_pages, page, hkv, hd = cache["k"].shape
        assert (L, page, hkv, hd) == (CFG.n_layers, 8, CFG.n_kv_heads,
                                      CFG.head_dim)
        assert n_pages == 1 + 3 * 4            # garbage + full backing
        assert cache["block_table"].shape == (3, 4)
        assert cache["pos"].shape == (3,)

    def test_oversubscribed_pool_shrinks_memory(self):
        m = Model(CFG)
        full = m.init_cache(4, 64, paged=True, page_size=8)
        over = m.init_cache(4, 64, paged=True, page_size=8, n_pages=9)
        assert over["k"].size < full["k"].size / 3

    def test_int8_paged_adds_scale_pools(self):
        # paged + int8 is now supported: scale slabs ride parallel pools
        # sharing the block table (one f32 scale per (token, head))
        cache = Model(CFG).init_cache(3, 32, paged=True, page_size=8,
                                      kv_dtype=jnp.int8)
        assert cache["k"].dtype == jnp.int8
        assert cache["k_scale"].shape == cache["k"].shape[:-1]
        assert cache["k_scale"].dtype == jnp.float32
        assert cache["v_scale"].shape == cache["v"].shape[:-1]

    def test_rejects_unsupported(self):
        with pytest.raises(ValueError):
            Model(CFG).init_cache(2, 32, paged=True, kv_quant="fp4")
        with pytest.raises(NotImplementedError):
            Model(CFG.replace(sliding_window=8)).init_cache(2, 32, paged=True)
        with pytest.raises(NotImplementedError):
            Model(get_config("mamba2-2.7b").reduced()).init_cache(
                2, 32, paged=True)

    def test_step_program_rejects_paged(self):
        m = Model(CFG)
        params = m.init(KEY)
        cache = m.init_cache(2, 32, paged=True, page_size=8)
        with pytest.raises(NotImplementedError):
            m.step_program(params, cache)

    def test_paged_view_gathers_block_table(self):
        pool = jnp.arange(5 * 2 * 1 * 1, dtype=jnp.float32).reshape(5, 2, 1, 1)
        bt = jnp.array([[3, 1], [0, 0]], jnp.int32)
        view = np.asarray(attn.paged_view(pool, bt))
        assert view.shape == (2, 4, 1, 1)
        np.testing.assert_array_equal(view[0, :, 0, 0], [6, 7, 2, 3])
        np.testing.assert_array_equal(view[1, :, 0, 0], [0, 1, 0, 1])


class TestPagedPrefill:
    def _paged_cache(self, m, n_slots=2, max_len=32, page=8):
        cache = m.init_cache(n_slots, max_len, paged=True, page_size=page)
        bt = np.zeros((n_slots, -(-max_len // page)), np.int32)
        bt[0] = np.arange(1, bt.shape[1] + 1)      # slot 0 fully backed
        cache["block_table"] = jnp.asarray(bt)
        return cache

    def test_whole_prompt_matches_contiguous_prefill(self):
        m = Model(CFG)
        params = m.init(KEY)
        toks = jax.random.randint(KEY, (1, 11), 0, CFG.vocab_size)
        cache = self._paged_cache(m)
        lp, cache = m.prefill_into_slot(params, {"tokens": toks}, cache,
                                        jnp.int32(0))
        ref = m.init_cache(2, 32, slotted=True)
        lr, _ = m.prefill_into_slot(params, {"tokens": toks}, ref,
                                    jnp.int32(0))
        np.testing.assert_allclose(np.asarray(lp, np.float32),
                                   np.asarray(lr, np.float32), atol=2e-2)
        np.testing.assert_array_equal(np.asarray(cache["pos"]), [11, 0])

    def test_chunked_equals_whole_prompt(self):
        """Feeding a prompt chunk-by-chunk (page-aligned chunks) must
        reproduce the one-shot prefill bit-for-bit: same last-position
        logits, same pool contents, same positions."""
        m = Model(CFG)
        params = m.init(KEY)
        toks = jax.random.randint(jax.random.fold_in(KEY, 7), (1, 19), 0,
                                  CFG.vocab_size)
        c1 = self._paged_cache(m)
        l1, c1 = m.prefill_into_slot(params, {"tokens": toks}, c1,
                                     jnp.int32(0))
        c2 = self._paged_cache(m)
        for start in (0, 8, 16):
            chunk = toks[:, start:start + 8]
            l2, c2 = m.prefill_chunk_into_slot(params, {"tokens": chunk},
                                               c2, jnp.int32(0),
                                               jnp.int32(start))
        np.testing.assert_allclose(np.asarray(l1, np.float32),
                                   np.asarray(l2, np.float32), atol=1e-5)
        np.testing.assert_array_equal(np.asarray(c1["pos"]),
                                      np.asarray(c2["pos"]))
        np.testing.assert_allclose(np.asarray(c1["k"], np.float32),
                                   np.asarray(c2["k"], np.float32),
                                   atol=1e-5)


class TestPagedEquivalence:
    def _contiguous_ref(self, eng, reqs, n_slots=3, max_len=32):
        return eng.generate_continuous(reqs, n_slots=n_slots,
                                       max_len=max_len)

    def test_full_backing_matches_contiguous(self):
        eng = _engine()
        reqs = _requests(6)
        ref = self._contiguous_ref(eng, reqs)
        res = eng.generate_continuous(reqs, n_slots=3, max_len=32,
                                      paged=True, page_size=8)
        assert res.step_cache_size == 1
        assert res.preemptions == 0
        for r in reqs:
            np.testing.assert_array_equal(
                ref.tokens_for(r.session_id), res.tokens_for(r.session_id),
                err_msg=f"{r.session_id} diverged under paging")

    def test_oversubscribed_pool_token_identity(self):
        """The acceptance case: a pool holding fewer tokens than the
        contiguous n_slots*max_len reservation serves a workload whose
        summed KV footprint exceeds the pool — eviction reclaim keeps it
        flowing — and the greedy streams are identical."""
        eng = _engine()
        reqs = _requests(6)
        n_slots, max_len, page, n_pages = 3, 32, 8, 7
        pool_tokens = (n_pages - 1) * page
        assert pool_tokens < n_slots * max_len          # oversubscribed
        footprint = sum(len(r.prompt) + r.max_new_tokens - 1 for r in reqs)
        assert footprint > pool_tokens                  # needs reclaim
        ref = self._contiguous_ref(eng, reqs)
        res = eng.generate_continuous(reqs, n_slots=n_slots,
                                      max_len=max_len, paged=True,
                                      page_size=page, n_pages=n_pages)
        assert res.step_cache_size == 1
        for r in reqs:
            np.testing.assert_array_equal(
                ref.tokens_for(r.session_id), res.tokens_for(r.session_id),
                err_msg=f"{r.session_id} diverged oversubscribed")

    def test_chunked_prefill_token_identity(self):
        eng = _engine()
        reqs = _requests(5)
        ref = self._contiguous_ref(eng, reqs)
        res = eng.generate_continuous(reqs, n_slots=3, max_len=32,
                                      paged=True, page_size=4,
                                      prefill_chunk=4)
        assert res.step_cache_size == 1
        for r in reqs:
            np.testing.assert_array_equal(
                ref.tokens_for(r.session_id), res.tokens_for(r.session_id),
                err_msg=f"{r.session_id} diverged chunk-prefilled")

    def test_preemption_token_identity(self):
        """Decode outgrowing the pool preempts the youngest session
        (pages reclaimed, session requeued + re-prefilled from prompt +
        generated prefix); its stream must be unchanged."""
        eng = _engine()
        reqs = [SessionRequest("a", np.arange(4) % CFG.vocab_size, 20),
                SessionRequest("b", np.arange(5) % CFG.vocab_size, 20)]
        ref = eng.generate_continuous(reqs, n_slots=2, max_len=32)
        res = eng.generate_continuous(reqs, n_slots=2, max_len=32,
                                      paged=True, page_size=4,
                                      n_pages=1 + 7)
        assert res.preemptions > 0, "pool was sized to force preemption"
        assert res.step_cache_size == 1
        for r in reqs:
            np.testing.assert_array_equal(
                ref.tokens_for(r.session_id), res.tokens_for(r.session_id),
                err_msg=f"{r.session_id} diverged through preemption")

    def test_compiled_once_through_churn_and_reclaim(self):
        """Two admission waves through one oversubscribed paged
        scheduler: exhaustion, reclaim, backfill — and still exactly one
        compiled decode step."""
        eng = _engine()
        sched = SlotScheduler(eng.model, eng.params, n_slots=2, max_len=32,
                              paged=True, page_size=8, n_pages=5)
        for r in _requests(4):
            sched.submit(r)
        sched.run()
        assert sched.step_cache_size() == 1
        for r in _requests(3, base_len=5, base_new=4):
            sched.submit(SessionRequest(r.session_id + "w2", r.prompt,
                                        r.max_new_tokens))
        sched.run()
        assert sched.step_cache_size() == 1
        assert sched.free_pages == 4           # everything reclaimed
        assert sched.free_slots == [0, 1]


class TestPagedSchedulerInvariants:
    def test_admission_gated_on_free_pages(self):
        """Two sessions that cannot coexist in the pool are serialised:
        the second admits only after the first's pages are reclaimed."""
        eng = _engine()
        reqs = [SessionRequest("a", np.arange(16) % CFG.vocab_size, 5),
                SessionRequest("b", np.arange(16) % CFG.vocab_size, 5)]
        sched = SlotScheduler(eng.model, eng.params, n_slots=2, max_len=32,
                              paged=True, page_size=4, n_pages=1 + 5)
        for r in reqs:
            sched.submit(r)
        res = sched.run()
        a, b = res.sessions["a"], res.sessions["b"]
        assert b.admitted_tick >= a.finished_tick
        assert res.preemptions == 0            # gating, not preemption

    def test_submit_rejects_session_larger_than_pool(self):
        eng = _engine()
        sched = SlotScheduler(eng.model, eng.params, n_slots=1, max_len=32,
                              paged=True, page_size=4, n_pages=3)
        with pytest.raises(AssertionError):
            sched.submit(SessionRequest("x", np.arange(8), 8))

    def test_event_log_replay(self):
        """Replaying admit/preempt/finish, occupancy and page accounting
        stay consistent (a preempted session's re-admit is legal)."""
        eng = _engine()
        sched = SlotScheduler(eng.model, eng.params, n_slots=2, max_len=32,
                              paged=True, page_size=4, n_pages=1 + 7)
        reqs = [SessionRequest("a", np.arange(4) % CFG.vocab_size, 18),
                SessionRequest("b", np.arange(5) % CFG.vocab_size, 18),
                SessionRequest("c", np.arange(6) % CFG.vocab_size, 6)]
        for r in reqs:
            sched.submit(r)
        res = sched.run()
        occupancy = {}
        for ev in res.events:
            kind, sid, slot = ev[0], ev[1], ev[2]
            if kind == "admit":
                assert slot not in occupancy
                occupancy[slot] = sid
            elif kind in ("finish", "preempt"):
                assert occupancy.pop(slot) == sid
        assert not occupancy
        assert len(res.sessions) == 3

    def test_paged_requires_full_jit(self):
        eng = _engine()
        with pytest.raises(NotImplementedError):
            SlotScheduler(eng.model, eng.params, n_slots=2, max_len=32,
                          paged=True, dispatch_mode="stage_jit")

    def test_prefill_chunk_must_be_page_aligned(self):
        eng = _engine()
        with pytest.raises(AssertionError):
            SlotScheduler(eng.model, eng.params, n_slots=2, max_len=32,
                          paged=True, page_size=8, prefill_chunk=12)


class TestJitCacheSize:
    """The recompile guard must not crash on jax versions that drop the
    private ``_cache_size`` hook — it degrades to None (= unknown)."""

    def test_counts_compiled_executables(self):
        f = jax.jit(lambda x: x + 1)
        f(jnp.ones((2,)))
        assert jit_cache_size(f) in (1, None)

    def test_degrades_to_none_without_the_hook(self):
        assert jit_cache_size(object()) is None
        assert jit_cache_size(lambda x: x) is None
