import os

# Tests run single-device (the dry-run sets its own 512-device flag in a
# subprocess); keep XLA quiet and deterministic.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_threefry_partitionable", True)


# ---------------------------------------------------------------------------
# hypothesis fallback shim
#
# The property tests use a small slice of the hypothesis API
# (@given/@settings + integers/floats/sampled_from/lists strategies).
# When the real package is absent we degrade gracefully: each @given test
# runs against a deterministic fixed set of examples — the strategy's
# boundary values first, then seeded pseudo-random draws — instead of
# failing at collection.  With hypothesis installed this block is a no-op.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:
    import random
    import sys
    import types

    _DEFAULT_EXAMPLES = 6
    _MAX_EXAMPLES_CAP = 12

    class _Strategy:
        """A strategy = boundary examples + a seeded random draw."""

        def __init__(self, draw, edges=()):
            self._draw = draw
            self._edges = tuple(edges)

        def example_at(self, i, rng):
            if i < len(self._edges):
                return self._edges[i]
            return self._draw(rng)

    def _integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value),
                         (min_value, max_value))

    def _floats(min_value, max_value, **_kw):
        return _Strategy(lambda r: r.uniform(min_value, max_value),
                         (min_value, max_value))

    def _sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda r: r.choice(elements), elements)

    def _lists(elem, *, min_size=0, max_size=10, **_kw):
        def draw(r):
            n = r.randint(min_size, max_size)
            return [elem._draw(r) for _ in range(n)]
        return _Strategy(draw)

    def _settings(max_examples=None, deadline=None, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def _given(*pos_strats, **kw_strats):
        def deco(fn):
            n = getattr(fn, "_shim_max_examples", None) or _DEFAULT_EXAMPLES
            n = min(n, _MAX_EXAMPLES_CAP)

            def wrapper(*args, **kwargs):
                rng = random.Random(0xC0FFEE)
                for i in range(n):
                    pos = tuple(s.example_at(i, rng) for s in pos_strats)
                    kws = {k: s.example_at(i, rng)
                           for k, s in kw_strats.items()}
                    fn(*args, *pos, **kws, **kwargs)
            # NOT functools.wraps: __wrapped__ would make pytest resolve
            # the original signature and demand fixtures for the
            # strategy-filled parameters
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper
        return deco

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = _integers
    _st.floats = _floats
    _st.sampled_from = _sampled_from
    _st.lists = _lists
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st
