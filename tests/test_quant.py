"""Quantisation-path tests: the paper's §7 invariants — dequant paths
never save traffic, fused paths do; numerics ordered int8 < int4."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import floor as fl
from repro.models import Model
from repro.quant import (dequantize, quantize,
                         quantize_tree, tree_weight_traffic)

KEY = jax.random.PRNGKey(7)


def test_traffic_ordering_is_the_papers_lesson():
    """fused int4 < fused int8 < bf16 < int4_dequant/int8_dequant:
    the dequant paths stream MORE than bf16 (Table 7's bnb-nf4 trap)."""
    cfg = get_config("qwen2.5-3b").reduced()
    params = Model(cfg).init(KEY)
    t = {p: tree_weight_traffic(quantize_tree(params, p, group=64))
         for p in ("bf16", "int8_dequant", "int8_fused",
                   "int4_dequant", "int4_fused")}
    assert t["int4_fused"] < t["int8_fused"] < t["bf16"]
    assert t["int8_dequant"] > t["bf16"]
    assert t["int4_dequant"] > t["bf16"]


def test_fused_int4_traffic_close_to_quarter():
    cfg = get_config("internlm2-1.8b").reduced()
    params = Model(cfg).init(KEY)
    bf16 = tree_weight_traffic(params)
    q4 = tree_weight_traffic(quantize_tree(params, "int4_fused", group=32))
    # not all leaves quantise (embeddings, norms) — expect 0.25..0.8
    assert 0.2 * bf16 < q4 < 0.8 * bf16


def test_quant_numerics_ordering():
    w = jax.random.normal(KEY, (256, 128), jnp.float32)
    e8 = float(jnp.mean(jnp.abs(dequantize(quantize(w, 8, 64), jnp.float32) - w)))
    e4 = float(jnp.mean(jnp.abs(dequantize(quantize(w, 4, 64), jnp.float32) - w)))
    assert e8 < e4 < float(jnp.mean(jnp.abs(w)))


def test_dequant_vs_fused_same_math():
    """The two paths differ ONLY in traffic, not semantics."""
    cfg = get_config("olmo-1b").reduced()
    m = Model(cfg)
    params = m.init(KEY)
    tokens = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
    outs = {}
    for path in ("int8_dequant", "int8_fused"):
        qp = quantize_tree(params, path, group=64)
        outs[path], _ = m.forward(qp, {"tokens": tokens})
    err = float(jnp.max(jnp.abs(
        outs["int8_dequant"].astype(jnp.float32)
        - outs["int8_fused"].astype(jnp.float32))))
    assert err < 0.05


def test_stacked_quantized_tensor_slices_in_scan():
    """lax.scan over a stacked QuantizedTensor yields valid per-layer
    tensors (derived metadata stays consistent)."""
    w = jax.random.normal(KEY, (4, 64, 32), jnp.float32)   # (L, K, N)
    qt = quantize(w, 4, 32)
    assert qt.shape == (4, 64, 32)

    def body(c, layer_qt):
        assert layer_qt.shape == (64, 32)
        assert layer_qt.group == 32
        return c, dequantize(layer_qt, jnp.float32)

    _, ws = jax.lax.scan(body, 0, qt)
    assert ws.shape == (4, 64, 32)
    ref = dequantize(qt, jnp.float32)
    assert jnp.allclose(ws, ref, atol=1e-6)


def test_quantized_decode_all_paths_finite():
    cfg = get_config("qwen2-moe-a2.7b").reduced()
    m = Model(cfg)
    params = m.init(KEY)
    tokens = jax.random.randint(KEY, (1, 8), 0, cfg.vocab_size)
    for path in ("int8_fused", "int4_fused", "int4_dequant"):
        qp = quantize_tree(params, path, group=32)
        cache = m.init_cache(1, 16)
        _, cache = m.prefill(qp, {"tokens": tokens}, cache)
        ld, _ = jax.jit(m.decode_step)(qp, cache, tokens[:, :1])
        assert bool(jnp.all(jnp.isfinite(ld.astype(jnp.float32)))), path


def test_floor_model_quant_paths():
    """Floor with int4 weights = paper's 4x-reduced floor."""
    q = get_config("qwen2.5-7b")
    from repro.core.hardware import GPU_L4
    f_bf16 = fl.floor_cell(q, GPU_L4, 2048, weight_dtype_bytes=2).t_floor_ms
    f_int4 = fl.floor_cell(q, GPU_L4, 2048, weight_dtype_bytes=0.5).t_floor_ms
    assert f_bf16 == pytest.approx(51.17, rel=0.01)   # paper Table 7
    assert f_int4 == pytest.approx(13.09, rel=0.01)   # paper Table 7
