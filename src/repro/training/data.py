"""Deterministic synthetic data pipeline with an explicit cursor.

Multi-host discipline without multi-host hardware: every batch is a pure
function of (seed, step, shard) — so (a) restarts resume bit-identically
from a checkpointed cursor, (b) each data-parallel shard draws a disjoint
stream (process_index/shard_count mirror jax.process_* in a real fleet),
and (c) elastic re-sharding re-partitions the same global stream.

The token stream is a counter-mode threefry draw shaped like an LM batch;
labels are next-token shifted.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig


@dataclasses.dataclass
class DataCursor:
    seed: int
    step: int

    def to_json(self):
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_json(cls, d):
        return cls(int(d["seed"]), int(d["step"]))


def synthetic_batch(cfg: ArchConfig, cursor: DataCursor, *, batch: int,
                    seq_len: int, shard: int = 0, shard_count: int = 1,
                    mode: str = "uniform") -> Dict[str, jnp.ndarray]:
    """Pure function of (seed, step, shard): a (tokens, labels) LM batch.

    mode="uniform": i.i.d. tokens (throughput benchmarking; loss pins at
    ln V).  mode="arith": deterministic affine stream
    x_{t+1} = (a*x_t + c) mod V from a random x_0 — learnable structure
    so examples/tests can assert the loss actually falls.
    """
    assert batch % shard_count == 0
    b_local = batch // shard_count
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(cursor.seed), cursor.step), shard)
    shape = ((b_local, seq_len + 1, cfg.n_codebooks) if cfg.n_codebooks
             else (b_local, seq_len + 1))
    if mode == "arith" and not cfg.n_codebooks:
        x0 = jax.random.randint(key, (b_local,), 0, cfg.vocab_size, jnp.int32)
        a, c = 5, 17

        def step(x, _):
            nxt = (a * x + c) % cfg.vocab_size
            return nxt, x
        _, seq = jax.lax.scan(step, x0, None, length=seq_len + 1)
        toks = jnp.moveaxis(seq, 0, 1)
    else:
        toks = jax.random.randint(key, shape, 0, cfg.vocab_size, dtype=jnp.int32)
    batch_d = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.family == "vlm":
        ke = jax.random.fold_in(key, 1)
        batch_d["embeds"] = jax.random.normal(
            ke, (b_local, seq_len, cfg.d_model), jnp.bfloat16)
        batch_d["positions"] = jnp.broadcast_to(
            jnp.arange(seq_len)[None, :, None], (b_local, seq_len, 3))
        batch_d.pop("tokens")
    return batch_d


class DataLoader:
    """Stateful iterator over the deterministic stream, with a
    checkpointable cursor."""

    def __init__(self, cfg: ArchConfig, *, batch: int, seq_len: int,
                 seed: int = 0, shard: int = 0, shard_count: int = 1,
                 start_step: int = 0, mode: str = "uniform"):
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.shard = shard
        self.shard_count = shard_count
        self.mode = mode
        self.cursor = DataCursor(seed, start_step)

    def __iter__(self) -> Iterator[Dict[str, jnp.ndarray]]:
        return self

    def __next__(self) -> Dict[str, jnp.ndarray]:
        b = synthetic_batch(self.cfg, self.cursor, batch=self.batch,
                            seq_len=self.seq_len, shard=self.shard,
                            shard_count=self.shard_count, mode=self.mode)
        self.cursor = DataCursor(self.cursor.seed, self.cursor.step + 1)
        return b
