"""Fault-tolerant training driver: checkpoint/restart, preemption
handling, elastic re-mesh.

``run_training`` is the production loop shape for a 1000-node fleet:

  restore-or-init -> step loop -> periodic atomic checkpoint
      -> on failure/preemption: restore latest + replay data cursor

Failure injection (``failure_hook``) lets tests kill the loop at an
arbitrary step and assert bit-identical recovery: the data pipeline is a
pure function of its cursor, the optimizer state is checkpointed, so a
restarted run reproduces the uninterrupted loss curve exactly.

Elastic re-mesh: restore() re-device_puts host-side leaves with the
*current* mesh's shardings, so the same checkpoint drives a 256-chip or
512-chip restart (tests exercise 1-device -> 4-device fake meshes).
"""
from __future__ import annotations

import dataclasses
import logging
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.training import checkpoint as ckpt
from repro.training.data import DataLoader

log = logging.getLogger("repro.fault_tolerance")


class Preemption(RuntimeError):
    """Simulated SIGTERM from the cluster scheduler."""


@dataclasses.dataclass
class TrainRunResult:
    step: int
    metrics_history: List[Dict[str, float]]
    restarts: int


def run_training(*, train_step: Callable, init_state: Callable[[], Any],
                 loader: DataLoader, ckpt_dir: str, total_steps: int,
                 ckpt_every: int = 50, keep: int = 3,
                 state_shardings: Any = None,
                 failure_hook: Optional[Callable[[int], None]] = None,
                 max_restarts: int = 3) -> TrainRunResult:
    """The fault-tolerant loop.  ``train_step(state, batch)`` must be the
    compiled program; ``init_state()`` builds a fresh (params, opt_state).
    """
    restarts = 0
    history: List[Dict[str, float]] = []

    while True:
        try:
            # ---- restore or init ----
            state = init_state()
            start = 0
            if ckpt.latest_step(ckpt_dir) is not None:
                state, manifest = ckpt.restore(
                    ckpt_dir, jax.eval_shape(lambda: state),
                    shardings=state_shardings)
                start = manifest["step"]
                loader.cursor.step = manifest["cursor"].get("step", start)
                log.info("restored checkpoint at step %d", start)
            loader.cursor.step = start

            # ---- step loop ----
            for step in range(start, total_steps):
                if failure_hook is not None:
                    failure_hook(step)     # may raise Preemption
                batch = next(loader)
                state, metrics = train_step(state, batch)
                history.append({k: float(v) for k, v in metrics.items()})
                if (step + 1) % ckpt_every == 0 or step + 1 == total_steps:
                    jax.block_until_ready(state)
                    ckpt.save(ckpt_dir, step + 1, state,
                              cursor={"step": step + 1}, keep=keep)
            return TrainRunResult(total_steps, history, restarts)

        except Preemption as e:
            restarts += 1
            log.warning("preempted at %s (restart %d/%d)", e, restarts, max_restarts)
            if restarts > max_restarts:
                raise
            # fall through: loop restarts from latest checkpoint
