"""Train-step factory: loss -> grads -> AdamW, with remat policies,
microbatch gradient accumulation (lax.scan), and donated buffers.

The step is ONE compiled program (the paper's own lesson applied to
training: zero per-step host dispatch beyond the single launch).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.model import Model
from repro.training.optimizer import AdamW, AdamWState

REMAT_POLICIES = ("none", "blocks", "full")


def make_loss_fn(model: Model, *, remat: str = "none", aux_weight: float = 0.01):
    """remat is applied at the scan-BODY level inside the model (see
    Model._maybe_remat): wrapping the whole loss in jax.checkpoint does
    not shrink scan residuals, block-level checkpointing does."""
    model.remat = remat if remat in ("blocks", "full") else "none"
    return lambda p, b: model.loss(p, b, aux_weight=aux_weight)


def make_train_step(model: Model, opt: AdamW, *, remat: str = "blocks",
                    microbatches: int = 1, aux_weight: float = 0.01,
                    grad_compression: Optional[str] = None
                    ) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics) where
    state = (params, opt_state).

    microbatches > 1: gradient accumulation via lax.scan (batch axis is
    split host-side-invisible, inside the compiled program).
    grad_compression="int8": stochastic-free symmetric int8 quantisation
    of gradients before the (pseudo-)all-reduce — at scale this halves
    gradient collective bytes 4x; on one program it is a numerics knob.
    """
    loss_fn = make_loss_fn(model, remat=remat, aux_weight=aux_weight)

    def compress(g):
        if grad_compression != "int8":
            return g

        def q(x):
            s = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
            return (jnp.round(x / s).astype(jnp.int8).astype(jnp.float32) * s
                    ).astype(x.dtype)
        return jax.tree_util.tree_map(q, g)

    def grads_of(params, batch):
        (l, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return g, metrics

    def train_step(state: Tuple[Any, AdamWState], batch: Dict):
        params, opt_state = state
        if microbatches == 1:
            grads, metrics = grads_of(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])
            mb = jax.tree_util.tree_map(split, batch)

            def body(acc, mbi):
                g, m = grads_of(params, mbi)
                return jax.tree_util.tree_map(jnp.add, acc, g), m
            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            grads, metrics = jax.lax.scan(body, zeros, mb)
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)
            metrics = jax.tree_util.tree_map(lambda m: m[-1], metrics)
        grads = compress(grads)
        params, opt_state, opt_metrics = opt.update(grads, opt_state, params)
        return (params, opt_state), {**metrics, **opt_metrics}

    return train_step


def jit_train_step(train_step, *, donate_state: bool = True, **jit_kw):
    donate = (0,) if donate_state else ()
    return jax.jit(train_step, donate_argnums=donate, **jit_kw)
