from repro.training.checkpoint import latest_step, restore, save  # noqa: F401
from repro.training.data import DataCursor, DataLoader, synthetic_batch  # noqa: F401
from repro.training.fault_tolerance import Preemption, run_training  # noqa: F401
from repro.training.optimizer import AdamW, cosine_schedule, global_norm  # noqa: F401
from repro.training.train_loop import (jit_train_step, make_loss_fn,  # noqa: F401
                                       make_train_step)
