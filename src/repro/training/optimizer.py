"""Pure-JAX AdamW with global-norm clipping and LR schedules.

optax-style (init/update) but dependency-free.  Moments are f32
regardless of param dtype (bf16-safe); the update preserves param dtype.
State is a plain pytree — checkpointable and shardable like params
(ZeRO-1: shard moments over the data axis; see launch/sharding.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jnp.ndarray], jnp.ndarray] | float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(jnp.zeros((), jnp.int32),
                          jax.tree_util.tree_map(zeros, params),
                          jax.tree_util.tree_map(zeros, params))

    def _lr(self, step):
        return self.lr(step) if callable(self.lr) else self.lr

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        if self.clip_norm is not None:
            gnorm = global_norm(grads)
            scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
        else:
            gnorm = global_norm(grads)

        b1, b2 = self.b1, self.b2
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = self._lr(step)

        def upd(p, m, v):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, mu, nu)
        return new_params, AdamWState(step, mu, nu), {"grad_norm": gnorm, "lr": lr}


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1) -> Callable:
    def lr(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup, 1)
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(math.pi * t)))
        return jnp.where(s < warmup, warm, cos)
    return lr
