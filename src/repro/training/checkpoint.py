"""Atomic, sharding-aware checkpointing (no orbax dependency).

Layout:  <dir>/step_<N>/
            manifest.json       — step, cursor, tree structure, leaf index
            arrays.npz          — flattened leaves keyed by path string
         <dir>/LATEST           — atomic pointer (write tmp + rename)

Properties required at fleet scale:
  * atomic: a crash mid-save never corrupts LATEST (tmp + os.replace)
  * resharding restore: leaves are loaded host-side and ``device_put``
    with the *current* mesh sharding — a checkpoint from mesh (16,16)
    restores onto (8,16) or (2,16,16) unchanged (elastic re-mesh path)
  * keep-last-k garbage collection
  * restores params, optimizer state, data cursor and PRNG key
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


_NP_NATIVE = {"float64", "float32", "float16", "int64", "int32", "int16",
              "int8", "uint8", "uint16", "uint32", "uint64", "bool"}


def _flatten(tree) -> Tuple[Dict[str, np.ndarray], Dict[str, str]]:
    """Flatten to numpy; exotic dtypes (bfloat16, ...) are stored as raw
    bytes (uint8 view) with the true dtype recorded for restore."""
    flat, dtypes = {}, {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.name not in _NP_NATIVE:
            dtypes[key] = {"dtype": arr.dtype.name, "shape": list(arr.shape)}
            arr = np.ascontiguousarray(arr).reshape(-1).view(np.uint8)
        flat[key] = arr
    return flat, dtypes


def save(ckpt_dir: str, step: int, tree: Any, *, cursor: Optional[dict] = None,
         keep: int = 3) -> str:
    """Atomically save a pytree checkpoint. Returns the step dir."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    flat, dtypes = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **flat)
    manifest = {
        "step": step,
        "cursor": cursor or {},
        "keys": sorted(flat.keys()),
        "raw_dtypes": dtypes,
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)

    # atomic LATEST pointer
    ptr_tmp = os.path.join(ckpt_dir, "LATEST.tmp")
    with open(ptr_tmp, "w") as f:
        f.write(os.path.basename(final))
    os.replace(ptr_tmp, os.path.join(ckpt_dir, "LATEST"))

    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    ptr = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(ptr):
        return None
    with open(ptr) as f:
        name = f.read().strip()
    if not os.path.exists(os.path.join(ckpt_dir, name)):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, like: Any, *, step: Optional[int] = None,
            shardings: Any = None) -> Tuple[Any, dict]:
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings`` (optional matching tree of
    NamedSharding) re-shards onto the current mesh — the elastic path."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(d, "arrays.npz"))

    raw_dtypes = manifest.get("raw_dtypes", {})
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (jax.tree_util.tree_leaves(shardings) if shardings is not None
                    else [None] * len(paths))
    out = []
    for (path, leaf), shd in zip(paths, shard_leaves):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        arr = arrays[key]
        if key in raw_dtypes:
            import ml_dtypes  # noqa: F401  (registers bfloat16 et al.)
            meta = raw_dtypes[key]
            arr = arr.view(np.dtype(meta["dtype"])).reshape(meta["shape"])
        want_dtype = leaf.dtype
        a = arr.astype(want_dtype) if arr.dtype != want_dtype else arr
        out.append(jax.device_put(a, shd) if shd is not None else jax.numpy.asarray(a))
    return jax.tree_util.tree_unflatten(treedef, out), manifest
