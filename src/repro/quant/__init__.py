"""Quantisation paths (paper §7): bf16 / int8 / int4, dequant vs fused."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.quant.paths import matmul, weight_bytes_streamed  # noqa: F401
from repro.quant.quantize import (DEFAULT_GROUP, QuantizedTensor,  # noqa: F401
                                  dequantize, quantize, quantize_int4,
                                  quantize_int8, unpack_int4)

# weight leaf names eligible for quantisation (embeddings, norms, biases,
# routers, convs and SSM scalars stay bf16 — standard practice)
QUANTIZABLE = {"wq", "wk", "wv", "wo", "gate", "up", "down",
               "w_gate", "w_up", "w_down", "in_proj", "out_proj"}

WEIGHT_PATHS = ("bf16", "int8_dequant", "int8_fused", "int4_dequant", "int4_fused")


def parse_path(path: str):
    """'int4_fused' -> (4, 'fused'); 'bf16' -> None."""
    if path == "bf16":
        return None
    bits_s, mode = path.split("_")
    return int(bits_s[3:]), mode


def quantize_tree(params: Dict, path: str, group: int = DEFAULT_GROUP) -> Dict:
    """Replace eligible linear weights with QuantizedTensor leaves."""
    spec = parse_path(path)
    if spec is None:
        return params
    bits, mode = spec

    def visit(kp, leaf):
        if not isinstance(leaf, jnp.ndarray) or leaf.ndim < 2:
            return leaf
        name = kp[-1].key if hasattr(kp[-1], "key") else str(kp[-1])
        if name not in QUANTIZABLE:
            return leaf
        k = leaf.shape[-2]
        g = min(group, k)
        if (bits == 4 and k % 2) or k % g:
            return leaf
        return quantize(leaf, bits, g, mode)

    return jax.tree_util.tree_map_with_path(visit, params)


def tree_weight_traffic(params: Any) -> float:
    """Total per-step analytic weight HBM traffic (bytes) for a params
    tree under its current quant layout (floor-model numerator)."""
    total = 0.0

    def visit(leaf):
        nonlocal total
        total += weight_bytes_streamed(leaf)
    for leaf in jax.tree_util.tree_leaves(
            params, is_leaf=lambda x: isinstance(x, QuantizedTensor)):
        visit(leaf)
    return total
