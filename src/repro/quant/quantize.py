"""Weight quantisation: int8 and packed-int4 with per-group scales.

The paper's §7 lesson is encoded in the *path* attached to each
quantised tensor:

  dequant — dequantise the whole weight to bf16, then matmul.  This is
            the bnb-nf4 trap: HBM traffic = quantised bytes + the full
            bf16 materialisation, so the 4x saving never lands.
  fused   — stream packed weights through VMEM and dequantise in-register
            inside the matmul kernel (Pallas: kernels/int4_matmul).  This
            is the ExLlamaV2 lesson: traffic ~= W/4 + scales.

Layout is general over leading dims: weights are (..., K, N) — a single
linear (K, N), a scan-stacked layer weight (L, K, N), or stacked experts
(L, E, K, N).  int4 packs two adjacent-K nibbles per uint8 along axis -2
(low nibble = even k).  Metadata (shape/group) is DERIVED from the
children so lax.scan / vmap slicing of a stacked QuantizedTensor yields a
valid per-layer QuantizedTensor.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import jax
import jax.numpy as jnp

DEFAULT_GROUP = 128


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class QuantizedTensor:
    """A quantised weight living in a params pytree.

    data:   int8 (..., K, N) for w8, or uint8 (..., K//2, N) for w4
    scales: f32 (..., K//group, N)
    """
    data: jnp.ndarray
    scales: jnp.ndarray
    bits: int
    path: str  # "dequant" | "fused"

    def tree_flatten(self):
        return (self.data, self.scales), (self.bits, self.path)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0], aux[1])

    # ---- derived metadata (valid after scan/vmap slicing) ----
    @property
    def k(self) -> int:
        return self.data.shape[-2] * (2 if self.bits == 4 else 1)

    @property
    def n(self) -> int:
        return self.data.shape[-1]

    @property
    def group(self) -> int:
        return self.k // self.scales.shape[-2]

    @property
    def shape(self) -> Tuple[int, ...]:
        return tuple(self.data.shape[:-2]) + (self.k, self.n)

    @property
    def ndim(self) -> int:
        return len(self.shape)

    @property
    def dtype(self):  # duck-type for shape/dtype probes
        return jnp.bfloat16

    @property
    def nbytes_streamed(self) -> float:
        """Analytic HBM bytes streamed per use (floor-model numerator)."""
        d = self.data.size * self.data.dtype.itemsize
        s = self.scales.size * self.scales.dtype.itemsize
        if self.path == "dequant":
            # write + read back the materialised bf16 copy
            return d + s + 2 * math.prod(self.shape) * 2
        return d + s


def quantize(w: jnp.ndarray, bits: int, group: int = DEFAULT_GROUP,
             path: str = "fused") -> QuantizedTensor:
    """w (..., K, N) -> QuantizedTensor, per-group scales along K."""
    assert bits in (4, 8)
    K, N = w.shape[-2], w.shape[-1]
    group = min(group, K)
    assert K % group == 0
    qmax = 7 if bits == 4 else 127
    g = w.astype(jnp.float32).reshape(*w.shape[:-2], K // group, group, N)
    scales = jnp.max(jnp.abs(g), axis=-2) / qmax + 1e-12     # (..., K//group, N)
    q = jnp.clip(jnp.round(g / scales[..., None, :]), -qmax - 1, qmax)
    q = q.astype(jnp.int8).reshape(w.shape)
    if bits == 8:
        return QuantizedTensor(q, scales, 8, path)
    assert K % 2 == 0, "int4 packing needs even K"
    lo = (q[..., 0::2, :] & 0xF).astype(jnp.uint8)
    hi = (q[..., 1::2, :] & 0xF).astype(jnp.uint8)
    return QuantizedTensor((lo | (hi << 4)).astype(jnp.uint8), scales, 4, path)


def quantize_int8(w, group: int = DEFAULT_GROUP, path: str = "fused"):
    return quantize(w, 8, group, path)


def quantize_int4(w, group: int = DEFAULT_GROUP, path: str = "fused"):
    return quantize(w, 4, group, path)


def unpack_int4(packed: jnp.ndarray) -> jnp.ndarray:
    """uint8 (..., K//2, N) -> int8 (..., K, N) in [-8, 7]."""
    lo = (packed & 0xF).astype(jnp.int8)
    hi = ((packed >> 4) & 0xF).astype(jnp.int8)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    out = jnp.stack([lo, hi], axis=-2)           # (..., K//2, 2, N)
    return out.reshape(*packed.shape[:-2], 2 * packed.shape[-2], packed.shape[-1])


def dequantize(qt: QuantizedTensor, dtype=jnp.bfloat16) -> jnp.ndarray:
    """Reshape-free: codes * repeat(scales) in the target dtype, which
    XLA fuses into the consuming GEMM's operand read (keeping the
    sharding of the packed data; an f32 reshape detour was measured to
    trigger full-weight all-gathers under GSPMD — EXPERIMENTS.md §Perf B)."""
    q = unpack_int4(qt.data) if qt.bits == 4 else qt.data
    s = jnp.repeat(qt.scales.astype(dtype), qt.group, axis=-2)
    return q.astype(dtype) * s
