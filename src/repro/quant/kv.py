"""Int8 KV-cache quantisation (per-token, per-head scales).

The decode memory term is weights + KV (paper §3.4); once weights are
int4-fused the KV sweep dominates at long context.  Scheme: each written
K/V vector (head_dim values) stores int8 codes + one f32 scale —
1/(2*hd) relative overhead — and dequantises into the QK/PV matmuls on
read (fused into the GEMM operand read on TPU, like the weight path).

This is the KV side of the paper's §7 lesson and the KVQuant/KIVI
related-work row, adapted to TPU.
"""
from __future__ import annotations

from typing import Tuple

import jax.numpy as jnp


def quantize_kv_write(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (..., hd) bf16 -> (codes int8 (..., hd), scales f32 (...))."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(codes: jnp.ndarray, scales: jnp.ndarray,
                  dtype=jnp.bfloat16) -> jnp.ndarray:
    """codes (..., hd) int8, scales (...) f32 -> (..., hd) dtype."""
    return (codes.astype(jnp.float32) * scales[..., None]).astype(dtype)


def is_quantized_cache(cache) -> bool:
    return "k_scale" in cache
