"""The single matmul entry point all models route linear layers through.

Plain arrays take the bf16 fast path; ``QuantizedTensor`` weights
dispatch on their ``path``:

  dequant — materialise bf16 then matmul (traffic >= W_bf16: the trap)
  fused   — fused dequant-matmul (Pallas kernel for 2D int4 on the K//2
            packed layout; jnp fallback keeps semantics identical
            elsewhere).  Traffic ~= W_q + scales: the saving lands.

``matmul_traffic_bytes`` gives the analytic per-call HBM traffic used by
the floor model and the Table-7 benchmark.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.quant.quantize import QuantizedTensor, dequantize


def matmul(x: jnp.ndarray, w) -> jnp.ndarray:
    """x (..., K) @ w (K, N) with quant-path dispatch."""
    if isinstance(w, QuantizedTensor):
        if w.path == "dequant":
            return x @ dequantize(w, x.dtype)
        # fused path.  The Pallas kernel runs on real TPU only (it is not
        # GSPMD-partitionable: under a multi-device jit it would force
        # full-weight all-gathers — measured in EXPERIMENTS.md §Perf B).
        # Elsewhere the same semantics are expressed as an XLA-fusable
        # dequant-into-GEMM read (kernel==ref equivalence is tested).
        import jax
        if w.bits == 4 and w.ndim == 2 and jax.default_backend() == "tpu":
            from repro.kernels.int4_matmul import ops as int4_ops
            lead = x.shape[:-1]
            x2 = x.reshape(-1, x.shape[-1])
            y = int4_ops.int4_matmul(x2, w.data, w.scales, group=w.group)
            return y.reshape(*lead, w.n).astype(x.dtype)
        return x @ dequantize(w, jnp.bfloat16)
    return x @ w


def expert_einsum(spec: str, x: jnp.ndarray, w) -> jnp.ndarray:
    """Batched expert matmul 'ecd,edf->ecf' / 'ecf,efd->ecd' with
    quant-path dispatch on stacked (E, K, N) weights.  Both quant paths
    dequantise per expert; the distinction (materialise-to-HBM vs
    fuse-into-GEMM-read) is a traffic-accounting property on TPU — XLA
    fuses the bf16 cast into the GEMM operand read for the fused path."""
    if isinstance(w, QuantizedTensor):
        return jnp.einsum(spec, x, dequantize(w, x.dtype))
    return jnp.einsum(spec, x, w)


def weight_bytes_streamed(w) -> float:
    """Per-use analytic HBM weight traffic (bytes) for the floor model."""
    if isinstance(w, QuantizedTensor):
        return w.nbytes_streamed
    return w.size * w.dtype.itemsize
