"""jit'd public wrapper for the fused GQA decode-attention kernel."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.decode_attention import decode_attention_pallas


def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     mask: Optional[jnp.ndarray] = None,
                     length: Optional[jnp.ndarray] = None,
                     block: int = 512) -> jnp.ndarray:
    """q (B, Hq, hd); k/v (B, S, Hkv, hd) -> (B, Hq, hd).

    Provide either ``mask`` — (S,) shared or (B, S) per-sequence
    valid-slot mask — or ``length`` (valid prefix length).  Pads S up to
    a block multiple with masked slots."""
    B, Hq, hd = q.shape
    S = k.shape[1]
    if mask is None:
        assert length is not None
        mask = jnp.arange(S) < length
    interpret = jax.default_backend() != "tpu"
    bs = min(block, S)
    Sp = (S + bs - 1) // bs * bs
    if Sp != S:
        pad = ((0, 0), (0, Sp - S), (0, 0), (0, 0))
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
        mask = jnp.pad(mask.reshape(-1, S), ((0, 0), (0, Sp - S))).reshape(-1)
    return decode_attention_pallas(q, k, v, mask, bs=bs, interpret=interpret)


def traffic_bytes(B: int, S: int, Hkv: int, hd: int, kv_bytes: int = 2) -> dict:
    """Analytic per-call HBM traffic: the K term of the floor model."""
    return {"kv": 2 * B * S * Hkv * hd * kv_bytes}
