"""Pallas TPU kernel: fused GQA single-token decode attention
(flash-decoding style online softmax over KV blocks).

This is the per-step KV sweep — the "K" term of the paper's floor model.
One kernel launch covers the whole (batch, kv-head) grid; the context
axis is the innermost sequential grid dimension so the (m, l, acc)
online-softmax carry lives in VMEM scratch across KV blocks.

Grid (B, Hkv, S/BS); blocks: q (1,1,G,hd) resident, K/V (1,BS,1,hd)
streamed, mask (1,BS) streamed.  hd is MXU-lane aligned (128 or 64 for
the assigned archs).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, m_ref, l_ref, acc_ref,
            *, scale: float):
    s = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (G, hd)
    k = k_ref[0, :, 0].astype(jnp.float32)       # (BS, hd)
    v = v_ref[0, :, 0].astype(jnp.float32)       # (BS, hd)
    valid = mask_ref[0] != 0                     # (BS,)

    scores = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale          # (G, BS)
    scores = jnp.where(valid[None, :], scores, NEG_INF)

    m_prev = m_ref[...]                          # (G, 1)
    m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(scores - m_new)                  # (G, BS)
    p = jnp.where(valid[None, :], p, 0.0)

    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(s == ns - 1)
    def _out():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bs", "interpret"))
def decode_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                            mask: jnp.ndarray, *, bs: int = 512,
                            interpret: bool = False) -> jnp.ndarray:
    """q (B, Hq, hd); k/v (B, S, Hkv, hd); mask (B?, S) int8 -> (B, Hq, hd).

    S must divide bs (ops.py pads with masked-out slots)."""
    B, Hq, hd = q.shape
    _, S, Hkv, _ = k.shape
    G = Hq // Hkv
    assert S % bs == 0, (S, bs)
    qg = q.reshape(B, Hkv, G, hd)
    mask2 = jnp.broadcast_to(mask.astype(jnp.int8).reshape(-1, S), (B, S))

    out = pl.pallas_call(
        functools.partial(_kernel, scale=hd ** -0.5),
        grid=(B, Hkv, S // bs),
        in_specs=[
            pl.BlockSpec((1, 1, G, hd), lambda b, h, s: (b, h, 0, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, bs, 1, hd), lambda b, h, s: (b, s, h, 0)),
            pl.BlockSpec((1, bs), lambda b, h, s: (b, s)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, hd), lambda b, h, s: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k, v, mask2)
    return out.reshape(B, Hq, hd)
