"""Pure-jnp oracle for the fused GQA decode-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         mask: jnp.ndarray) -> jnp.ndarray:
    """q (B, Hq, hd); k/v (B, S, Hkv, hd); mask (S,) valid slots.
    Returns (B, Hq, hd) f32."""
    B, Hq, hd = q.shape
    Hkv = k.shape[2]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg, k.astype(jnp.float32))
    scores = scores * (hd ** -0.5)
    scores = jnp.where(mask[None, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", probs, v.astype(jnp.float32))
    return out.reshape(B, Hq, hd)
