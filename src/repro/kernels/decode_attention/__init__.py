from repro.kernels.decode_attention import ops, ref  # noqa: F401
from repro.kernels.decode_attention.ops import decode_attention  # noqa: F401
