"""Pallas TPU kernel: fused int4 dequant + matmul (the ExLlamaV2 lesson,
paper §7, adapted to TPU).

HBM traffic per call ~= packed nibbles (K*N/2 bytes) + scales — the 4x
weight-traffic reduction actually lands because bf16 weights never exist
in HBM.  Nibble unpack + per-group scaling happen in VMEM/registers; the
MXU sees an f32-accumulated GEMM.

Grid (M/BM, N/BN, K/BK), K innermost (sequential accumulation into a VMEM
scratch tile).  BM/BN/BK default to 128 — MXU-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, s_ref, o_ref, acc_ref, *, group: int, bk: int):
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    packed = w_ref[...]                                      # (BK//2, BN) uint8
    lo = (packed & 0xF).astype(jnp.int8)
    hi = ((packed >> 4) & 0xF).astype(jnp.int8)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    bn = packed.shape[1]
    w = jnp.stack([lo, hi], axis=1).reshape(bk, bn)          # (BK, BN) int8

    scales = s_ref[...]                                      # (BK//group, BN)
    s_exp = jnp.repeat(scales, group, axis=0)                # (BK, BN)
    wf = w.astype(jnp.float32) * s_exp.astype(jnp.float32)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.float32), wf,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _out():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("group", "bm", "bn", "bk", "interpret"))
def int4_matmul_pallas(x: jnp.ndarray, packed: jnp.ndarray, scales: jnp.ndarray,
                       *, group: int = 128, bm: int = 128, bn: int = 128,
                       bk: int = 128, interpret: bool = False) -> jnp.ndarray:
    """x (M, K); packed (K//2, N) uint8; scales (K//group, N) -> (M, N).

    M/N/K must be multiples of the block sizes and BK a multiple of the
    scale group (ops.py pads and picks blocks)."""
    M, K = x.shape
    N = packed.shape[1]
    assert packed.shape[0] == K // 2, (packed.shape, K)
    g_eff = min(group, bk)
    assert bk % g_eff == 0 and M % bm == 0 and N % bn == 0 and K % bk == 0

    grid = (M // bm, N // bn, K // bk)
    return pl.pallas_call(
        functools.partial(_kernel, group=g_eff, bk=bk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk // g_eff, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, packed, scales)
