"""jit'd public wrapper for the fused int4 matmul kernel.

Pads M/N/K to block multiples, picks CPU interpret mode automatically,
and exposes the analytic per-call HBM traffic for the floor model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.int4_matmul.int4_matmul import int4_matmul_pallas


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def int4_matmul(x: jnp.ndarray, packed: jnp.ndarray, scales: jnp.ndarray,
                *, group: int = 128, block: int = 128) -> jnp.ndarray:
    """x (M, K) @ int4-packed (K//2, N) with per-group scales -> (M, N)."""
    M, K = x.shape
    K2, N = packed.shape
    assert K == 2 * K2, f"K mismatch: x K={K}, packed implies {2 * K2}"
    interpret = jax.default_backend() != "tpu"

    bm = min(block, _round_up(M, 8))
    bn = min(block, _round_up(N, 128))
    bk = min(block, K)
    g_eff = min(group, bk)

    Mp, Np, Kp = _round_up(M, bm), _round_up(N, bn), _round_up(K, bk)
    if (Mp, Kp) != (M, K):
        x = jnp.pad(x, ((0, Mp - M), (0, Kp - K)))
    if (Kp // 2, Np) != (K2, N):
        packed = jnp.pad(packed, ((0, Kp // 2 - K2), (0, Np - N)))
        scales = jnp.pad(scales, ((0, Kp // g_eff - scales.shape[0]), (0, Np - N)))
    out = int4_matmul_pallas(x, packed, scales, group=g_eff,
                             bm=bm, bn=bn, bk=bk, interpret=interpret)
    return out[:M, :N]


def traffic_bytes(M: int, K: int, N: int, group: int = 128) -> dict:
    """Analytic HBM bytes per call (fused path)."""
    return {
        "x": M * K * 2,
        "weights": K * N // 2,
        "scales": (K // group) * N * 4,
        "out": M * N * 2,
    }
