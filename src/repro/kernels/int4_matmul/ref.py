"""Pure-jnp oracle for the fused int4 dequant-matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp


def unpack_int4_ref(packed: jnp.ndarray) -> jnp.ndarray:
    """uint8 (K//2, N) -> int8 (K, N), low nibble = even k, high = odd k."""
    lo = (packed & 0xF).astype(jnp.int8)
    hi = ((packed >> 4) & 0xF).astype(jnp.int8)
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    K2, N = packed.shape
    return jnp.stack([lo, hi], axis=1).reshape(2 * K2, N)


def int4_matmul_ref(x: jnp.ndarray, packed: jnp.ndarray, scales: jnp.ndarray,
                    group: int) -> jnp.ndarray:
    """x (M, K) @ dequant(packed (K//2, N), scales (K//group, N)) -> (M, N) f32."""
    K = 2 * packed.shape[0]
    N = packed.shape[1]
    q = unpack_int4_ref(packed).astype(jnp.float32)
    w = (q.reshape(K // group, group, N) * scales[:, None, :].astype(jnp.float32)
         ).reshape(K, N)
    return x.astype(jnp.float32) @ w
