from repro.kernels.int4_matmul import ops, ref  # noqa: F401
from repro.kernels.int4_matmul.ops import int4_matmul  # noqa: F401
