"""Pallas TPU kernel: fused Mamba2/SSD recurrent decode step.

The attention-free analogue of decode_attention: the per-step state
sweep h' = exp(dA)*h + xdt ⊗ B ; y = h'·C is THE memory hot spot of
SSM decode (the floor's constant "K" term — ctx-independent).  Fusing
update + readout means the (P, N) state tile is read from HBM once and
written once per step, with the outer product, decay and C-contraction
all in VMEM — instead of three separate HBM sweeps (decay-mul, add,
einsum) in the unfused form.

Grid (B, H): each step owns one head's (P, N) state tile.
P=64, N=64..128 for the assigned archs — (64,128) f32 = 32 KB, VMEM-easy
and lane-aligned.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(h_ref, xdt_ref, dA_ref, b_ref, c_ref, hout_ref, y_ref):
    h = h_ref[0, 0].astype(jnp.float32)          # (P, N)
    xdt = xdt_ref[0, 0].astype(jnp.float32)      # (P,)
    decay = jnp.exp(dA_ref[0, 0].astype(jnp.float32))   # scalar
    bv = b_ref[0, 0].astype(jnp.float32)         # (N,)
    cv = c_ref[0, 0].astype(jnp.float32)         # (N,)

    h_new = decay * h + xdt[:, None] * bv[None, :]
    hout_ref[0, 0] = h_new.astype(hout_ref.dtype)
    y_ref[0, 0] = (h_new @ cv).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_update_pallas(h: jnp.ndarray, xdt: jnp.ndarray, dA: jnp.ndarray,
                      Bv: jnp.ndarray, Cv: jnp.ndarray, *,
                      interpret: bool = False):
    """h (B,H,P,N) f32; xdt (B,H,P); dA (B,H); Bv/Cv (B,H,N).
    Returns (h' (B,H,P,N) f32, y (B,H,P) f32)."""
    B, H, P, N = h.shape
    grid = (B, H)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, P, N), lambda b, h_: (b, h_, 0, 0)),
            pl.BlockSpec((1, 1, P), lambda b, h_: (b, h_, 0)),
            pl.BlockSpec((1, 1), lambda b, h_: (b, h_)),
            pl.BlockSpec((1, 1, N), lambda b, h_: (b, h_, 0)),
            pl.BlockSpec((1, 1, N), lambda b, h_: (b, h_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, P, N), lambda b, h_: (b, h_, 0, 0)),
            pl.BlockSpec((1, 1, P), lambda b, h_: (b, h_, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
            jax.ShapeDtypeStruct((B, H, P), jnp.float32),
        ],
        interpret=interpret,
    )(h, xdt, dA, Bv, Cv)
