"""jit'd public wrapper for the fused SSD decode-step kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd_update.ssd_update import ssd_update_pallas


def ssd_update(h: jnp.ndarray, x: jnp.ndarray, dt: jnp.ndarray,
               A: jnp.ndarray, Bm: jnp.ndarray, Cm: jnp.ndarray):
    """Mamba2 decode-step state update, kernel-fused.

    h (B,H,P,N) f32; x (B,H,P); dt (B,H) post-softplus; A (H,) negative;
    Bm/Cm (B,G,N) with G | H (broadcast to heads here).
    Returns (h', y) matching mamba2.mamba_decode_step's inner math
    (before the D-skip/gating, which stay in jnp)."""
    B, H, P, N = h.shape
    G = Bm.shape[1]
    rep = H // G
    Bv = jnp.repeat(Bm, rep, axis=1)
    Cv = jnp.repeat(Cm, rep, axis=1)
    xdt = (x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None])
    dA = dt.astype(jnp.float32) * A[None, :].astype(jnp.float32)
    interpret = jax.default_backend() != "tpu"
    return ssd_update_pallas(h.astype(jnp.float32), xdt, dA,
                             Bv.astype(jnp.float32), Cv.astype(jnp.float32),
                             interpret=interpret)


def traffic_bytes(B: int, H: int, P: int, N: int) -> dict:
    """Analytic per-step HBM traffic: the SSM 'K' term of the floor."""
    state = B * H * P * N * 4
    return {"state_read": state, "state_write": state,
            "unfused_extra_sweeps": 2 * state}
