from repro.kernels.ssd_update import ops, ref  # noqa: F401
from repro.kernels.ssd_update.ops import ssd_update  # noqa: F401
