"""Pure-jnp oracle for the fused SSD decode state-update kernel."""
from __future__ import annotations

import jax.numpy as jnp


def ssd_update_ref(h: jnp.ndarray, xdt: jnp.ndarray, dA: jnp.ndarray,
                   Bv: jnp.ndarray, Cv: jnp.ndarray):
    """One recurrent SSD step (per decode token).

    h (B,H,P,N) f32; xdt = x*dt (B,H,P); dA = dt*A (B,H) (A negative);
    Bv/Cv (B,H,N) (groups pre-broadcast to heads).
    Returns (h' (B,H,P,N), y (B,H,P)):
      h' = exp(dA) * h + xdt ⊗ Bv ;  y = h' · Cv
    """
    decay = jnp.exp(dA)[..., None, None]
    h_new = decay * h + xdt[..., None] * Bv[..., None, :]
    y = jnp.einsum("bhpn,bhn->bhp", h_new, Cv)
    return h_new, y
