"""Pallas TPU kernel: fused paged GQA single-token decode attention.

The paged decode path used to *materialise* the slot-major virtual KV
view (``paged_view``: gather every block-table page into a fresh
(B, max_blocks*page, Hkv, hd) buffer) before the SDPA even ran — per
layer per step that is a full extra read+write of the virtual KV on top
of the SDPA's own read, i.e. the exact avoidable data movement the
paper's realised-vs-floor gap is made of.  This kernel fuses the gather
into the flash-decoding sweep: the block table rides in as a
scalar-prefetch operand, the BlockSpec index map dereferences it, and
each slot's pages are read **in place** from the pool, once, with no
intermediate view.

Grid (B, Hkv, max_blocks); the page axis is the innermost sequential
dimension so the (m, l, acc) online-softmax carry lives in VMEM scratch
across a slot's pages (same scheme as kernels/decode_attention).  Blocks
past a slot's live length — block-table entries parked on the garbage
sentinel — are skipped via ``pl.when`` (their DMA re-targets the same
sentinel page, so consecutive skipped steps cost no new fetch), which is
what makes the kernel's KV traffic track *allocated* pages instead of
the constant virtual length.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
            acc_ref, *, page: int, scale: float):
    b = pl.program_id(0)
    i = pl.program_id(2)
    ni = pl.num_programs(2)
    length = len_ref[b]

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(i * page < length)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)          # (G, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)       # (page, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)       # (page, hd)
        G = q.shape[0]
        # partial last page: tokens at absolute position >= length mask out
        tok = i * page + jax.lax.broadcasted_iota(jnp.int32, (G, page), 1)
        valid = tok < length

        scores = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # (G, page)
        scores = jnp.where(valid, scores, NEG_INF)

        m_prev = m_ref[...]                          # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new)                  # (G, page)
        p = jnp.where(valid, p, 0.0)

        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(i == ni - 1)
    def _out():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


def _kernel_quant(bt_ref, len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                  o_ref, m_ref, l_ref, acc_ref, *, page: int, scale: float):
    """Int8-KV variant: k/v blocks are int8 codes, ks/vs blocks the
    per-(token, head) float32 scales riding the SAME block-table
    indirection.  Dequantisation happens here, in-register, on the
    (page, hd) tile the DMA just landed — no model-dtype copy of the
    pool is ever materialised, so the stored-width traffic cut is
    *realised* (the paper's GPTQ+ExLlamaV2-style path, vs the gather
    route's bnb-style dequantised view)."""
    b = pl.program_id(0)
    i = pl.program_id(2)
    ni = pl.num_programs(2)
    length = len_ref[b]

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(i * page < length)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)          # (G, hd)
        ks = ks_ref[0, :, 0]                         # (page,) f32
        vs = vs_ref[0, :, 0]
        k = k_ref[0, :, 0].astype(jnp.float32) * ks[:, None]   # (page, hd)
        v = v_ref[0, :, 0].astype(jnp.float32) * vs[:, None]
        G = q.shape[0]
        tok = i * page + jax.lax.broadcasted_iota(jnp.int32, (G, page), 1)
        valid = tok < length

        scores = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # (G, page)
        scores = jnp.where(valid, scores, NEG_INF)

        m_prev = m_ref[...]                          # (G, 1)
        m_new = jnp.maximum(m_prev, jnp.max(scores, axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(scores - m_new)                  # (G, page)
        p = jnp.where(valid, p, 0.0)

        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(i == ni - 1)
    def _out():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention_pallas(q: jnp.ndarray, k_pool: jnp.ndarray,
                                  v_pool: jnp.ndarray,
                                  block_table: jnp.ndarray,
                                  lengths: jnp.ndarray,
                                  k_scale_pool: jnp.ndarray = None,
                                  v_scale_pool: jnp.ndarray = None, *,
                                  interpret: bool = False) -> jnp.ndarray:
    """q (B, Hq, hd); k_pool/v_pool (n_pages, page, Hkv, hd);
    block_table (B, max_blocks) page ids; lengths (B,) live tokens per
    slot -> (B, Hq, hd).

    A slot's output attends over virtual positions ``0..lengths[b]-1``,
    read through its block-table row; a slot with ``lengths[b] == 0``
    returns zeros (free lane, output discarded by the scheduler).

    With ``k_scale_pool``/``v_scale_pool`` (n_pages, page, Hkv) the
    pools hold int8 codes and the kernel dequantises inside the block
    loads (``_kernel_quant``): the scale tiles follow the same
    ``bt[b, i]`` index maps, and the output attends over exactly
    ``codes * scale`` — bitwise the function the dequantised-view
    gather reference computes at float32."""
    B, Hq, hd = q.shape
    _, page, Hkv, _ = k_pool.shape
    max_blocks = block_table.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, hd)
    block_table = block_table.astype(jnp.int32)
    lengths = lengths.astype(jnp.int32)
    quantized = k_scale_pool is not None

    pool_spec = pl.BlockSpec((1, page, 1, hd),
                             lambda b, h, i, bt, ln: (bt[b, i], 0, h, 0))
    scale_spec = pl.BlockSpec((1, page, 1),
                              lambda b, h, i, bt, ln: (bt[b, i], 0, h))
    in_specs = [
        pl.BlockSpec((1, 1, G, hd), lambda b, h, i, bt, ln: (b, h, 0, 0)),
        # the fused gather: the index map dereferences the prefetched
        # block table, so page i of slot b streams straight from the
        # pool — no materialised view
        pool_spec,
        pool_spec,
    ]
    operands = [block_table, lengths, qg, k_pool, v_pool]
    if quantized:
        in_specs += [scale_spec, scale_spec]
        operands += [k_scale_pool, v_scale_pool]
        kernel = _kernel_quant
    else:
        kernel = _kernel

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # block table + lengths
        grid=(B, Hkv, max_blocks),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, i, bt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, 1), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(kernel, page=page, scale=hd ** -0.5),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, hd), q.dtype),
        interpret=interpret,
    )(*operands)
    return out.reshape(B, Hq, hd)
