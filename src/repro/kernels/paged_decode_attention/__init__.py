from repro.kernels.paged_decode_attention import ops, ref  # noqa: F401
from repro.kernels.paged_decode_attention.ops import (  # noqa: F401
    paged_decode_attention)
