"""Pure-jnp oracle for the fused paged decode-attention kernel: the
gather+SDPA route the kernel replaces (materialise the virtual view via
the block table, then masked softmax-attention over it)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def paged_decode_attention_ref(q: jnp.ndarray, k_pool: jnp.ndarray,
                               v_pool: jnp.ndarray,
                               block_table: jnp.ndarray,
                               lengths: jnp.ndarray) -> jnp.ndarray:
    """q (B, Hq, hd); k_pool/v_pool (n_pages, page, Hkv, hd);
    block_table (B, max_blocks); lengths (B,) -> (B, Hq, hd) f32.

    A slot with ``lengths[b] == 0`` returns zeros (matching the kernel's
    free-lane contract)."""
    B, Hq, hd = q.shape
    _, page, Hkv, _ = k_pool.shape
    k_view = jnp.take(k_pool, block_table, axis=0).reshape(B, -1, Hkv, hd)
    v_view = jnp.take(v_pool, block_table, axis=0).reshape(B, -1, Hkv, hd)
    S = k_view.shape[1]
    G = Hq // Hkv
    qg = q.reshape(B, Hkv, G, hd).astype(jnp.float32)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg,
                        k_view.astype(jnp.float32)) * (hd ** -0.5)
    mask = jnp.arange(S)[None, :] < lengths[:, None]
    scores = jnp.where(mask[:, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where(mask[:, None, None, :], probs, 0.0)   # len-0 lanes
    out = jnp.einsum("bkgs,bskh->bkgh", probs, v_view.astype(jnp.float32))
    return out.reshape(B, Hq, hd)


def paged_decode_attention_quant_ref(q: jnp.ndarray, k_pool: jnp.ndarray,
                                     v_pool: jnp.ndarray,
                                     k_scale_pool: jnp.ndarray,
                                     v_scale_pool: jnp.ndarray,
                                     block_table: jnp.ndarray,
                                     lengths: jnp.ndarray) -> jnp.ndarray:
    """Int8-KV oracle: dequantise the pools to float32 (codes * scale,
    the gather route's materialised-view semantics) and run the plain
    reference.  The fused quant kernel computes the same function with
    the dequantisation moved inside its block loads."""
    k = k_pool.astype(jnp.float32) * k_scale_pool[..., None]
    v = v_pool.astype(jnp.float32) * v_scale_pool[..., None]
    return paged_decode_attention_ref(q, k, v, block_table, lengths)
