"""jit'd public wrapper + analytic traffic accounting for the fused
paged decode-attention kernel."""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_decode_attention.paged_decode_attention import (
    paged_decode_attention_pallas)


# staticcheck: hotpath
def paged_decode_attention(q: jnp.ndarray, k_pool: jnp.ndarray,
                           v_pool: jnp.ndarray, block_table: jnp.ndarray,
                           lengths: jnp.ndarray,
                           k_scale_pool: Optional[jnp.ndarray] = None,
                           v_scale_pool: Optional[jnp.ndarray] = None
                           ) -> jnp.ndarray:
    """q (B, Hq, hd); k_pool/v_pool (n_pages, page, Hkv, hd);
    block_table (B, max_blocks); lengths (B,) -> (B, Hq, hd).

    Reads each slot's allocated pages in place through the block table
    (scalar-prefetch indirection) — no materialised virtual view.  With
    scale pools (n_pages, page, Hkv) the pools hold int8 codes and the
    kernel dequantises in-register inside its block loads.  Interpret
    mode off-TPU."""
    interpret = jax.default_backend() != "tpu"
    return paged_decode_attention_pallas(q, k_pool, v_pool, block_table,
                                         lengths, k_scale_pool,
                                         v_scale_pool, interpret=interpret)


def kv_token_bytes(Hkv: int, hd: int, kv_bytes: int,
                   kv_quant: str = "none") -> int:
    """Stored bytes per cached token (K + V together).

    ``kv_quant="int8"``: one int8 code per element plus one float32
    scale per (token, head) for each of K and V."""
    if kv_quant == "int8":
        return 2 * Hkv * (hd + 4)
    return 2 * Hkv * hd * kv_bytes


def traffic_bytes(live_blocks: int, page_size: int, Hkv: int, hd: int,
                  *, n_slots: int, max_blocks: int, n_layers: int = 1,
                  kv_bytes: int = 2, kv_quant: str = "none") -> dict:
    """Analytic per-decode-step HBM KV traffic for the two paged routes.

    ``live_blocks`` is the summed ``ceil(live_len/page)`` over slots at
    that step (what the fused kernel actually walks; skipped sentinel
    blocks cost nothing).  The gather route is charged per layer for the
    full virtual view three times: the gather's pool read, the
    materialised-view write, and the SDPA's read of that view — the two
    middle terms are the traffic the fused kernel deletes.

    With ``kv_quant="int8"`` the routes diverge the way the paper's
    realised-savings gap does: the fused kernel reads live pages once at
    *stored* width (codes + scales — it achieves the analytic floor by
    construction), while the gather route reads the pool at stored
    width but then writes AND re-reads a dequantised model-dtype view
    of the whole virtual span (bnb-style), so most of the stored-width
    cut never reaches the step's actual traffic.  ``floor`` is the
    irreducible per-step KV term: live tokens once at stored width."""
    stored = kv_token_bytes(Hkv, hd, kv_bytes, kv_quant)
    model_tok = 2 * Hkv * hd * kv_bytes
    virtual = n_slots * max_blocks * page_size
    live = live_blocks * page_size
    if kv_quant == "none":
        gather = n_layers * 3 * virtual * model_tok
    else:
        # pool read (stored width) + dequantised-view write + SDPA read
        # (both model width) over the constant virtual span
        gather = n_layers * virtual * (stored + 2 * model_tok)
    return {
        "fused": n_layers * live * stored,
        "gather_sdpa": gather,
        "floor": n_layers * live * stored,
    }


def serving_traffic_bytes(step_kv_blocks: Sequence[int], cfg, *,
                          page_size: int, n_slots: int, max_blocks: int,
                          kv_bytes: Optional[int] = None,
                          kv_quant: str = "none") -> dict:
    """Mean per-decode-step KV traffic for both routes from a run's
    live-block trace (``ContinuousResult.step_kv_blocks``).

    ``kv_bytes`` defaults to the KV element size implied by the model
    dtype (an unquantised paged cache stores KV at the model dtype;
    under ``kv_quant="int8"`` it also sets the width the gather route's
    dequantised view materialises at)."""
    if kv_bytes is None:
        kv_bytes = 4 if cfg.dtype == "float32" else 2
    mean_blocks = int(round(float(np.mean(np.asarray(step_kv_blocks)))))
    return traffic_bytes(mean_blocks, page_size, cfg.n_kv_heads,
                         cfg.head_dim, n_slots=n_slots,
                         max_blocks=max_blocks, n_layers=cfg.n_layers,
                         kv_bytes=kv_bytes, kv_quant=kv_quant)
