"""jit'd public wrapper + analytic traffic accounting for the fused
paged decode-attention kernel."""
from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.paged_decode_attention.paged_decode_attention import (
    paged_decode_attention_pallas)


def paged_decode_attention(q: jnp.ndarray, k_pool: jnp.ndarray,
                           v_pool: jnp.ndarray, block_table: jnp.ndarray,
                           lengths: jnp.ndarray) -> jnp.ndarray:
    """q (B, Hq, hd); k_pool/v_pool (n_pages, page, Hkv, hd);
    block_table (B, max_blocks); lengths (B,) -> (B, Hq, hd).

    Reads each slot's allocated pages in place through the block table
    (scalar-prefetch indirection) — no materialised virtual view.
    Interpret mode off-TPU."""
    interpret = jax.default_backend() != "tpu"
    return paged_decode_attention_pallas(q, k_pool, v_pool, block_table,
                                         lengths, interpret=interpret)


def traffic_bytes(live_blocks: int, page_size: int, Hkv: int, hd: int,
                  *, n_slots: int, max_blocks: int, n_layers: int = 1,
                  kv_bytes: int = 2) -> dict:
    """Analytic per-decode-step HBM KV traffic for the two paged routes.

    ``live_blocks`` is the summed ``ceil(live_len/page)`` over slots at
    that step (what the fused kernel actually walks; skipped sentinel
    blocks cost nothing).  The gather route is charged per layer for the
    full virtual view three times: the gather's pool read, the
    materialised-view write, and the SDPA's read of that view — the two
    middle terms are the traffic the fused kernel deletes."""
    kv = 2 * Hkv * hd * kv_bytes               # K + V, per token
    virtual = n_slots * max_blocks * page_size
    return {
        "fused": n_layers * live_blocks * page_size * kv,
        "gather_sdpa": n_layers * 3 * virtual * kv,
    }


def serving_traffic_bytes(step_kv_blocks: Sequence[int], cfg, *,
                          page_size: int, n_slots: int, max_blocks: int,
                          kv_bytes: Optional[int] = None) -> dict:
    """Mean per-decode-step KV traffic for both routes from a run's
    live-block trace (``ContinuousResult.step_kv_blocks``).

    ``kv_bytes`` defaults to the KV element size implied by the model
    dtype (the paged cache stores KV at the model dtype)."""
    if kv_bytes is None:
        kv_bytes = 4 if cfg.dtype == "float32" else 2
    mean_blocks = int(round(float(np.mean(np.asarray(step_kv_blocks)))))
    return traffic_bytes(mean_blocks, page_size, cfg.n_kv_heads,
                         cfg.head_dim, n_slots=n_slots,
                         max_blocks=max_blocks, n_layers=cfg.n_layers,
                         kv_bytes=kv_bytes)
