"""Pure-jnp oracle for the fused RMSNorm kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """x (R, D), w (D,) -> (R, D) in x.dtype."""
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)
