"""jit'd public wrapper for the fused RMSNorm kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.rmsnorm import rmsnorm_pallas


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6,
            block: int = 256) -> jnp.ndarray:
    """x (..., D) fused RMSNorm; flattens leading dims, pads rows."""
    lead = x.shape[:-1]
    D = x.shape[-1]
    x2 = x.reshape(-1, D)
    R = x2.shape[0]
    br = min(block, R)
    Rp = (R + br - 1) // br * br
    if Rp != R:
        x2 = jnp.pad(x2, ((0, Rp - R), (0, 0)))
    interpret = jax.default_backend() != "tpu"
    out = rmsnorm_pallas(x2, w, br=br, eps=eps, interpret=interpret)
    return out[:R].reshape(*lead, D)
