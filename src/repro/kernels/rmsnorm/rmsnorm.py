"""Pallas TPU kernel: fused RMSNorm (2x per decoder block per step).

Single pass: each grid step owns a (BR, D) row block resident in VMEM,
computes the row mean-square and scales in-register — one HBM read and
one write per element, no intermediate round-trips.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)           # (BR, D)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * w_ref[0].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("br", "eps", "interpret"))
def rmsnorm_pallas(x: jnp.ndarray, w: jnp.ndarray, *, br: int = 256,
                   eps: float = 1e-6, interpret: bool = False) -> jnp.ndarray:
    R, D = x.shape
    assert R % br == 0, (R, br)
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(R // br,),
        in_specs=[
            pl.BlockSpec((br, D), lambda r: (r, 0)),
            pl.BlockSpec((1, D), lambda r: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, D), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        interpret=interpret,
    )(x, w.reshape(1, D))
