from repro.kernels.rmsnorm import ops, ref  # noqa: F401
from repro.kernels.rmsnorm.ops import rmsnorm  # noqa: F401
