"""Training launcher.

Real-hardware entry point (and CPU reduced-config driver): builds the
model + sharding plan for the ambient device set, runs the
fault-tolerant loop (training/fault_tolerance.py) with atomic
checkpoints.  On a TPU fleet each process calls
``jax.distributed.initialize()`` first (--distributed).

  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
      --steps 100 --batch 8 --seq-len 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.launch.hints import activation_hints
from repro.launch.mesh import make_test_mesh
from repro.models.model import Model
from repro.training import (AdamW, DataLoader, cosine_schedule, jit_train_step,
                            make_train_step, run_training)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized config of the same family")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", default="none", choices=["none", "blocks", "full"])
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--data-mode", default="arith", choices=["uniform", "arith"])
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    if args.distributed:
        jax.distributed.initialize()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    opt = AdamW(lr=cosine_schedule(args.lr, args.steps // 10 + 1, args.steps))

    n_dev = jax.device_count()
    mesh = None
    if n_dev > 1:
        dp = n_dev
        mesh = make_test_mesh(data=dp, model=1)

    step = make_train_step(model, opt, remat=args.remat,
                           microbatches=args.microbatches)
    step_fn = jit_train_step(step)

    def init_state():
        params = model.init(jax.random.PRNGKey(args.seed))
        return (params, opt.init(params))

    loader = DataLoader(cfg, batch=args.batch, seq_len=args.seq_len,
                        seed=args.seed, mode=args.data_mode)

    ctx = activation_hints(mesh) if mesh is not None else activation_hints(None)
    import contextlib
    mesh_ctx = mesh if mesh is not None else contextlib.nullcontext()
    with mesh_ctx, ctx:
        result = run_training(train_step=step_fn, init_state=init_state,
                              loader=loader, ckpt_dir=args.ckpt_dir,
                              total_steps=args.steps,
                              ckpt_every=args.ckpt_every)
    first = result.metrics_history[0]["loss"]
    last = result.metrics_history[-1]["loss"]
    print(f"steps={result.step} loss {first:.4f} -> {last:.4f} "
          f"(restarts={result.restarts})")


if __name__ == "__main__":
    main()
