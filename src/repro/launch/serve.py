"""Serving launcher — batch-1 streaming decode, the paper's workload.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
      --prompt-len 32 --new-tokens 64 --quant int4_fused --timed
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_config
from repro.core import floor as fl
from repro.core.hardware import DEFAULT_CHIP
from repro.models.model import Model
from repro.serving import DecodeEngine
from repro.training.data import DataLoader


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--quant", default="bf16",
                    choices=["bf16", "int8_dequant", "int8_fused",
                             "int4_dequant", "int4_fused"])
    ap.add_argument("--mode", default="streamed", choices=["streamed", "fused"])
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--timed", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    engine = DecodeEngine(model, params, quant_path=args.quant)

    loader = DataLoader(cfg, batch=args.batch, seq_len=args.prompt_len,
                        seed=args.seed)
    batch = next(loader)
    batch.pop("labels", None)
    max_len = args.prompt_len + args.new_tokens + 1

    if args.mode == "fused":
        res = engine.generate_fused(batch, max_len=max_len,
                                    n_new=args.new_tokens)
    else:
        res = engine.generate_streamed(batch, max_len=max_len,
                                       n_new=args.new_tokens,
                                       temperature=args.temperature,
                                       timed=args.timed)
    print(f"generated {res.tokens.shape} tokens; {res.tokens_per_s:.1f} tok/s")
    if args.timed and res.step_times_s:
        import numpy as np
        p50 = float(np.median(res.step_times_s)) * 1e3
        fc = fl.floor_cell(cfg, DEFAULT_CHIP, args.prompt_len)
        print(f"p50 step {p50:.2f} ms (v5e analytic floor for the FULL "
              f"config would be {fc.t_floor_ms:.2f} ms)")
    print("first tokens:", res.tokens[0, :12].tolist())


if __name__ == "__main__":
    main()
