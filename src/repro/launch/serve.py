"""Serving launcher — batch-1 streaming decode, the paper's workload,
plus the continuous-batching multi-session mode (slotted KV cache).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
      --prompt-len 32 --new-tokens 64 --quant int4_fused --timed

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
      --continuous --slots 4 --sessions 10 --timed

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b --reduced \
      --paged --trace bursty --steps-per-tick 8 --adaptive-k --slo-json
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.core import floor as fl
from repro.core.hardware import DEFAULT_CHIP
from repro.models.model import Model
from repro.serving import DecodeEngine, SessionRequest
from repro.training.data import DataLoader


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=1)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--quant", default="bf16",
                    choices=["bf16", "int8_dequant", "int8_fused",
                             "int4_dequant", "int4_fused"])
    ap.add_argument("--weights", default=None,
                    choices=["bf16", "int8", "int4", "int8_dequant",
                             "int8_fused", "int4_dequant", "int4_fused"],
                    help="weight quantisation path (alias for --quant; "
                         "bare 'int8'/'int4' select the fused "
                         "realised-savings path)")
    ap.add_argument("--kv-quant", default="none", choices=["none", "int8"],
                    help="KV cache quantisation: int8 stores codes + "
                         "per-(token, head) f32 scales — on --paged the "
                         "scales ride parallel pool slabs sharing the "
                         "block table, and --decode-backend pallas "
                         "dequantises inside the fused kernel's block "
                         "loads (realised traffic cut); the gather "
                         "route materialises a dequantised view "
                         "(bnb-style, stored-only cut)")
    ap.add_argument("--mode", default="streamed", choices=["streamed", "fused"])
    ap.add_argument("--decode-backend", default="sdpa",
                    choices=["sdpa", "math", "split_kv", "pallas"],
                    help="decode attention route; with --paged, 'pallas' "
                         "runs the fused block-table kernel (pages read "
                         "in place, no gathered view; interpret mode on "
                         "CPU), anything else the gather+SDPA reference")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--timed", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    # continuous batching (slotted KV cache, multi-session churn)
    ap.add_argument("--continuous", action="store_true",
                    help="serve --sessions sessions of mixed prompt/target "
                         "lengths through --slots cache slots")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--sessions", type=int, default=8)
    ap.add_argument("--dispatch", default="full_jit",
                    choices=["eager", "stage_jit", "full_jit"])
    ap.add_argument("--steps-per-tick", type=int, default=1,
                    help="horizon K: fuse K decode steps into ONE "
                         "compiled macro-tick program (on-device "
                         "sampling, one token transfer per macro-tick) "
                         "— amortises the per-token Python + dispatch + "
                         "sync launch tax by ~K; K=1 is the classic "
                         "one-dispatch-per-token loop.  Requires "
                         "--dispatch full_jit.  Sweet spot: 4-16 "
                         "(above that, mid-horizon finishes waste "
                         "device steps and admission latency grows)")
    # paged KV cache (slot->block-table->page-pool indirection)
    ap.add_argument("--paged", action="store_true",
                    help="serve out of a paged KV cache: a page pool + "
                         "per-slot block tables instead of per-slot "
                         "max_len rows (implies --continuous)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page")
    ap.add_argument("--pages", type=int, default=None,
                    help="total pool pages incl. the garbage sentinel; "
                         "below 1 + slots*ceil(max_len/page_size) the "
                         "pool is oversubscribed (default: full backing)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="admit prompts in chunks of this many tokens "
                         "(multiple of --page-size), interleaved with "
                         "decode ticks")
    # prefix sharing (copy-on-write KV pages over the block table)
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share page-aligned prompt prefixes across "
                         "sessions: matched pages are refcounted and "
                         "aliased into the new slot's block table "
                         "(prefill skipped for the match, CoW copy "
                         "before any write could touch a shared page); "
                         "implies --paged")
    # host-DRAM KV page tier (serving/memory/tiers.py)
    ap.add_argument("--kv-tier", default="none", choices=["none", "host"],
                    help="with --paged: add a host-DRAM page tier — "
                         "preempted sessions park their full KV pages "
                         "host-side and re-admission restores them "
                         "instead of re-prefilling; LRU-evicted prefix "
                         "pages spill into a host prefix index "
                         "(implies --paged)")
    ap.add_argument("--tier-policy", default="spill",
                    choices=["prefer-device", "spill", "lookahead"],
                    help="placement/migration policy for --kv-tier host: "
                         "prefer-device never spills (the control arm), "
                         "spill migrates exactly on eviction, lookahead "
                         "additionally pre-copies the predicted next "
                         "victim's cold pages on idle ticks")
    ap.add_argument("--host-pages", type=int, default=None,
                    help="host pool capacity in pages (default: one full "
                         "device pool)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="prepend this many identical tokens to every "
                         "session's prompt (the physical-AI fleet "
                         "workload: one system prompt / scene preamble "
                         "replayed across sessions) — what "
                         "--prefix-cache deduplicates")
    # trace-driven load replay (serving/trace.py)
    ap.add_argument("--trace", default=None,
                    choices=["poisson", "bursty"],
                    help="replay a seeded arrival trace instead of the "
                         "all-at-once session wave: requests are "
                         "released into the admission queue by virtual "
                         "arrival time and the run reports per-class "
                         "TTFT / per-token latency percentiles and "
                         "goodput-under-SLO on the scheduler's "
                         "deterministic virtual clock (implies "
                         "--continuous; --sessions sets the request "
                         "count)")
    ap.add_argument("--trace-seed", type=int, default=13,
                    help="trace generator seed (same seed -> "
                         "byte-identical trace)")
    ap.add_argument("--rate", type=float, default=25.0,
                    help="mean arrival rate of the trace, requests per "
                         "virtual second")
    ap.add_argument("--adaptive-k", action="store_true",
                    help="let each macro-tick pick its horizon from the "
                         "[1, --steps-per-tick] halving ladder by load "
                         "(ends ticks at completions when sessions "
                         "queue, at arrivals when a slot is free); "
                         "requires --steps-per-tick >= 2")
    ap.add_argument("--no-priority-preemption", action="store_true",
                    help="page-pressure eviction picks the youngest "
                         "session regardless of priority (the FIFO "
                         "baseline) instead of "
                         "lowest-priority-youngest")
    ap.add_argument("--slo-json", action="store_true",
                    help="with --trace: print the full SLO report as "
                         "JSON instead of the one-line summary")
    # fault injection + graceful degradation (serving/faults.py)
    ap.add_argument("--fault-plan", default=None,
                    help="with --trace: arm a chaos plan against the "
                         "replay — 'mixed' generates a seeded plan over "
                         "every fault kind (--chaos-seed; same seed -> "
                         "byte-identical schedule), anything else is "
                         "read as a fault-plan file (plan_to_text "
                         "format).  Injected copy failures retry with "
                         "backoff then degrade to re-prefill, poisoned "
                         "logits quarantine their lane, aborts free the "
                         "session's slot and pages with a terminal "
                         "event")
    ap.add_argument("--chaos-seed", type=int, default=7,
                    help="seed for --fault-plan mixed")
    ap.add_argument("--retry-budget", type=int, default=2,
                    help="retries per failed host-tier copy before the "
                         "restore degrades to re-prefill (backoff is "
                         "charged to the virtual clock)")
    ap.add_argument("--session-ttl", type=float, default=None,
                    help="per-session deadline in virtual seconds since "
                         "arrival; overdue sessions are expired and "
                         "their slot/pages freed")
    ap.add_argument("--restore-patience", type=int, default=0,
                    help="ticks a parked host copy is held while the "
                         "page gate can't cover its restore before "
                         "re-prefill admission supersedes it")
    args = ap.parse_args()
    if args.weights:
        args.quant = {"int8": "int8_fused",
                      "int4": "int4_fused"}.get(args.weights, args.weights)
    if args.trace:
        args.continuous = True
    if args.prefix_cache:
        args.paged = True
    if args.kv_tier != "none":
        args.paged = True
    if args.paged:
        args.continuous = True

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    model = Model(cfg, decode_backend=args.decode_backend)
    params = model.init(jax.random.PRNGKey(args.seed))
    import jax.numpy as jnp
    engine = DecodeEngine(
        model, params, quant_path=args.quant,
        kv_dtype=jnp.int8 if args.kv_quant == "int8" else None)

    if args.trace:
        return serve_trace(engine, cfg, args)
    if args.continuous:
        return serve_continuous(engine, cfg, args)

    loader = DataLoader(cfg, batch=args.batch, seq_len=args.prompt_len,
                        seed=args.seed)
    batch = next(loader)
    batch.pop("labels", None)
    max_len = args.prompt_len + args.new_tokens + 1

    if args.mode == "fused":
        res = engine.generate_fused(batch, max_len=max_len,
                                    n_new=args.new_tokens,
                                    temperature=args.temperature,
                                    seed=args.seed)
    else:
        res = engine.generate_streamed(batch, max_len=max_len,
                                       n_new=args.new_tokens,
                                       temperature=args.temperature,
                                       timed=args.timed)
    print(f"generated {res.tokens.shape} tokens; {res.tokens_per_s:.1f} tok/s")
    if args.timed and res.step_times_s:
        import numpy as np
        p50 = float(np.median(res.step_times_s)) * 1e3
        fc = fl.floor_cell(cfg, DEFAULT_CHIP, args.prompt_len)
        print(f"p50 step {p50:.2f} ms (v5e analytic floor for the FULL "
              f"config would be {fc.t_floor_ms:.2f} ms)")
    print("first tokens:", res.tokens[0, :12].tolist())


def mixed_requests(cfg, n_sessions: int, *, base_prompt: int,
                   base_new: int, seed: int, shared_prefix: int = 0):
    """Deterministic session mix: prompt lengths base..~2x base, token
    budgets base_new..~2x base_new — enough spread to exercise churn.
    ``shared_prefix`` prepends that many identical tokens to every
    prompt (the prefix-sharing workload)."""
    key = jax.random.PRNGKey(seed + 1)
    common = np.asarray(jax.random.randint(
        jax.random.fold_in(key, 10_000), (shared_prefix,), 0,
        cfg.vocab_size)) if shared_prefix else None
    reqs = []
    for i in range(n_sessions):
        k = jax.random.fold_in(key, i)
        plen = base_prompt + (i * 7) % (base_prompt + 1)
        n_new = base_new + (i * 5) % (base_new + 1)
        prompt = np.asarray(jax.random.randint(k, (plen,), 0,
                                               cfg.vocab_size))
        if common is not None:
            prompt = np.concatenate([common, prompt])
        reqs.append(SessionRequest(f"session{i}", prompt, n_new))
    return reqs


def serve_trace(engine: DecodeEngine, cfg, args):
    """Trace-driven load replay: generate a seeded arrival trace,
    release its requests by virtual arrival time through the continuous
    scheduler, and report the SLO metrics (TTFT / per-token latency
    percentiles, goodput-under-SLO) per session class."""
    import json

    from repro.serving import generate_trace, slo_report
    from repro.serving.trace import bursty_config, poisson_config
    mk = bursty_config if args.trace == "bursty" else poisson_config
    tcfg = mk(seed=args.trace_seed, n_requests=args.sessions,
              vocab_size=cfg.vocab_size, rate_rps=args.rate)
    trace = generate_trace(tcfg)
    max_len = trace.max_len() + 1
    injector = None
    if args.fault_plan:
        from repro.serving.faults import (FaultInjector, FaultPlanConfig,
                                          generate_fault_plan,
                                          plan_from_text, validate_plan)
        sids = [r.session_id for r in trace.requests]
        if args.fault_plan == "mixed":
            horizon = round(max(r.arrival_s for r in trace.requests)
                            + 0.25, 6)
            plan = generate_fault_plan(
                FaultPlanConfig(seed=args.chaos_seed, n_faults=12,
                                horizon_s=horizon), session_ids=sids)
        else:
            with open(args.fault_plan) as fh:
                plan = plan_from_text(fh.read())
            validate_plan(plan)
        injector = FaultInjector(plan)
    res = engine.generate_continuous(
        trace.requests, n_slots=args.slots, max_len=max_len,
        temperature=args.temperature, seed=args.seed,
        dispatch_mode=args.dispatch, paged=args.paged,
        page_size=args.page_size, n_pages=args.pages,
        prefill_chunk=args.prefill_chunk,
        steps_per_tick=args.steps_per_tick, timed=args.timed,
        prefix_cache=args.prefix_cache, adaptive_k=args.adaptive_k,
        priority_preemption=not args.no_priority_preemption,
        kv_tier=args.kv_tier, tier_policy=args.tier_policy,
        host_pages=args.host_pages,
        fault_injector=injector, retry_budget=args.retry_budget,
        session_ttl_s=args.session_ttl,
        restore_patience=args.restore_patience,
        self_audit=injector is not None)
    rep = slo_report(res, trace.classes)
    if args.slo_json:
        print(json.dumps(rep, indent=2, allow_nan=False))
        return
    print(f"replayed {args.trace} trace (seed {args.trace_seed}, "
          f"{len(trace.requests)} requests at {args.rate:g} req/s) through "
          f"{args.slots} slots, steps_per_tick={args.steps_per_tick}"
          f"{' adaptive' if args.adaptive_k else ''}: "
          f"{res.dispatches} decode dispatches, "
          f"{res.preemptions} preemptions, "
          f"virtual makespan {rep['makespan_s']:.3f}s")
    if res.kv_tier != "none":
        print(f"kv tier ({res.tier_policy}): {res.pages_spilled} spilled / "
              f"{res.pages_restored} restored pages, "
              f"{res.tier_restores} parked restores, "
              f"{res.host_prefix_hits} host prefix hits")
    if injector is not None:
        fc = " ".join(f"{k}:{v}" for k, v in res.fault_counts.items())
        print(f"chaos plan ({args.fault_plan}, seed {args.chaos_seed}): "
              f"{res.faults_injected} faults fired"
              f"{' (' + fc + ')' if fc else ''}")
        print(f"recovery: {res.save_retries}/{res.restore_retries} "
              f"save/restore retries "
              f"({res.retry_backoff_s * 1e3:.1f} ms virtual backoff), "
              f"{res.degraded_restores} degraded restores, "
              f"{res.corrupt_blobs} checksum rejects, "
              f"{res.quarantines} quarantines; sessions "
              f"{res.aborted_sessions} aborted / "
              f"{res.failed_sessions} failed / "
              f"{res.expired_sessions} expired")
    if rep["ttft"] is not None and rep["tpot"] is not None:
        print(f"ttft p50/p95/p99 {rep['ttft']['p50']:.4f}/"
              f"{rep['ttft']['p95']:.4f}/{rep['ttft']['p99']:.4f} s, "
              f"tpot p50/p95/p99 {rep['tpot']['p50']:.4f}/"
              f"{rep['tpot']['p95']:.4f}/{rep['tpot']['p99']:.4f} s "
              f"(virtual)")
    for name, c in rep["classes"].items():
        print(f"  class {name}: {c['sessions']} sessions, "
              f"slo_frac {c['slo_frac']:.2f} "
              f"(ttft<={c['slo_ttft_s']:g}s, tpot_p95<={c['slo_tpot_s']:g}s), "
              f"goodput {c['goodput_tok_s']:.1f} tok/s")
    dropped = (f", {rep['failed_sessions']} dropped"
               if rep.get("failed_sessions") else "")
    print(f"goodput under SLO: {rep['goodput_tok_s']:.1f} tok/s "
          f"({rep['slo_sessions']}/{rep['sessions']} sessions in SLO"
          f"{dropped}, "
          f"{rep.get('tokens_per_s_virtual', 0.0):.1f} tok/s served)")
    if res.adaptive_k:
        hist = " ".join(f"K{k}:{v}" for k, v in
                        sorted(res.horizon_hist.items()))
        print(f"adaptive horizon histogram: {hist}")


def serve_continuous(engine: DecodeEngine, cfg, args):
    reqs = mixed_requests(cfg, args.sessions, base_prompt=args.prompt_len,
                          base_new=args.new_tokens, seed=args.seed,
                          shared_prefix=args.shared_prefix)
    max_len = max(len(r.prompt) + r.max_new_tokens for r in reqs) + 1
    res = engine.generate_continuous(
        reqs, n_slots=args.slots, max_len=max_len,
        temperature=args.temperature, seed=args.seed,
        dispatch_mode=args.dispatch, paged=args.paged,
        page_size=args.page_size, n_pages=args.pages,
        prefill_chunk=args.prefill_chunk,
        steps_per_tick=args.steps_per_tick, timed=args.timed,
        prefix_cache=args.prefix_cache, kv_tier=args.kv_tier,
        tier_policy=args.tier_policy, host_pages=args.host_pages)
    n_tok = sum(len(s.tokens) for s in res.sessions.values())
    layout = "paged" if args.paged else "contiguous"
    backend = engine.model.decode_backend
    print(f"served {len(res.sessions)} sessions through {args.slots} slots "
          f"({args.dispatch}, {layout}, attn={backend}): {n_tok} tokens in "
          f"{res.ticks} ticks / {res.dispatches} decode dispatches, "
          f"{res.tokens_per_s:.1f} tok/s aggregate")
    if args.steps_per_tick > 1:
        dec_tok = n_tok - len(res.sessions)   # first tokens come from prefill
        print(f"horizon-K: steps_per_tick={args.steps_per_tick}, "
              f"{dec_tok / max(res.dispatches, 1):.1f} tokens per dispatch, "
              f"host dispatch {res.host_dispatch_s * 1e3:.1f} ms + sync "
              f"{res.host_sync_s * 1e3:.1f} ms over the run")
    if args.paged:
        max_blocks = -(-max_len // args.page_size)
        full = 1 + args.slots * max_blocks
        pages = args.pages or full
        print(f"paged: page_size={args.page_size} pages={pages} "
              f"(full backing {full}, "
              f"oversubscription x{(full - 1) / max(pages - 1, 1):.2f}), "
              f"preemptions={res.preemptions}")
        if args.prefix_cache:
            # denominator = prefill work this run would have dispatched
            # without sharing (saved + dispatched) — preempted sessions
            # re-match their own prefix on resume, so hits can exceed
            # the session count and saved can exceed the prompt bytes
            total = res.prefix_tokens_saved + res.prefill_tokens
            print(f"prefix cache: {res.prefix_hits} admission hits "
                  f"({len(reqs)} sessions), prefill tokens "
                  f"{res.prefill_tokens} dispatched / "
                  f"{res.prefix_tokens_saved} shared "
                  f"({res.prefix_tokens_saved / max(total, 1):.0%} of "
                  f"prefill work skipped), "
                  f"{res.cow_copies} CoW page cop"
                  f"{'y' if res.cow_copies == 1 else 'ies'}")
        if res.kv_tier != "none":
            print(f"kv tier ({res.tier_policy}): "
                  f"{res.pages_spilled} pages spilled / "
                  f"{res.pages_restored} restored, "
                  f"{res.tier_restores} parked-session restores, "
                  f"{res.host_prefix_hits} host prefix hits, "
                  f"{res.host_pages_used} host pages resident")
        if res.step_kv_blocks:
            from repro.kernels.paged_decode_attention.ops import (
                serving_traffic_bytes)
            tb = serving_traffic_bytes(res.step_kv_blocks, cfg,
                                       page_size=args.page_size,
                                       n_slots=args.slots,
                                       max_blocks=max_blocks,
                                       kv_quant=args.kv_quant)
            route = "fused-in-place" if backend == "pallas" else "gather+sdpa"
            moved = tb["fused"] if backend == "pallas" else tb["gather_sdpa"]
            quant_note = (f", kv_quant={args.kv_quant} "
                          f"floor {tb['floor'] / 1024:.1f} KiB"
                          if args.kv_quant != "none" else "")
            print(f"per-step KV traffic ({route}): {moved / 1024:.1f} KiB "
                  f"(fused would move {tb['fused'] / 1024:.1f}, gather "
                  f"{tb['gather_sdpa'] / 1024:.1f}{quant_note})")
    if args.quant != "bf16" or args.kv_quant != "none":
        from repro.quant import tree_weight_traffic
        wb = tree_weight_traffic(engine.params)
        print(f"quantised serving: weights={args.quant} "
              f"kv={args.kv_quant}; per-step weight stream "
              f"{wb / 1024:.1f} KiB")
    compiled = (f"compiled {res.step_cache_size}x"
                if res.step_cache_size is not None else
                "compile count n/a (staged/eager executors)")
    print(f"decode step {compiled}, "
          f"{res.launches_per_step} host launch(es) per step")
    if args.timed:
        for sid, s in res.sessions.items():
            if not s.step_times_s:
                continue
            p50 = float(np.median(s.step_times_s)) * 1e3
            p95 = float(np.percentile(s.step_times_s, 95)) * 1e3
            print(f"  {sid}: {len(s.tokens)} tokens, slot {s.slot}, "
                  f"ticks {s.admitted_tick}-{s.finished_tick}, "
                  f"step p50 {p50:.2f} ms p95 {p95:.2f} ms")
    first = next(iter(res.sessions.values()))
    print("first session tokens:", first.tokens[:12].tolist())


if __name__ == "__main__":
    main()
