"""Production mesh builders.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so
importing this module never touches jax device state; the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax
import and then calls it.
"""
from __future__ import annotations

from typing import Tuple

import jax


def _mk(shape, axes):
    # axis_types / AxisType only exist on newer jax; Auto is the default
    # there, so older versions just omit the argument
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_test_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small fake-device mesh for unit tests (subprocess-scoped)."""
    if pod:
        return _mk((pod, data, model), ("pod", "data", "model"))
    return _mk((data, model), ("data", "model"))


def dp_axes(mesh) -> Tuple[str, ...]:
    """Data-parallel axes: everything that is not 'model'."""
    return tuple(a for a in mesh.axis_names if a != "model")


def dp_size(mesh) -> int:
    s = 1
    for a in dp_axes(mesh):
        s *= mesh.shape[a]
    return s


def tp_size(mesh) -> int:
    return mesh.shape["model"]
