"""Adaptive sharding planner (DESIGN.md §4).

Maps every param/batch/cache leaf to a PartitionSpec by *name-based
rules* + *divisibility guards*: an axis is only assigned when the dim
divides the mesh axis size (jax rejects uneven input shardings); every
fallback is recorded in ``plan.decisions`` and printed by the dry-run.

Strategies encoded here:
  TP       — feature dims (d_ff, heads*head_dim, d_inner, vocab) over
             "model" (Megatron column/row pattern: one all-reduce/block)
  EP vs in-expert TP — experts over "model" when E % model == 0
             (llama4 16e), else TP inside each expert (qwen2-moe 60e,
             1408 = 16*88)
  DP       — batch over ("pod", "data")
  FSDP/ZeRO— params (and always optimizer moments) additionally sharded
             over "data" on a non-TP dim, for archs that cannot fit
             weights on the model axis alone (llama4-scout)
  seq-sharded KV — decode caches shard context over "model" (and batch
             over "data"); sidesteps GQA head divisibility and fits
             32k x 128 caches (flash-decoding combine is GSPMD-emitted)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig
from repro.launch.mesh import dp_axes, tp_size
from repro.quant.quantize import QuantizedTensor


@dataclasses.dataclass
class ShardingPlan:
    mesh: Any
    cfg: ArchConfig
    fsdp: bool
    decisions: Dict[str, str]
    strategy: str = "tp"   # "tp" (Megatron default) | "dp" (pure data-
    #                        parallel: params replicated, batch over ALL
    #                        axes, ZeRO-1 moments — the small-model layout
    #                        found in §Perf hillclimb A)

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)

    @property
    def batch_axes(self):
        if self.strategy == "dp":
            return tuple(self.mesh.axis_names)       # all axes carry batch
        return dp_axes(self.mesh)


def _fits(dim: int, mesh, axis) -> bool:
    if axis is None:
        return True
    sizes = [mesh.shape[a] for a in (axis if isinstance(axis, tuple) else (axis,))]
    n = 1
    for s in sizes:
        n *= s
    return dim % n == 0


def _guard(plan: ShardingPlan, path: str, shape, wanted: Tuple) -> P:
    """Drop axes that don't divide; record every fallback."""
    out = []
    for dim, axis in zip(shape, wanted):
        if axis is not None and not _fits(dim, plan.mesh, axis):
            plan.decisions[path] = (f"wanted {axis} on dim {dim}, "
                                    f"not divisible -> replicated")
            axis = None
        out.append(axis)
    return P(*out)


def _leaf_name(kp) -> str:
    return str(getattr(kp[-1], "key", getattr(kp[-1], "idx", kp[-1])))


def _path_str(kp) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in kp)


def make_plan(cfg: ArchConfig, mesh, *, fsdp: bool = False,
              strategy: str = "tp") -> ShardingPlan:
    return ShardingPlan(mesh, cfg, fsdp, {}, strategy)


# --------------------------------------------------------------------------
# params
# --------------------------------------------------------------------------

def param_spec(plan: ShardingPlan, kp, leaf) -> P:
    """PartitionSpec for one param leaf (shape includes stacking dims:
    blocks are (L, ...), experts (L, E, ...))."""
    cfg, mesh = plan.cfg, plan.mesh
    name = _leaf_name(kp)
    path = _path_str(kp)
    shape = leaf.shape
    if plan.strategy == "dp":
        # pure DP: parameters replicated everywhere
        return P(*([None] * len(shape)))
    stacked = path.startswith("blocks")
    dp = "data" if (plan.fsdp and "data" in mesh.axis_names) else None
    L = (None,) if stacked else ()

    def guard(*wanted):
        base = L + tuple(wanted)
        # align to actual rank (quantized leaves add/remove dims)
        base = base[:len(shape)] + (None,) * (len(shape) - len(base))
        return _guard(plan, path, shape, base)

    ep_ok = cfg.n_experts and cfg.n_experts % tp_size(mesh) == 0

    if name in ("embed", "lm_head"):
        # (V, D) or (K_codebooks, V, D): vocab over model, else d_model
        if shape[-2] % tp_size(mesh) == 0:
            spec = (None,) * (len(shape) - 2) + ("model", dp)
        else:
            spec = (None,) * (len(shape) - 2) + (None, "model")
        return _guard(plan, path, shape, spec)
    if name in ("wq", "wk", "wv"):            # (D, H*hd) col-parallel
        return guard(dp, "model")
    if name == "wo":                          # (H*hd, D) row-parallel
        return guard("model", dp)
    if name in ("bq", "bk", "bv"):
        return guard("model")
    if name in ("gate", "up"):                # (D, F) col-parallel
        return guard(dp, "model")
    if name == "down":                        # (F, D) row-parallel
        return guard("model", dp)
    if name == "router":
        return guard(None, None)
    if name in ("w_gate", "w_up"):            # (E, D, F)
        return guard("model", dp, None) if ep_ok else guard(None, dp, "model")
    if name == "w_down":                      # (E, F, D)
        return guard("model", None, dp) if ep_ok else guard(None, "model", dp)
    if name == "in_proj":                     # (D, 2DI+2GN+H) col-parallel
        return guard(dp, "model")
    if name == "out_proj":                    # (DI, D) row-parallel
        return guard("model", dp)
    if name in ("conv_w",):                   # (K, C)
        return guard(None, "model")
    if name in ("conv_b", "gate_norm"):
        return guard("model")
    if name in ("A_log", "D", "dt_bias"):     # (H,)
        return guard("model")
    # norms, scalars: replicated
    return guard(*([None] * (len(shape) - len(L))))


def params_shardings(plan: ShardingPlan, abstract_params) -> Any:
    """Tree of NamedSharding matching the (possibly quantised) param tree.

    QuantizedTensor leaves: data/scales inherit the logical weight's spec
    on their shared (K-ish, N) trailing dims."""
    def visit(kp, leaf):
        if isinstance(leaf, QuantizedTensor):
            spec = param_spec(plan, kp, leaf)     # uses logical .shape
            # data/scales have same rank; K-dim sharding only if divisible
            d_spec = _guard(plan, _path_str(kp) + ".data", leaf.data.shape,
                            tuple(spec))
            s_spec = _guard(plan, _path_str(kp) + ".scales", leaf.scales.shape,
                            tuple(spec))
            return QuantizedTensor(plan.named(d_spec), plan.named(s_spec),
                                   leaf.bits, leaf.path)
        return plan.named(param_spec(plan, kp, leaf))
    return jax.tree_util.tree_map_with_path(
        visit, abstract_params,
        is_leaf=lambda x: isinstance(x, QuantizedTensor))


def opt_state_shardings(plan: ShardingPlan, abstract_opt_state,
                        *, zero1: bool = False) -> Any:
    """Moments follow the param layout by default (consistent shardings
    keep XLA from leaking an FSDP layout into the backward graph — see
    EXPERIMENTS.md §Perf for the measured ZeRO-1 trade-off).  zero1=True
    additionally shards moments over the data axes.  Under the pure-DP
    strategy, moments use the TP layout (ZeRO-1: replicated params,
    sharded optimizer)."""
    plan_m = dataclasses.replace(plan, fsdp=True) if zero1 else plan
    if plan.strategy == "dp":
        plan_m = dataclasses.replace(plan, strategy="tp", fsdp=True)
    step, mu, nu = abstract_opt_state
    return type(abstract_opt_state)(plan.named(P()),
                                    params_shardings(plan_m, mu),
                                    params_shardings(plan_m, nu))


# --------------------------------------------------------------------------
# batches and caches
# --------------------------------------------------------------------------

def batch_shardings(plan: ShardingPlan, batch_specs: Dict) -> Dict:
    dp = plan.batch_axes
    out = {}
    for k, v in batch_specs.items():
        wanted = (dp,) + (None,) * (len(v.shape) - 1)
        out[k] = plan.named(_guard(plan, f"batch/{k}", v.shape, wanted))
    return out


def cache_shardings(plan: ShardingPlan, cache_specs: Dict) -> Dict:
    """KV cache (L, B, S, Hkv, hd): batch over data, seq over model.
    SSM state (L, B, H, P, N): batch over data, heads over model.
    B==1 (long-context single stream): seq additionally over data."""
    mesh = plan.mesh
    dp = dp_axes(mesh)
    # single data axis shards as the flat name (P-spec equivalent to the
    # 1-tuple, and what callers comparing specs expect)
    dpf = dp[0] if len(dp) == 1 else dp
    out = {}
    for k, v in cache_specs.items():
        shape = v.shape
        if k in ("k", "v"):
            B, S = shape[1], shape[2]
            if B == 1:
                wanted = (None, None, (dp + ("model",)), None, None)
                if not _fits(S, mesh, wanted[2]):
                    wanted = (None, None, "model", None, None)
            else:
                wanted = (None, dpf, "model", None, None)
            out[k] = plan.named(_guard(plan, f"cache/{k}", shape, wanted))
        elif k in ("k_scale", "v_scale"):   # (L, B, S, Hkv) int8-KV scales
            B = shape[1]
            wanted = ((None, None, (dp + ("model",)), None) if B == 1
                      else (None, dpf, "model", None))
            out[k] = plan.named(_guard(plan, f"cache/{k}", shape, wanted))
        elif k == "h":        # (L, B, H, P, N)
            wanted = (None, dpf, "model", None, None)
            out[k] = plan.named(_guard(plan, f"cache/{k}", shape, wanted))
        elif k == "conv":     # (L, B, K-1, C)
            wanted = (None, dpf, None, "model")
            out[k] = plan.named(_guard(plan, f"cache/{k}", shape, wanted))
        else:                 # pos scalar
            out[k] = plan.named(P())
    return out
