"""Activation-sharding hints.

Models stay mesh-agnostic: they call ``constrain(x, axes)`` with logical
axis names ("dp" = all data axes, "tp" = the model axis, None = keep).
When a mesh is installed (dry-run / launcher), this becomes a
``with_sharding_constraint`` — pinning GSPMD's activation layout so
attention scores and MLP intermediates shard over heads/features instead
of replicating.  Without an installed mesh it is a no-op, so single-
device tests and benches are untouched.

Divisibility guards mirror launch/sharding.py: an axis that does not
divide the dim is dropped (never an error).
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

_STATE = {"mesh": None, "dp_all": False}


def enable(mesh, dp_all: bool = False) -> None:
    _STATE["mesh"] = mesh
    _STATE["dp_all"] = dp_all


def disable() -> None:
    _STATE["mesh"] = None
    _STATE["dp_all"] = False


class activation_hints:
    """Context manager: with activation_hints(mesh): ... lower/compile.

    dp_all=True (pure-DP strategy): 'dp' resolves to ALL mesh axes and
    'tp' is dropped (no model axis is reserved for TP)."""

    def __init__(self, mesh, dp_all: bool = False):
        self.mesh = mesh
        self.dp_all = dp_all

    def __enter__(self):
        enable(self.mesh, self.dp_all)
        return self

    def __exit__(self, *exc):
        disable()
        return False


def _resolve(ax, mesh):
    if ax == "dp":
        if _STATE["dp_all"]:
            axes = tuple(mesh.axis_names)
        else:
            axes = tuple(a for a in mesh.axis_names if a != "model")
        return axes if len(axes) > 1 else axes[0]
    if ax == "tp":
        if _STATE["dp_all"]:
            return None
        return "model"
    return ax


def _size_of(ax, mesh) -> int:
    if isinstance(ax, tuple):
        n = 1
        for a in ax:
            n *= mesh.shape[a]
        return n
    return mesh.shape[ax]


def tp_divides(n: int) -> bool:
    """True iff a mesh is installed, TP is active, and n divides the
    model-axis size."""
    mesh = _STATE["mesh"]
    if mesh is None or _STATE["dp_all"]:
        return False
    return n % mesh.shape["model"] == 0


def constrain(x, axes: Sequence[Optional[str]]):
    """x: array; axes: per-dim 'dp' | 'tp' | None (trailing dims None)."""
    mesh = _STATE["mesh"]
    if mesh is None:
        return x
    spec = []
    for i, dim in enumerate(x.shape):
        ax = axes[i] if i < len(axes) else None
        if ax is not None:
            ax = _resolve(ax, mesh)
            if ax is not None and dim % _size_of(ax, mesh) != 0:
                ax = None
        spec.append(ax)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*spec)))


def constrain_first_fit(x, candidates: Sequence[Sequence[Optional[str]]]):
    """Apply the first candidate whose named axes ALL divide their dims
    (e.g. prefer kv-head TP for attention scores, fall back to
    query-sequence context-parallelism when head counts don't split)."""
    mesh = _STATE["mesh"]
    if mesh is None:
        return x
    for axes in candidates:
        ok = True
        for i, dim in enumerate(x.shape):
            ax = axes[i] if i < len(axes) else None
            if ax is None:
                continue
            r = _resolve(ax, mesh)
            if r is not None and dim % _size_of(r, mesh) != 0:
                ok = False
                break
        if ok:
            return constrain(x, axes)
    return constrain(x, candidates[-1])   # guards drop what doesn't fit
