from repro.launch import hints, mesh, sharding  # noqa: F401
