import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the Model and allocation-free abstract params/caches
     (jax.eval_shape -> ShapeDtypeStruct trees),
  2. plans shardings (launch/sharding.py) for the production mesh
     (16,16) single-pod or (2,16,16) multi-pod,
  3. jit(...).lower(...).compile() — proving the distribution config is
     coherent (sharding mismatches / OOM at compile / unsupported
     collectives fail HERE),
  4. records memory_analysis, cost_analysis, parsed collective bytes,
     the analytic FLOP/byte model, and sharding decisions into one JSON
     per cell under --out (read by analysis/roofline.py).

Weight paths per cell: bf16 default; llama4-scout serving cells use
int8_fused (109B params cannot hold bf16 on a 16-chip model axis —
quantised serving is the deployable path, DESIGN.md §5); its train cell
uses FSDP (2D weight sharding).

Usage:
  python -m repro.launch.dryrun --arch olmo-1b --shape train_4k --mesh pod
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from typing import Dict, Optional  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.analysis import analytic  # noqa: E402
from repro.analysis.hlo import collective_summary, parse_collectives  # noqa: E402
from repro.configs import SHAPES, get_config, list_configs, shape_applicable  # noqa: E402
from repro.core.hardware import DEFAULT_CHIP  # noqa: E402
from repro.launch import sharding as shd  # noqa: E402
from repro.launch.mesh import dp_size, make_production_mesh, tp_size  # noqa: E402
from repro.models.model import Model, input_specs  # noqa: E402
from repro.quant import quantize_tree  # noqa: E402
from repro.training.optimizer import AdamW  # noqa: E402
from repro.training.train_loop import make_train_step  # noqa: E402


def cell_policy(arch: str, shape_name: str) -> Dict:
    """Per-cell deployment choices (recorded in the cell JSON)."""
    pol = {"weight_path": "bf16", "fsdp": False, "kv_dtype": "bfloat16",
           "remat": "blocks", "microbatches": 1, "strategy": "tp",
           "grad_compression": None, "attn_chunk_threshold": None}
    if shape_name == "train_4k":
        # grad-accumulation keeps per-chip activation residuals bounded
        # (mb=8 fits phi4/zamba2/mamba2 on 16GB v5e; measured in §Perf)
        pol["microbatches"] = 8
    if arch == "llama4-scout-17b-a16e":
        if shape_name == "train_4k":
            pol["fsdp"] = True
        else:
            # 109B params: int4 fused weights are the deployable path on
            # 16GB v5e (the paper's ExLlamaV2 lesson, DESIGN.md §5)
            pol["weight_path"] = "int4_fused"
    return pol


def build_cell(arch: str, shape_name: str, mesh_kind: str,
               policy_overrides: Optional[Dict] = None) -> Dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if not shape_applicable(cfg, shape):
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped",
                "reason": "long_500k needs sub-quadratic attention "
                          "(full-attention arch; DESIGN.md §5)"}

    pol = cell_policy(arch, shape_name)
    pol.update(policy_overrides or {})
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    n_chips = mesh.size
    if pol["attn_chunk_threshold"] is not None:
        from repro.models import attention as _attn
        _attn.configure(threshold=pol["attn_chunk_threshold"])
    model = Model(cfg)
    plan = shd.make_plan(cfg, mesh, fsdp=pol["fsdp"], strategy=pol["strategy"])

    t0 = time.time()
    abstract_params = model.abstract_params()
    if pol["weight_path"] != "bf16":
        abstract_params = jax.eval_shape(
            lambda p: quantize_tree(p, pol["weight_path"]), abstract_params)
    p_sh = shd.params_shardings(plan, abstract_params)

    B, S = shape.global_batch, shape.seq_len
    kv_dtype = jnp.bfloat16 if pol["kv_dtype"] == "bfloat16" else jnp.int8

    if shape.kind == "train":
        opt = AdamW(lr=1e-4)
        abstract_opt = jax.eval_shape(opt.init, abstract_params)
        o_sh = shd.opt_state_shardings(plan, abstract_opt)
        batch_specs = input_specs(cfg, seq_len=S, batch=B, kind="train")
        b_sh = shd.batch_shardings(plan, batch_specs)
        step = make_train_step(model, opt, remat=pol["remat"],
                               microbatches=pol["microbatches"],
                               grad_compression=pol["grad_compression"])
        fn = jax.jit(step, in_shardings=((p_sh, o_sh), b_sh),
                     donate_argnums=(0,))
        args = ((abstract_params, abstract_opt), batch_specs)
    elif shape.kind == "prefill":
        abstract_cache = jax.eval_shape(
            lambda: model.init_cache(B, S, kv_dtype=kv_dtype))
        c_sh = shd.cache_shardings(plan, abstract_cache)
        batch_specs = input_specs(cfg, seq_len=S, batch=B, kind="prefill")
        b_sh = shd.batch_shardings(plan, batch_specs)
        fn = jax.jit(model.prefill, in_shardings=(p_sh, b_sh, c_sh),
                     donate_argnums=(2,))
        args = (abstract_params, batch_specs, abstract_cache)
    else:  # decode
        abstract_cache = jax.eval_shape(
            lambda: model.init_cache(B, S, kv_dtype=kv_dtype))
        c_sh = shd.cache_shardings(plan, abstract_cache)
        tok_specs = input_specs(cfg, seq_len=S, batch=B, kind="decode")
        t_sh = shd.batch_shardings(plan, tok_specs)
        fn = jax.jit(model.decode_step,
                     in_shardings=(p_sh, c_sh, t_sh["tokens"]),
                     donate_argnums=(1,))
        args = (abstract_params, abstract_cache, tok_specs["tokens"])

    from repro.launch.hints import activation_hints
    with mesh, activation_hints(mesh, dp_all=(pol["strategy"] == "dp")):
        lowered = fn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    hlo_text = compiled.as_text()
    colls = parse_collectives(hlo_text, n_devices=n_chips)
    csum = collective_summary(colls)

    wdtype = {"bf16": 2, "int8_fused": 1, "int8_dequant": 1,
              "int4_fused": 0.5, "int4_dequant": 0.5}[pol["weight_path"]]
    tp_eff = 1 if pol["strategy"] == "dp" else tp_size(mesh)
    dp_eff = n_chips if pol["strategy"] == "dp" else dp_size(mesh)
    kv_bytes_eff = 1.0 + 4.0 / (2 * max(cfg.head_dim, 1)) \
        if pol["kv_dtype"] == "int8" else 2.0
    est = analytic.estimate(cfg, shape, n_chips=n_chips, tp=tp_eff,
                            dp=dp_eff, weight_dtype_bytes=wdtype,
                            kv_dtype_bytes=kv_bytes_eff,
                            remat=pol["remat"])

    per_chip_bytes = (getattr(mem, "argument_size_in_bytes", 0)
                      + getattr(mem, "temp_size_in_bytes", 0)
                      + getattr(mem, "output_size_in_bytes", 0)
                      - getattr(mem, "alias_size_in_bytes", 0))
    cell = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "kind": shape.kind, "n_chips": n_chips, "status": "ok",
        "policy": pol,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
            "per_chip_bytes": per_chip_bytes,
            "fits_v5e": bool(per_chip_bytes <= DEFAULT_CHIP.hbm_bytes),
        },
        "cost_analysis_xla": {
            "flops": cost.get("flops"),
            "bytes_accessed": cost.get("bytes accessed"),
            "note": "XLA counts while bodies once; see analytic + "
                    "EXPERIMENTS.md §Dry-run",
        },
        "collectives": csum,
        "collective_count_kinds": sorted(csum["by_kind"].keys()),
        "analytic": {
            "flops": est.flops,
            "hbm_bytes_per_chip": est.hbm_bytes_per_chip,
            "model_flops": est.model_flops,
            **{k: float(v) for k, v in est.detail.items()},
        },
        "sharding_decisions": plan.decisions,
    }
    return cell


def run_cell(arch, shape_name, mesh_kind, out_dir, force=False,
             policy_overrides=None, tag="") -> Dict:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_kind}{tag}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    try:
        cell = build_cell(arch, shape_name, mesh_kind, policy_overrides)
    except Exception as e:
        cell = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-2000:]}
    with open(path, "w") as f:
        json.dump(cell, f, indent=1, default=str)
    return cell


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--assigned-only", action="store_true", default=True)
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    archs = list_configs(assigned_only=True) if (args.all or not args.arch) \
        else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]

    n_ok = n_skip = n_err = 0
    for arch in archs:
        for shape_name in shapes:
            for mesh_kind in meshes:
                t0 = time.time()
                cell = run_cell(arch, shape_name, mesh_kind, args.out,
                                force=args.force)
                dt = time.time() - t0
                st = cell["status"]
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_err += st == "error"
                extra = ""
                if st == "ok":
                    extra = (f" fits={cell['memory']['fits_v5e']} "
                             f"perchip={cell['memory']['per_chip_bytes']/1e9:.2f}GB "
                             f"compile={cell['compile_s']}s")
                elif st == "error":
                    extra = " " + cell["error"][:120]
                print(f"[{st:7s}] {arch} x {shape_name} x {mesh_kind}"
                      f" ({dt:.1f}s){extra}", flush=True)
    print(f"\nok={n_ok} skipped={n_skip} error={n_err}")


if __name__ == "__main__":
    main()
