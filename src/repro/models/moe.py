"""Mixture-of-Experts FFN: routed experts (capacity-based GShard/Switch
dispatch) + optional shared experts.

Dispatch is sort-free and static-shaped: per (token, slot) assignment we
compute the token's rank within its expert via a masked cumulative sum,
drop overflow beyond capacity, scatter into an (E, C, D) buffer, run the
experts as one batched einsum (EP- or TP-shardable), and scatter-add
back.  FLOPs scale as tokens x top_k x capacity_factor — NOT x E — so the
dry-run rooflines are honest.

Routers: softmax_topk (Qwen-MoE: softmax then renormalised top-k) and
sigmoid_top1 (Llama-4).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import dense_init, init_mlp, mlp_forward
from repro.quant.paths import expert_einsum

Params = Dict[str, jnp.ndarray]


def init_moe(key, cfg: ArchConfig, dtype) -> Params:
    ks = jax.random.split(key, 5)
    E, D, F = cfg.n_experts, cfg.d_model, cfg.moe_d_ff
    p: Params = {
        "router": dense_init(ks[0], D, E, dtype),
        "w_up": (jax.random.normal(ks[1], (E, D, F), jnp.float32) / jnp.sqrt(D)).astype(dtype),
        "w_down": (jax.random.normal(ks[2], (E, F, D), jnp.float32) / jnp.sqrt(F)).astype(dtype),
    }
    if cfg.mlp_gated:
        p["w_gate"] = (jax.random.normal(ks[3], (E, D, F), jnp.float32) / jnp.sqrt(D)).astype(dtype)
    if cfg.shared_d_ff:
        p["shared"] = init_mlp(ks[4], D, cfg.shared_d_ff, cfg.mlp_gated, dtype)
    return p


def _route(p: Params, xt: jnp.ndarray, cfg: ArchConfig
           ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """xt (T, D) -> (expert_idx (T,k), gates (T,k), router_probs (T,E))."""
    logits = (xt @ p["router"]).astype(jnp.float32)
    if cfg.router_type == "sigmoid_top1":
        idx = jnp.argmax(logits, axis=-1)[:, None]
        gates = jax.nn.sigmoid(jnp.take_along_axis(logits, idx, axis=-1))
        probs = jax.nn.softmax(logits, axis=-1)   # for aux loss only
        return idx, gates, probs
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.top_k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    return idx, gates, probs


def load_balance_loss(probs: jnp.ndarray, idx: jnp.ndarray, n_experts: int) -> jnp.ndarray:
    """Switch-style aux loss: E * sum_e f_e * p_e."""
    one_hot = jax.nn.one_hot(idx, n_experts, dtype=jnp.float32)  # (T,k,E)
    f = jnp.mean(jnp.sum(one_hot, axis=1), axis=0)               # fraction per expert
    pbar = jnp.mean(probs, axis=0)
    return n_experts * jnp.sum(f * pbar)


def moe_forward(p: Params, x: jnp.ndarray, cfg: ArchConfig
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, D) -> (y (B, S, D), aux_loss scalar)."""
    B, S, D = x.shape
    T = B * S
    E, k = cfg.n_experts, max(cfg.top_k, 1)
    xt = x.reshape(T, D)

    idx, gates, probs = _route(p, xt, cfg)                       # (T,k)
    aux = load_balance_loss(probs, idx, E)

    capacity = max(int(T * k * cfg.capacity_factor / E), 1)
    # round capacity to a shardable multiple so the (E, C, D) dispatch
    # buffer splits over the data axes (else it replicates at 32k ctx:
    # 60 experts x 87k capacity x 2048 = 21 GB/chip, measured)
    if capacity > 256:
        capacity = (capacity + 255) // 256 * 256

    # rank of each (token, slot) within its expert, in token order
    flat_e = idx.reshape(-1)                                     # (T*k,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)          # (T*k, E)
    ranks = (jnp.cumsum(onehot, axis=0) - onehot)                # exclusive prefix count
    rank = jnp.take_along_axis(ranks, flat_e[:, None], axis=1)[:, 0]
    keep = rank < capacity

    # scatter tokens into (E, C, D); dropped slots stay zero
    safe_rank = jnp.where(keep, rank, 0)
    buf = jnp.zeros((E, capacity, D), x.dtype)
    tok_of_slot = jnp.repeat(jnp.arange(T), k)
    contrib = jnp.where(keep[:, None], xt[tok_of_slot], 0)
    buf = buf.at[flat_e, safe_rank].add(contrib, mode="drop")

    # expert compute, batched over E: EP (experts over model) when E
    # divides the TP degree, else TP inside each expert (F over model)
    from repro.launch import hints
    ep = hints.tp_divides(E)
    buf = hints.constrain(buf, ("tp" if ep else None, "dp", None))
    if cfg.mlp_gated:
        h = jax.nn.silu(expert_einsum("ecd,edf->ecf", buf, p["w_gate"])) * \
            expert_einsum("ecd,edf->ecf", buf, p["w_up"])
    else:
        h = jax.nn.gelu(expert_einsum("ecd,edf->ecf", buf, p["w_up"]))
    h = hints.constrain(h, ("tp", "dp", None) if ep else (None, "dp", "tp"))
    out_buf = expert_einsum("ecf,efd->ecd", h, p["w_down"])
    out_buf = hints.constrain(out_buf, ("tp" if ep else None, "dp", None))

    # combine: gather back per assignment, weight by gate, sum over k
    gathered = out_buf[flat_e, safe_rank]                        # (T*k, D)
    gathered = jnp.where(keep[:, None], gathered, 0)
    weighted = gathered * gates.reshape(-1)[:, None].astype(gathered.dtype)
    y = jnp.zeros((T, D), x.dtype).at[tok_of_slot].add(weighted.astype(x.dtype))

    if cfg.shared_d_ff:
        y = y + mlp_forward(p["shared"], xt, cfg.mlp_gated)
    return y.reshape(B, S, D), aux
