from repro.models.model import Model, input_specs  # noqa: F401
