"""Model assembly for all six families (dense / moe / ssm / hybrid /
vlm / audio).

Pure-functional API (params are pytrees; ``jax.eval_shape`` over ``init``
gives the allocation-free abstract trees the dry-run lowers with):

    m = Model(cfg)
    params = m.init(key)
    logits, aux = m.forward(params, batch)          # train / full-seq
    loss, metrics = m.loss(params, batch)
    cache = m.init_cache(batch_size, max_len)
    logits, cache = m.prefill(params, batch, cache)
    logits, cache = m.decode_step(params, cache, tokens)
    program = m.step_program(params, cache_len, batch)  # dispatch A/B

Layer stacks are scanned (stacked params, MaxText-style) so compile time
is depth-independent; ``unroll=True`` switches to a Python loop for
dry-run cost-analysis fidelity (XLA counts while bodies once).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.dispatch import StepProgram
from repro.models import attention as attn
from repro.models import mamba2, moe
from repro.models.common import (apply_norm, apply_rope, cross_entropy,
                                 embed_init, init_mlp, init_norm,
                                 make_angle_fn, mlp_forward)

Params = Dict[str, Any]
Cache = Dict[str, Any]


def _stack_init(init_fn, key, n: int):
    return jax.vmap(init_fn)(jax.random.split(key, n))


class Model:
    def __init__(self, cfg: ArchConfig, *, decode_backend: str = "sdpa",
                 ssd_chunk: int = mamba2.DEFAULT_CHUNK, remat: str = "none"):
        self.cfg = cfg
        self.decode_backend = decode_backend
        self.ssd_chunk = ssd_chunk
        self.remat = remat   # none | blocks (checkpoint each scan body)
        self.angle_fn = make_angle_fn(cfg) if cfg.n_heads else None
        self.dtype = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32

    def _maybe_remat(self, body):
        """Block-level rematerialisation: the scan body saves only its
        carry; internals (scores, MLP intermediates) recompute in bwd.
        Wrapping the whole loss in jax.checkpoint does NOT reduce scan
        residuals — the recompute rebuilds them — so remat must live at
        the body (measured in EXPERIMENTS.md §Dry-run)."""
        if self.remat == "none":
            return body
        return jax.checkpoint(body,
                              policy=jax.checkpoint_policies.nothing_saveable)

    # ------------------------------------------------------------------
    # init
    # ------------------------------------------------------------------
    def _init_block(self, key) -> Params:
        cfg, dt = self.cfg, self.dtype
        ks = jax.random.split(key, 4)
        if cfg.family in ("ssm", "hybrid"):
            return {"norm1": init_norm(cfg, dt),
                    "mamba": mamba2.init_mamba(ks[0], cfg, dt)}
        p: Params = {
            "norm1": init_norm(cfg, dt),
            "attn": attn.init_attention(ks[0], cfg, dt),
            "norm2": init_norm(cfg, dt),
        }
        if cfg.family == "moe":
            p["moe"] = moe.init_moe(ks[1], cfg, dt)
        else:
            p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_gated, dt)
        return p

    def _init_shared_attn(self, key) -> Params:
        """Zamba2-style shared attention+MLP block (one set of weights)."""
        cfg, dt = self.cfg, self.dtype
        ks = jax.random.split(key, 2)
        return {
            "norm1": init_norm(cfg, dt),
            "attn": attn.init_attention(ks[0], cfg, dt),
            "norm2": init_norm(cfg, dt),
            "mlp": init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_gated, dt),
        }

    def init(self, key) -> Params:
        cfg, dt = self.cfg, self.dtype
        ks = jax.random.split(key, 5)
        n_tables = max(1, cfg.n_codebooks)
        if n_tables == 1:
            embed = embed_init(ks[0], cfg.vocab_size, cfg.d_model, dt)
        else:
            embed = jax.vmap(
                lambda k: embed_init(k, cfg.vocab_size, cfg.d_model, dt)
            )(jax.random.split(ks[0], n_tables))
        params: Params = {
            "embed": embed,
            "blocks": _stack_init(self._init_block, ks[1], cfg.n_layers),
            "final_norm": init_norm(cfg, dt),
        }
        if cfg.family == "hybrid":
            params["shared_attn"] = self._init_shared_attn(ks[2])
        if not cfg.tie_embeddings:
            if n_tables == 1:
                params["lm_head"] = embed_init(ks[3], cfg.vocab_size, cfg.d_model, dt)
            else:
                params["lm_head"] = jax.vmap(
                    lambda k: embed_init(k, cfg.vocab_size, cfg.d_model, dt)
                )(jax.random.split(ks[3], n_tables))
        return params

    def abstract_params(self, seed: int = 0):
        return jax.eval_shape(lambda: self.init(jax.random.PRNGKey(seed)))

    # ------------------------------------------------------------------
    # embedding / head
    # ------------------------------------------------------------------
    def embed_tokens(self, params: Params, tokens: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        if cfg.n_codebooks:
            # tokens (B, S, K): sum of per-codebook embeddings (MusicGen)
            parts = [jnp.take(params["embed"][k], tokens[..., k], axis=0)
                     for k in range(cfg.n_codebooks)]
            return functools.reduce(jnp.add, parts)
        return jnp.take(params["embed"], tokens, axis=0)

    def lm_logits(self, params: Params, x: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        if cfg.n_codebooks:
            return jnp.einsum("bsd,kvd->bskv", x, head)
        return x @ head.T

    # ------------------------------------------------------------------
    # blocks: full-sequence
    # ------------------------------------------------------------------
    def _attn_block_full(self, bp: Params, x, angles):
        cfg = self.cfg
        a_out, (k, v) = attn.attention_full(bp["attn"], apply_norm(x, bp["norm1"]),
                                            angles, cfg, apply_rope)
        x = x + a_out
        h = apply_norm(x, bp["norm2"])
        if cfg.family == "moe":
            m_out, aux = moe.moe_forward(bp["moe"], h, cfg)
        else:
            m_out, aux = mlp_forward(bp["mlp"], h, cfg.mlp_gated), 0.0
        return x + m_out, aux, (k, v)

    def _mamba_block_full(self, bp: Params, x):
        y, h_fin, conv = mamba2.mamba_forward(
            bp["mamba"], apply_norm(x, bp.get("norm1")), self.cfg,
            chunk=self.ssd_chunk)
        return x + y, h_fin, conv

    # ------------------------------------------------------------------
    # forward (train / prefill backbone)
    # ------------------------------------------------------------------
    def _positions(self, batch: Dict, B: int, S: int):
        pos = batch.get("positions")
        if pos is None:
            pos = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
        return pos

    def backbone(self, params: Params, batch: Dict, *, collect_cache: bool = False,
                 unroll: bool = False):
        """Full-sequence backbone.  Returns (hidden, aux, layer_caches)."""
        cfg = self.cfg
        unroll = unroll or getattr(self, "unroll_layers", False)
        x = batch.get("embeds")
        tokens = batch.get("tokens")
        if x is None:
            x = self.embed_tokens(params, tokens)
        elif tokens is not None and cfg.family == "vlm":
            # merged stream: embeds already contain patch + text embeddings
            pass
        B, S = x.shape[0], x.shape[1]
        angles = self.angle_fn(self._positions(batch, B, S)) if self.angle_fn else None

        if cfg.family in ("dense", "vlm", "audio", "moe"):
            def body(carry, bp):
                h, aux = carry
                h, aux_l, (k, v) = self._attn_block_full(bp, h, angles)
                ys = (k, v) if collect_cache else None
                return (h, aux + aux_l), ys

            aux0 = jnp.float32(0.0)
            body = self._maybe_remat(body)
            if unroll:
                aux, kvs = aux0, []
                for i in range(cfg.n_layers):
                    bp = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
                    (x, aux), ys = body((x, aux), bp)
                    if collect_cache:
                        kvs.append(ys)
                layer_caches = (jnp.stack([kv[0] for kv in kvs]),
                                jnp.stack([kv[1] for kv in kvs])) if collect_cache else None
            else:
                (x, aux), kv = jax.lax.scan(body, (x, aux0), params["blocks"])
                layer_caches = kv if collect_cache else None
            return x, aux, layer_caches

        if cfg.family == "ssm":
            def body(carry, bp):
                h = carry
                h, h_fin, conv = self._mamba_block_full(bp, h)
                ys = (h_fin, conv) if collect_cache else None
                return h, ys

            body = self._maybe_remat(body)
            if unroll:
                states = []
                for i in range(cfg.n_layers):
                    bp = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
                    x, ys = body(x, bp)
                    if collect_cache:
                        states.append(ys)
                stacked = ((jnp.stack([s[0] for s in states]),
                            jnp.stack([s[1] for s in states]))
                           if collect_cache else None)
                return x, jnp.float32(0.0), stacked
            x, states = jax.lax.scan(body, x, params["blocks"])
            return x, jnp.float32(0.0), (states if collect_cache else None)

        if cfg.family == "hybrid":
            return self._hybrid_backbone(params, x, angles, collect_cache)
        raise ValueError(cfg.family)

    def _hybrid_groups(self):
        cfg = self.cfg
        ae = cfg.attn_every
        starts = list(range(0, cfg.n_layers, ae))
        return [(s, min(s + ae, cfg.n_layers)) for s in starts]

    def _hybrid_backbone(self, params, x, angles, collect_cache):
        cfg = self.cfg
        groups = self._hybrid_groups()
        ssm_states, attn_caches = [], []
        for (g0, g1) in groups:
            # shared attention block at the start of each group
            sp = params["shared_attn"]
            a_out, (k, v) = attn.attention_full(
                sp["attn"], apply_norm(x, sp["norm1"]), angles, cfg, apply_rope)
            x = x + a_out
            x = x + mlp_forward(sp["mlp"], apply_norm(x, sp["norm2"]), cfg.mlp_gated)
            if collect_cache:
                attn_caches.append((k, v))
            gp = jax.tree_util.tree_map(lambda a: a[g0:g1], params["blocks"])

            def body(h, bp):
                h, h_fin, conv = self._mamba_block_full(bp, h)
                return h, (h_fin, conv)
            x, states = jax.lax.scan(self._maybe_remat(body), x, gp)
            if collect_cache:
                ssm_states.append(states)
        if collect_cache:
            h_fin = jnp.concatenate([s[0] for s in ssm_states], axis=0)
            conv = jnp.concatenate([s[1] for s in ssm_states], axis=0)
            ks = jnp.stack([c[0] for c in attn_caches], axis=0)
            vs = jnp.stack([c[1] for c in attn_caches], axis=0)
            return x, 0.0, ((h_fin, conv), (ks, vs))
        return x, 0.0, None

    def forward(self, params: Params, batch: Dict) -> Tuple[jnp.ndarray, jnp.ndarray]:
        x, aux, _ = self.backbone(params, batch)
        x = apply_norm(x, params["final_norm"])
        return self.lm_logits(params, x), aux

    def loss(self, params: Params, batch: Dict, *, aux_weight: float = 0.01,
             z_loss: float = 0.0) -> Tuple[jnp.ndarray, Dict]:
        logits, aux = self.forward(params, batch)
        labels = batch["labels"]
        ce = cross_entropy(logits, labels, z_loss)
        total = ce + aux_weight * aux
        return total, {"loss": total, "ce": ce, "aux": aux}

    # ------------------------------------------------------------------
    # caches
    # ------------------------------------------------------------------
    def init_cache(self, batch_size: int, max_len: int,
                   kv_dtype=None, slotted: bool = False,
                   paged: bool = False, page_size: int = 16,
                   n_pages: Optional[int] = None,
                   kv_quant: Optional[str] = None) -> Cache:
        """KV/state cache.  ``slotted=True`` makes ``pos`` a (batch,)
        vector of per-slot positions — the continuous-batching layout
        where each batch row is an independent session slot and the
        decode step stays ONE compiled program at constant shapes while
        sessions churn (see repro.serving.scheduler).

        ``paged=True`` (implies slotted) replaces the per-slot
        ``max_len`` K/V rows with a **page pool** plus a per-slot block
        table: ``k``/``v`` become (L, n_pages, page_size, Hkv, hd) and
        ``block_table`` (batch, max_blocks) maps each slot's virtual
        positions onto pool pages.  Page 0 is the reserved garbage
        sentinel (never allocated; free lanes point at it).  With
        ``n_pages < 1 + batch_size * max_blocks`` the pool is
        *oversubscribed*: slots no longer each reserve a full
        ``max_len`` row, capacity follows live tokens instead
        (repro.serving.scheduler manages allocation/reclaim).  Distinct
        slots' block tables may alias the SAME physical page (prefix
        sharing): aliased pages are read-only by convention — the
        scheduler CoW-copies (``copy_kv_page``) before any write could
        land in one.

        ``kv_quant="int8"`` (equivalently ``kv_dtype=jnp.int8``) stores
        K/V as int8 codes with per-(token, head) float32 scales.  On
        paged caches the scales ride parallel ``k_scale``/``v_scale``
        pools of shape (L, n_pages, page_size, Hkv) sharing the block
        table, so a page id addresses codes and scales together —
        allocation, CoW, tiering, and prefix sharing all work unchanged
        page-at-a-time."""
        cfg = self.cfg
        if kv_quant is not None:
            if kv_quant not in ("none", "int8"):
                raise ValueError(f"kv_quant must be none|int8, got {kv_quant!r}")
            if kv_quant == "int8":
                kv_dtype = jnp.int8
        kv_dtype = kv_dtype or self.dtype
        if paged:
            slotted = True
        if slotted and cfg.family not in ("dense", "vlm", "audio", "moe"):
            raise NotImplementedError(
                "slotted (continuous-batching) caches target the "
                f"attention families, got {cfg.family!r}")
        if paged:
            if cfg.sliding_window:
                raise NotImplementedError(
                    "paged KV + sliding-window (ring) caches not supported")
            assert page_size >= 1
            max_blocks = -(-max_len // page_size)
            if n_pages is None:
                n_pages = 1 + batch_size * max_blocks   # full backing
            assert n_pages >= 2, "need the garbage page plus >=1 real page"
            shape = (cfg.n_layers, n_pages, page_size,
                     cfg.n_kv_heads, cfg.head_dim)
            cache = {"k": jnp.zeros(shape, kv_dtype),
                     "v": jnp.zeros(shape, kv_dtype),
                     "pos": jnp.zeros((batch_size,), jnp.int32),
                     "block_table": jnp.zeros((batch_size, max_blocks),
                                              jnp.int32)}
            if kv_dtype == jnp.int8:
                # scale pools share the block table: page p's codes in
                # k[:, p] pair with its scales in k_scale[:, p]
                cache["k_scale"] = jnp.zeros(shape[:-1], jnp.float32)
                cache["v_scale"] = jnp.zeros(shape[:-1], jnp.float32)
            return cache
        pos = (jnp.zeros((batch_size,), jnp.int32) if slotted
               else jnp.zeros((), jnp.int32))
        if cfg.family in ("dense", "vlm", "audio", "moe"):
            kv_len = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
            shape = (cfg.n_layers, batch_size, kv_len, cfg.n_kv_heads, cfg.head_dim)
            cache = {"k": jnp.zeros(shape, kv_dtype), "v": jnp.zeros(shape, kv_dtype),
                     "pos": pos}
            if kv_dtype == jnp.int8:
                # per-(token, head) scales: the int8 KV-quant path
                cache["k_scale"] = jnp.zeros(shape[:-1], jnp.float32)
                cache["v_scale"] = jnp.zeros(shape[:-1], jnp.float32)
            return cache
        if cfg.family == "ssm":
            return {
                "h": jnp.zeros((cfg.n_layers, batch_size, cfg.n_ssm_heads,
                                cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
                "conv": jnp.zeros((cfg.n_layers, batch_size, cfg.ssm_conv - 1,
                                   cfg.conv_channels), self.dtype),
                "pos": pos,
            }
        if cfg.family == "hybrid":
            n_apps = len(self._hybrid_groups())
            kv_len = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
            return {
                "h": jnp.zeros((cfg.n_layers, batch_size, cfg.n_ssm_heads,
                                cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
                "conv": jnp.zeros((cfg.n_layers, batch_size, cfg.ssm_conv - 1,
                                   cfg.conv_channels), self.dtype),
                "k": jnp.zeros((n_apps, batch_size, kv_len, cfg.n_kv_heads,
                                cfg.head_dim), kv_dtype),
                "v": jnp.zeros((n_apps, batch_size, kv_len, cfg.n_kv_heads,
                                cfg.head_dim), kv_dtype),
                "pos": pos,
            }
        raise ValueError(cfg.family)

    def copy_kv_page(self, cache: Cache, src: jnp.ndarray,
                     dst: jnp.ndarray) -> Cache:
        """Copy one pool page — every layer's K and V rows — onto
        another: the copy-on-write fault of prefix sharing.  A session
        admitted onto shared pages whose next KV write would land in a
        page other sessions still read gets a private copy first
        (serving/scheduler.py); ``src``/``dst`` are traced scalars, so
        ONE compiled copy program serves every fault."""
        assert "block_table" in cache, "copy_kv_page targets paged caches"
        src = jnp.asarray(src, jnp.int32)
        dst = jnp.asarray(dst, jnp.int32)
        return dict(cache, **{
            key: cache[key].at[:, dst].set(cache[key][:, src])
            for key in self._page_slab_keys(cache)})

    @staticmethod
    def _page_slab_keys(cache: Cache) -> Tuple[str, ...]:
        """Cache keys indexed (L, n_pages, ...) — everything a page id
        addresses.  Quantised pools carry scale slabs alongside codes."""
        if "k_scale" in cache:
            return ("k", "v", "k_scale", "v_scale")
        return ("k", "v")

    def save_kv_pages(self, cache: Cache, pages: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, ...]:
        """Gather ``pages`` (a (P,) id vector) out of the paged pool —
        every layer's K and V rows — as (L, P, page, ...) slabs: the
        device→host half of KV-page tiering (serving/memory/tiers.py).
        Returns one slab per page-indexed pool: (k, v) for bf16 caches,
        (k, v, k_scale, v_scale) for int8-quantised ones — codes and
        scales move together, bit-exact.  ``pages`` is traced, so one
        compiled program serves every save of the same P; callers pad
        P to a power of two with the garbage page to bound the program
        count."""
        assert "block_table" in cache, "save_kv_pages targets paged caches"
        pages = jnp.asarray(pages, jnp.int32)
        return tuple(cache[key][:, pages]
                     for key in self._page_slab_keys(cache))

    def restore_kv_pages(self, cache: Cache, pages: jnp.ndarray,
                         *slabs: jnp.ndarray) -> Cache:
        """Scatter saved KV slabs back into pool ``pages`` — the
        host→device half of tiering.  ``slabs`` must match
        ``save_kv_pages`` order ((k, v) or (k, v, k_scale, v_scale)).
        Padding lanes target the garbage page (a write sink by
        contract; duplicate scatter indices onto it are harmless)."""
        assert "block_table" in cache, "restore_kv_pages targets paged caches"
        keys = self._page_slab_keys(cache)
        assert len(slabs) == len(keys), (len(slabs), keys)
        pages = jnp.asarray(pages, jnp.int32)
        return dict(cache, **{
            key: cache[key].at[:, pages].set(slab.astype(cache[key].dtype))
            for key, slab in zip(keys, slabs)})

    # ------------------------------------------------------------------
    # prefill
    # ------------------------------------------------------------------
    def prefill(self, params: Params, batch: Dict, cache: Cache
                ) -> Tuple[jnp.ndarray, Cache]:
        """Populate the cache from a full prompt; returns last-pos logits."""
        cfg = self.cfg
        x, _, caches = self.backbone(params, batch, collect_cache=True)
        S = x.shape[1]

        def place(slab, dst, pre=None):
            """Write last min(S, kv_len) keys into the (possibly ring)
            cache so that token at absolute pos p lands at slot p % kv_len
            (no-op roll for full caches).  ``pre`` transforms the kept
            slab first (int8 KV quantisation)."""
            kv_len = dst.shape[2]
            s_eff = min(S, kv_len)
            kept = slab[:, :, S - s_eff:]
            kept = pre(kept) if pre is not None else kept.astype(dst.dtype)
            if s_eff == kv_len and S % kv_len:
                kept = jnp.roll(kept, S % kv_len, axis=2)
            return jax.lax.dynamic_update_slice_in_dim(dst, kept, 0, axis=2)

        quantized_kv = "k_scale" in cache

        if cfg.family in ("dense", "vlm", "audio", "moe"):
            k, v = caches    # (L, B, S, Hkv, hd) stacked by scan
            if quantized_kv:
                from repro.quant import kv as kvq
                kq, ks = kvq.quantize_kv_write(k)
                vq, vs = kvq.quantize_kv_write(v)
                cache = dict(cache,
                             k=place(kq, cache["k"], pre=lambda t: t),
                             v=place(vq, cache["v"], pre=lambda t: t),
                             k_scale=place(ks[..., None], cache["k_scale"][..., None],
                                           pre=lambda t: t.astype(jnp.float32))[..., 0],
                             v_scale=place(vs[..., None], cache["v_scale"][..., None],
                                           pre=lambda t: t.astype(jnp.float32))[..., 0])
            else:
                cache = dict(cache, k=place(k, cache["k"]), v=place(v, cache["v"]))
        elif cfg.family == "ssm":
            h, conv = caches
            cache = dict(cache, h=h, conv=conv.astype(cache["conv"].dtype))
        else:  # hybrid
            (h, conv), (ks, vs) = caches
            cache = dict(cache, h=h, conv=conv.astype(cache["conv"].dtype),
                         k=place(ks, cache["k"]), v=place(vs, cache["v"]))
        cache["pos"] = jnp.asarray(S, jnp.int32)
        x_last = apply_norm(x[:, -1:], params["final_norm"])
        return self.lm_logits(params, x_last), cache

    def prefill_into_slot(self, params: Params, batch: Dict, cache: Cache,
                          slot: jnp.ndarray) -> Tuple[jnp.ndarray, Cache]:
        """Prefill ONE session (batch-1 prompt) into one slot of a
        slotted cache (per-slot ``pos`` vector; see ``init_cache``).

        ``slot`` is a traced scalar, so admission into any slot reuses
        one compiled program per distinct prompt length; K/V land at
        positions ``0..S-1`` of the slot's row and ``pos[slot] = S``.
        Stale K/V beyond ``S`` from a previous occupant stay masked out
        by the per-slot length mask until overwritten.  Returns the
        last-position logits (1, 1, V) and the updated cache."""
        cfg = self.cfg
        if cfg.family not in ("dense", "vlm", "audio", "moe"):
            raise NotImplementedError(
                f"prefill_into_slot targets attention families, got "
                f"{cfg.family!r}")
        if "block_table" in cache:
            # paged cache: the whole prompt is one chunk (the scheduler
            # must have pointed block_table[slot] at allocated pages)
            return self.prefill_chunk_into_slot(params, batch, cache, slot,
                                                jnp.int32(0))
        x, _, caches = self.backbone(params, batch, collect_cache=True)
        S = x.shape[1]
        k, v = caches                            # (L, 1, S, Hkv, hd)
        kv_len = cache["k"].shape[2]
        assert x.shape[0] == 1, "prefill_into_slot takes a batch-1 prompt"
        assert S <= kv_len, (S, kv_len)
        zero = jnp.int32(0)
        start = (zero, jnp.asarray(slot, jnp.int32), zero, zero, zero)
        updates: Cache = {"pos": cache["pos"].at[slot].set(S)}
        if "k_scale" in cache:
            from repro.quant import kv as kvq
            k, ks = kvq.quantize_kv_write(k)
            v, vs = kvq.quantize_kv_write(v)
            updates.update(
                k_scale=jax.lax.dynamic_update_slice(
                    cache["k_scale"], ks, start[:-1]),
                v_scale=jax.lax.dynamic_update_slice(
                    cache["v_scale"], vs, start[:-1]))
        updates.update(
            k=jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), start),
            v=jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), start))
        cache = dict(cache, **updates)
        x_last = apply_norm(x[:, -1:], params["final_norm"])
        return self.lm_logits(params, x_last), cache

    def prefill_chunk_into_slot(self, params: Params, batch: Dict,
                                cache: Cache, slot: jnp.ndarray,
                                start_pos: jnp.ndarray
                                ) -> Tuple[jnp.ndarray, Cache]:
        """Prefill one CHUNK of a session's prompt into a paged cache.

        ``batch["tokens"]`` is (1, C) — chunk tokens at absolute
        positions ``start_pos .. start_pos + C - 1``; ``start_pos`` must
        be page-aligned (chunk boundaries land on page boundaries, so a
        chunk's K/V writes cover whole pages).  The chunk attends over
        the session's cached prefix plus itself (exact math — see
        ``attention_prefill_paged``), so feeding a prompt chunk-by-chunk
        is token-identical to one whole-prompt prefill.  ``slot`` and
        ``start_pos`` are traced: one compiled program per distinct
        chunk length, amortised over all admissions.  Returns the
        chunk's last-position logits (1, 1, V) and the updated cache
        (``pos[slot] = start_pos + C``)."""
        cfg = self.cfg
        assert "block_table" in cache, "prefill_chunk_into_slot needs paged"
        tokens = batch["tokens"]
        assert tokens.shape[0] == 1, "chunk prefill takes one session"
        x = self.embed_tokens(params, tokens)
        C = x.shape[1]
        start_pos = jnp.asarray(start_pos, jnp.int32)
        positions = (start_pos + jnp.arange(C))[None, :]
        angles = self.angle_fn(positions)
        slot_pages = cache["block_table"][slot]

        quantized_kv = "k_scale" in cache
        slab_keys = self._page_slab_keys(cache)

        def body(h, inp):
            bp, pools = inp[0], inp[1:]
            res = attn.attention_prefill_paged(
                bp["attn"], apply_norm(h, bp["norm1"]), pools[0], pools[1],
                slot_pages, start_pos, angles, cfg, apply_rope,
                k_scale_pool=pools[2] if quantized_kv else None,
                v_scale_pool=pools[3] if quantized_kv else None)
            a_out, pools = res[0], res[1:]
            h = h + a_out
            hn = apply_norm(h, bp["norm2"])
            if cfg.family == "moe":
                m_out, _ = moe.moe_forward(bp["moe"], hn, cfg)
            else:
                m_out = mlp_forward(bp["mlp"], hn, cfg.mlp_gated)
            return h + m_out, pools

        x, pools = jax.lax.scan(
            body, x,
            (params["blocks"],) + tuple(cache[key] for key in slab_keys))
        cache = dict(cache, pos=cache["pos"].at[slot].set(start_pos + C),
                     **dict(zip(slab_keys, pools)))
        x_last = apply_norm(x[:, -1:], params["final_norm"])
        return self.lm_logits(params, x_last), cache

    # ------------------------------------------------------------------
    # decode
    # ------------------------------------------------------------------
    def _attn_block_decode(self, bp, x, k_cache, v_cache, write_pos, mask,
                           angles, backend=None, k_scale=None, v_scale=None,
                           active=None):
        cfg = self.cfg
        res = attn.attention_decode(
            bp["attn"], apply_norm(x, bp["norm1"]), k_cache, v_cache,
            write_pos, mask, angles, cfg, apply_rope,
            backend=backend or self.decode_backend,
            k_scale=k_scale, v_scale=v_scale, active=active)
        if k_scale is not None:
            a_out, k_cache, v_cache, k_scale, v_scale = res
        else:
            a_out, k_cache, v_cache = res
        x = x + a_out
        h = apply_norm(x, bp["norm2"])
        if cfg.family == "moe":
            m_out, _ = moe.moe_forward(bp["moe"], h, cfg)
        else:
            m_out = mlp_forward(bp["mlp"], h, cfg.mlp_gated)
        if k_scale is not None:
            return x + m_out, k_cache, v_cache, k_scale, v_scale
        return x + m_out, k_cache, v_cache

    def _attn_block_decode_paged(self, bp, x, k_pool, v_pool, block_table,
                                 pos, mask, angles, backend=None,
                                 active=None, k_scale_pool=None,
                                 v_scale_pool=None):
        cfg = self.cfg
        res = attn.attention_decode_paged(
            bp["attn"], apply_norm(x, bp["norm1"]), k_pool, v_pool,
            block_table, pos, mask, angles, cfg, apply_rope,
            backend=backend or self.decode_backend, active=active,
            k_scale_pool=k_scale_pool, v_scale_pool=v_scale_pool)
        a_out, pools = res[0], res[1:]
        x = x + a_out
        h = apply_norm(x, bp["norm2"])
        if cfg.family == "moe":
            m_out, _ = moe.moe_forward(bp["moe"], h, cfg)
        else:
            m_out = mlp_forward(bp["mlp"], h, cfg.mlp_gated)
        return (x + m_out,) + pools

    def _mamba_block_decode(self, bp, x, h, conv):
        y, h, conv = mamba2.mamba_decode_step(
            bp["mamba"], apply_norm(x, bp.get("norm1")), h, conv, self.cfg)
        return x + y, h, conv

    # staticcheck: hotpath
    def decode_step(self, params: Params, cache: Cache, tokens: jnp.ndarray,
                    active: Optional[jnp.ndarray] = None
                    ) -> Tuple[jnp.ndarray, Cache]:
        """One new token per sequence.  tokens (B,1) or (B,1,K).

        With a slotted cache (``pos`` is a (B,) vector) every sequence
        advances at its own position: per-slot write offsets and (B, S)
        length masks, same compiled program every step regardless of
        which sessions occupy which slots.

        With a paged cache the step goes through
        ``attention_decode_paged``; the model's ``decode_backend``
        selects the route — ``"pallas"`` runs the fused block-table
        kernel (pages read in place, no gathered view), anything else
        the gather+SDPA reference.

        ``active`` (B,) bool (slotted caches, attention families only)
        turns inactive lanes into device-side no-ops: their K/V write is
        clamped (contiguous: row rewrite; paged: redirected to the
        garbage page) and their position does not advance.  This is what
        lets a horizon-K fused tick (``decode_steps``) carry lanes that
        hit EOS or their token budget mid-horizon without corrupting
        their cache — their (garbage) logits still come out and are
        discarded by the sampler clamp."""
        cfg = self.cfg
        x = self.embed_tokens(params, tokens)
        B = x.shape[0]
        pos = cache["pos"]
        slotted = pos.ndim == 1
        paged = "block_table" in cache
        if active is not None:
            if not slotted or cfg.family not in ("dense", "vlm", "audio",
                                                 "moe"):
                raise NotImplementedError(
                    "active-lane masking targets slotted caches of the "
                    "attention families")
            active = jnp.asarray(active, bool)
        if self.angle_fn:
            if paged:
                # virtual per-slot length = block-table span; the write
                # position is resolved through the block table inside
                # attention_decode_paged
                kv_len = cache["block_table"].shape[1] * cache["k"].shape[2]
                ring, write_pos = False, pos
            else:
                kv_len = cache["k"].shape[2]
                ring = bool(cfg.sliding_window) and kv_len <= cfg.sliding_window
                write_pos = pos % kv_len if ring else pos
            mask = attn.decode_mask(pos, kv_len, ring=ring)
            positions = (pos[:, None] if slotted
                         else jnp.broadcast_to(pos[None, None], (B, 1)))
            angles = self.angle_fn(positions)
        else:
            angles, mask, write_pos = None, None, pos

        new_cache = dict(cache)
        quantized_kv = "k_scale" in cache
        if cfg.family in ("dense", "vlm", "audio", "moe"):
            if paged:
                block_table = cache["block_table"]
                slab_keys = self._page_slab_keys(cache)

                def body(h, inp):
                    bp, pools = inp[0], inp[1:]
                    res = self._attn_block_decode_paged(
                        bp, h, pools[0], pools[1], block_table, pos, mask,
                        angles, active=active,
                        k_scale_pool=pools[2] if quantized_kv else None,
                        v_scale_pool=pools[3] if quantized_kv else None)
                    return res[0], res[1:]
                x, pools = jax.lax.scan(
                    body, x,
                    (params["blocks"],)
                    + tuple(cache[key] for key in slab_keys))
                new_cache.update(zip(slab_keys, pools))
            elif quantized_kv:
                def body(h, inp):
                    bp, kc, vc, ks, vs = inp
                    h, kc, vc, ks, vs = self._attn_block_decode(
                        bp, h, kc, vc, write_pos, mask, angles,
                        k_scale=ks, v_scale=vs, active=active)
                    return h, (kc, vc, ks, vs)
                x, (k, v, ks, vs) = jax.lax.scan(
                    body, x, (params["blocks"], cache["k"], cache["v"],
                              cache["k_scale"], cache["v_scale"]))
                new_cache.update(k=k, v=v, k_scale=ks, v_scale=vs)
            else:
                def body(h, inp):
                    bp, kc, vc = inp
                    h, kc, vc = self._attn_block_decode(bp, h, kc, vc, write_pos,
                                                        mask, angles,
                                                        active=active)
                    return h, (kc, vc)
                x, (k, v) = jax.lax.scan(body, x, (params["blocks"], cache["k"], cache["v"]))
                new_cache.update(k=k, v=v)
        elif cfg.family == "ssm":
            def body(h, inp):
                bp, hs, conv = inp
                h, hs, conv = self._mamba_block_decode(bp, h, hs, conv)
                return h, (hs, conv)
            x, (hs, conv) = jax.lax.scan(body, x, (params["blocks"], cache["h"], cache["conv"]))
            new_cache.update(h=hs, conv=conv)
        else:  # hybrid
            groups = self._hybrid_groups()
            hs_out, conv_out, k_out, v_out = [], [], [], []
            sp = params["shared_attn"]
            for a, (g0, g1) in enumerate(groups):
                x2, kc, vc = self._attn_block_decode(
                    sp, x, cache["k"][a], cache["v"][a], write_pos, mask, angles)
                x = x2
                k_out.append(kc)
                v_out.append(vc)
                gp = jax.tree_util.tree_map(lambda arr: arr[g0:g1], params["blocks"])

                def body(h, inp):
                    bp, hs, conv = inp
                    h, hs, conv = self._mamba_block_decode(bp, h, hs, conv)
                    return h, (hs, conv)
                x, (hs, conv) = jax.lax.scan(
                    body, x, (gp, cache["h"][g0:g1], cache["conv"][g0:g1]))
                hs_out.append(hs)
                conv_out.append(conv)
            new_cache.update(h=jnp.concatenate(hs_out, axis=0),
                             conv=jnp.concatenate(conv_out, axis=0),
                             k=jnp.stack(k_out, axis=0), v=jnp.stack(v_out, axis=0))
        new_cache["pos"] = (pos + 1 if active is None
                            else pos + active.astype(jnp.int32))
        x = apply_norm(x, params["final_norm"])
        return self.lm_logits(params, x), new_cache

    # staticcheck: hotpath
    def decode_steps(self, params: Params, cache: Cache, tokens: jnp.ndarray,
                     key: jnp.ndarray, steps_left: Optional[jnp.ndarray] = None,
                     *, horizon: int, temperature: float = 0.0,
                     top_k: int = 0, eos_id: Optional[int] = None
                     ) -> Tuple[jnp.ndarray, Cache]:
        """Advance every sequence up to ``horizon`` tokens inside ONE
        compiled program: ``lax.scan`` over ``decode_step`` with
        on-device sampling (greedy argmax, or categorical with
        ``fold_in(key, step)`` per-step keys), returning the token
        matrix (B, horizon) in a single transfer.

        This is the paper's CUDA-Graphs lesson applied across steps: the
        per-token host round-trip (Python + dispatch + sync) is paid
        once per *macro-tick* instead of once per token.

        ``steps_left`` (B,) int32 caps each lane's real steps (slotted
        caches, attention families): a lane stops being ``active`` once
        its budget is spent or — with ``eos_id`` set — once it samples
        EOS, after which its cache writes are no-ops, its position
        freezes, and its emitted tokens repeat the last real one (the
        host trims by its own ``steps_left``/EOS accounting, so the
        padding is never observed).  ``steps_left=None`` runs every lane
        for the full horizon (the single-stream fused-generation path —
        any family, any cache layout).

        Greedy streams are token-identical to ``horizon=1`` stepping;
        stochastic sampling draws from the same family but under
        per-step folded keys (one key per device step, as the
        single-step scheduler does per tick)."""
        from repro.serving.sampling import sample
        masked = steps_left is not None
        if masked:
            if self.cfg.n_codebooks:
                raise NotImplementedError(
                    "steps_left masking serves single-codebook archs")
            steps_left = jnp.asarray(steps_left, jnp.int32)
        if eos_id is not None and not masked:
            raise NotImplementedError("eos_id requires steps_left masking")

        def body(carry, step):
            cache, tok, alive = carry
            active = (alive & (step < steps_left)) if masked else None
            logits, cache = self.decode_step(params, cache, tok,
                                             active=active)
            k = jax.random.fold_in(key, step)
            nxt = sample(logits[:, -1], k, temperature=temperature,
                         top_k=top_k)
            if masked:
                nxt = jnp.where(active, nxt, tok[:, 0])
                if eos_id is not None:
                    alive = alive & ~(active & (nxt == eos_id))
            return (cache, nxt[:, None], alive), nxt

        alive0 = jnp.ones((tokens.shape[0],), bool)
        (cache, _, _), toks = jax.lax.scan(body, (cache, tokens, alive0),
                                           jnp.arange(horizon))
        return jnp.moveaxis(toks, 0, 1), cache

    # ------------------------------------------------------------------
    # dispatch A/B decomposition (paper §5)
    # ------------------------------------------------------------------
    def step_program(self, params: Params, cache: Cache) -> StepProgram:
        """Decompose decode_step into [embed] + [block_i]* + [head] stages
        over a state dict, for the eager / stage_jit / full_jit A/B.
        Attention-family archs only (the A/B targets the paper's models).

        Block stages mirror ``decode_step``'s cache semantics exactly —
        ring (sliding-window) write offsets/masks and int8-KV scale
        threading included — so the A/B touches the launch term and ONLY
        the launch term on every cache layout it accepts."""
        cfg = self.cfg
        assert cfg.family in ("dense", "vlm", "audio", "moe")
        if cache is not None and "block_table" in cache:
            raise NotImplementedError(
                "step_program does not decompose the paged decode step; "
                "paged serving runs the full_jit arm only")

        def embed_stage(state):
            tokens = state["tokens"]
            x = self.embed_tokens(params, tokens)
            B = x.shape[0]
            pos = state["cache"]["pos"]
            positions = (pos[:, None] if pos.ndim == 1
                         else jnp.broadcast_to(pos[None, None], (B, 1)))
            return dict(state, x=x, angles=self.angle_fn(positions))

        def make_block_stage(i):
            bp = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])

            def stage(state):
                c = state["cache"]
                kv_len = c["k"].shape[2]
                # mirror decode_step's ring handling: once pos >= kv_len
                # the write must wrap (pos % kv_len) and the mask must
                # treat every slot as in-window, else the update clamps
                # to the last slot and attention silently goes wrong
                ring = bool(cfg.sliding_window) and kv_len <= cfg.sliding_window
                write_pos = c["pos"] % kv_len if ring else c["pos"]
                mask = attn.decode_mask(c["pos"], kv_len, ring=ring)
                if "k_scale" in c:
                    x, kc, vc, ks, vs = self._attn_block_decode(
                        bp, state["x"], c["k"][i], c["v"][i], write_pos,
                        mask, state["angles"],
                        k_scale=c["k_scale"][i], v_scale=c["v_scale"][i])
                    c = dict(c, k=c["k"].at[i].set(kc),
                             v=c["v"].at[i].set(vc),
                             k_scale=c["k_scale"].at[i].set(ks),
                             v_scale=c["v_scale"].at[i].set(vs))
                else:
                    x, kc, vc = self._attn_block_decode(
                        bp, state["x"], c["k"][i], c["v"][i], write_pos,
                        mask, state["angles"])
                    c = dict(c, k=c["k"].at[i].set(kc),
                             v=c["v"].at[i].set(vc))
                return dict(state, x=x, cache=c)
            return stage

        def head_stage(state):
            x = apply_norm(state["x"], params["final_norm"])
            c = dict(state["cache"])
            c["pos"] = c["pos"] + 1
            return dict(state, logits=self.lm_logits(params, x), cache=c)

        stages = [embed_stage] + [make_block_stage(i) for i in range(cfg.n_layers)] \
            + [head_stage]
        return StepProgram(stages)


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins, no allocation) — dry-run inputs
# --------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, *, seq_len: int, batch: int, kind: str
                ) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract inputs for train/prefill/decode steps.

    vlm: precomputed patch embeddings replace token embedding lookups for
    the full-seq shapes (frontend stub per the assignment); decode feeds
    tokens.  audio: per-codebook token ids.
    """
    i32, bf16 = jnp.int32, jnp.bfloat16
    if kind in ("train", "prefill"):
        if cfg.family == "vlm":
            specs = {
                "embeds": jax.ShapeDtypeStruct((batch, seq_len, cfg.d_model), bf16),
                "positions": jax.ShapeDtypeStruct((batch, seq_len, 3), i32),
            }
        elif cfg.family == "audio":
            specs = {"tokens": jax.ShapeDtypeStruct(
                (batch, seq_len, cfg.n_codebooks), i32)}
        else:
            specs = {"tokens": jax.ShapeDtypeStruct((batch, seq_len), i32)}
        if kind == "train":
            lab_shape = ((batch, seq_len, cfg.n_codebooks) if cfg.family == "audio"
                         else (batch, seq_len))
            specs["labels"] = jax.ShapeDtypeStruct(lab_shape, i32)
        return specs
    # decode: one new token, KV cache of seq_len handled separately
    if cfg.family == "audio":
        return {"tokens": jax.ShapeDtypeStruct((batch, 1, cfg.n_codebooks), i32)}
    return {"tokens": jax.ShapeDtypeStruct((batch, 1), i32)}
