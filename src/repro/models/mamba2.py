"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) blocks.

Train/prefill use the chunked SSD algorithm (intra-chunk quadratic +
inter-chunk linear recurrence via scan); decode is the O(1) recurrent
update.  All SSD math in float32, params/activations in model dtype.

Layout: d_inner = expand*d_model channels split into H = d_inner/P heads
of dim P; B/C projections have G groups of state dim N (G=1 here),
broadcast over H/G heads per group via a (g, hg) factorization (no
materialized repeat).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.common import dense_init, rmsnorm
from repro.quant.paths import matmul

Params = Dict[str, jnp.ndarray]

DEFAULT_CHUNK = 128


def init_mamba(key, cfg: ArchConfig, dtype) -> Params:
    ks = jax.random.split(key, 6)
    D, DI, H, N, G = (cfg.d_model, cfg.d_inner, cfg.n_ssm_heads,
                      cfg.ssm_state, cfg.ssm_groups)
    conv_ch = cfg.conv_channels
    d_in_proj = 2 * DI + 2 * G * N + H
    # dt init: softplus(dt_bias) ~ U[1e-3, 1e-1]
    dt = jnp.exp(jax.random.uniform(ks[3], (H,), jnp.float32,
                                    jnp.log(1e-3), jnp.log(1e-1)))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    return {
        "in_proj": dense_init(ks[0], D, d_in_proj, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_ch), jnp.float32)
                   / jnp.sqrt(cfg.ssm_conv)).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jax.random.uniform(ks[2], (H,), jnp.float32, 1.0, 16.0)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": dt_bias,
        "gate_norm": jnp.ones((DI,), dtype),
        "out_proj": dense_init(ks[4], DI, D, dtype),
    }


def _split_proj(cfg: ArchConfig, zxbcdt: jnp.ndarray):
    DI, G, N, H = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.n_ssm_heads
    z = zxbcdt[..., :DI]
    xBC = zxbcdt[..., DI:2 * DI + 2 * G * N]
    dt = zxbcdt[..., 2 * DI + 2 * G * N:]
    assert dt.shape[-1] == H
    return z, xBC, dt


def _split_xbc(cfg: ArchConfig, xBC: jnp.ndarray):
    DI, G, N = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    x = xBC[..., :DI]
    Bm = xBC[..., DI:DI + G * N]
    Cm = xBC[..., DI + G * N:]
    lead = xBC.shape[:-1]
    return (x.reshape(*lead, cfg.n_ssm_heads, cfg.ssm_head_dim),
            Bm.reshape(*lead, G, N), Cm.reshape(*lead, G, N))


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """(..., q) -> (..., q, q): sum_{r=s+1..t} x_r below/on diagonal, -inf above."""
    q = x.shape[-1]
    xc = jnp.cumsum(x, axis=-1)
    d = xc[..., :, None] - xc[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, d, -jnp.inf)


def _causal_conv_full(xBC: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv, width K: xBC (B,S,C), w (K,C)."""
    K = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xBC.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def ssd_chunked(x, dt, A, Bm, Cm, h0, chunk: int = DEFAULT_CHUNK):
    """Chunked SSD scan.

    x (b,l,h,p) f32; dt (b,l,h) f32 (post-softplus); A (h,) f32 (negative);
    Bm/Cm (b,l,g,n) f32; h0 (b,h,p,n) f32 initial state.
    Returns (y (b,l,h,p), h_final (b,h,p,n)).
    """
    b, l, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    hg = H // G
    q = min(chunk, l)
    assert l % q == 0, f"seq {l} not divisible by chunk {q}"
    c = l // q

    xc = (x * dt[..., None]).reshape(b, c, q, G, hg, P)
    Bc = Bm.reshape(b, c, q, G, N)
    Cc = Cm.reshape(b, c, q, G, N)
    dA = (dt * A).reshape(b, c, q, G, hg).transpose(0, 3, 4, 1, 2)  # (b,g,hg,c,q)
    dA_cs = jnp.cumsum(dA, axis=-1)

    # 1. intra-chunk
    L = jnp.exp(_segsum(dA))                                        # (b,g,hg,c,q,q)
    Y_diag = jnp.einsum("bcqgn,bcsgn,bghcqs,bcsghp->bcqghp", Cc, Bc, L, xc)

    # 2. per-chunk input states
    decay_states = jnp.exp(dA_cs[..., -1:] - dA_cs)                 # (b,g,hg,c,q)
    states = jnp.einsum("bcqgn,bghcq,bcqghp->bcghpn", Bc, decay_states, xc)

    # 3. inter-chunk recurrence (the only sequential part)
    chunk_decay = jnp.exp(dA_cs[..., -1])                           # (b,g,hg,c)
    h0g = h0.reshape(b, G, hg, P, N)

    def step(h, inp):
        s_c, d_c = inp                    # (b,g,hg,p,n), (b,g,hg)
        h_out = h * d_c[..., None, None] + s_c
        return h_out, h                   # emit state ENTERING the chunk

    h_fin, h_in = jax.lax.scan(
        step, h0g,
        (states.transpose(1, 0, 2, 3, 4, 5), chunk_decay.transpose(3, 0, 1, 2)))
    h_in = h_in.transpose(1, 0, 2, 3, 4, 5)                         # (b,c,g,hg,p,n)

    # 4. state -> output
    state_decay_out = jnp.exp(dA_cs)                                # (b,g,hg,c,q)
    Y_off = jnp.einsum("bcqgn,bcghpn,bghcq->bcqghp", Cc, h_in, state_decay_out)

    y = (Y_diag + Y_off).reshape(b, l, H, P)
    return y, h_fin.reshape(b, H, P, N)


def mamba_forward(p: Params, x: jnp.ndarray, cfg: ArchConfig,
                  h0=None, conv0=None, chunk: int = DEFAULT_CHUNK
                  ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward (train/prefill).

    x (B,S,D).  Returns (y (B,S,D), h_final, conv_state) so prefill can
    seed decode.
    """
    from repro.launch import hints
    B, S, _ = x.shape
    H, P, N = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    zxbcdt = hints.constrain(matmul(x, p["in_proj"]), ("dp", None, "tp"))
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC = _causal_conv_full(xBC, p["conv_w"], p["conv_b"])
    xs, Bm, Cm = _split_xbc(cfg, xBC)

    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    h0 = h0 if h0 is not None else jnp.zeros((B, H, P, N), jnp.float32)
    y, h_fin = ssd_chunked(xs.astype(jnp.float32), dtf, A,
                           Bm.astype(jnp.float32), Cm.astype(jnp.float32),
                           h0, chunk)
    y = y + xs.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(B, S, cfg.d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"])
    out = matmul(y, p["out_proj"])
    # conv state for decode continuation: last (K-1) pre-conv inputs
    K = cfg.ssm_conv
    zxbc_tail = matmul(x[:, -(K - 1):, :], p["in_proj"]) if S >= K - 1 else None
    if zxbc_tail is not None:
        _, conv_tail, _ = _split_proj(cfg, zxbc_tail)
    else:
        conv_tail = jnp.zeros((B, K - 1, cfg.conv_channels), x.dtype)
    return out, h_fin, conv_tail.astype(x.dtype)


def mamba_decode_step(p: Params, x: jnp.ndarray, h: jnp.ndarray,
                      conv_state: jnp.ndarray, cfg: ArchConfig
                      ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """O(1) recurrent step.

    x (B,1,D); h (B,H,P,N) f32; conv_state (B,K-1,conv_ch).
    Returns (y (B,1,D), h', conv_state')."""
    B = x.shape[0]
    zxbcdt = matmul(x[:, 0, :], p["in_proj"])
    z, xBC_new, dt = _split_proj(cfg, zxbcdt)

    window = jnp.concatenate([conv_state, xBC_new[:, None, :]], axis=1)  # (B,K,C)
    conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32)) + p["conv_b"].astype(jnp.float32)
    xBC_act = jax.nn.silu(conv_out)
    xs, Bm, Cm = _split_xbc(cfg, xBC_act)            # (B,H,P), (B,G,N), (B,G,N)

    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,H)
    A = -jnp.exp(p["A_log"])
    G = cfg.ssm_groups
    hg = cfg.n_ssm_heads // G
    decay = jnp.exp(dtf * A)                                        # (B,H)
    xg = (xs * dtf[..., None]).reshape(B, G, hg, cfg.ssm_head_dim)
    hG = h.reshape(B, G, hg, cfg.ssm_head_dim, cfg.ssm_state)
    dBx = jnp.einsum("bghp,bgn->bghpn", xg.astype(jnp.float32), Bm.astype(jnp.float32))
    h_new = hG * decay.reshape(B, G, hg)[..., None, None] + dBx
    y = jnp.einsum("bghpn,bgn->bghp", h_new, Cm.astype(jnp.float32))
    y = y.reshape(B, cfg.n_ssm_heads, cfg.ssm_head_dim)
    y = y + xs.astype(jnp.float32) * p["D"][:, None]
    y = y.reshape(B, cfg.d_inner).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["gate_norm"])
    out = matmul(y, p["out_proj"])[:, None, :]
    conv_state = window[:, 1:, :].astype(conv_state.dtype)
    return out, h_new.reshape(*h.shape), conv_state
