"""Shared model primitives: norms, RoPE / M-RoPE, MLPs, init helpers.

Pure-functional: params are nested dicts of jnp arrays; every init
function is deterministic in its PRNG key so ``jax.eval_shape`` gives
allocation-free abstract param trees for the dry-run.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

Params = Dict[str, jnp.ndarray]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), dtype=jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), dtype=jnp.float32) * 0.02).astype(dtype)


# --------------------------------------------------------------------------
# norms
# --------------------------------------------------------------------------

def rmsnorm(x: jnp.ndarray, w: Optional[jnp.ndarray], eps: float = 1e-6) -> jnp.ndarray:
    """RMSNorm; w=None gives the non-parametric variant (OLMo)."""
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    if w is not None:
        y = y * w.astype(jnp.float32)
    return y.astype(dt)


def init_norm(cfg: ArchConfig, dtype, d: Optional[int] = None):
    if cfg.norm == "nonparametric":
        return None
    return jnp.ones((d or cfg.d_model,), dtype=dtype)


def apply_norm(x, w):
    return rmsnorm(x, w)


# --------------------------------------------------------------------------
# RoPE (rotate-half convention) and Qwen2-VL M-RoPE
# --------------------------------------------------------------------------

def rope_inv_freq(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def _rope_angles(positions: jnp.ndarray, inv_freq: jnp.ndarray) -> jnp.ndarray:
    """positions (..., S) -> angles (..., S, head_dim/2)."""
    return positions[..., None].astype(jnp.float32) * inv_freq


def mrope_angles(positions_thw: jnp.ndarray, inv_freq: jnp.ndarray,
                 sections: Tuple[int, int, int]) -> jnp.ndarray:
    """Qwen2-VL M-RoPE: positions (..., S, 3) (t,h,w ids), sections sum to
    head_dim/2.  Each frequency band takes its angle from its section's
    position stream.  Text-only tokens carry t==h==w, reducing to RoPE."""
    angles = positions_thw[..., None, :].astype(jnp.float32) * inv_freq[:, None]  # (...,S,hd/2,3)
    sel = jnp.concatenate([
        jnp.full((s,), i, dtype=jnp.int32) for i, s in enumerate(sections)
    ])  # (hd/2,)
    return jnp.take_along_axis(
        angles, jnp.broadcast_to(sel[..., None], angles.shape[:-1] + (1,)), axis=-1
    )[..., 0]


def apply_rope(x: jnp.ndarray, angles: jnp.ndarray) -> jnp.ndarray:
    """x (B, S, H, hd); angles (B?, S, hd/2) broadcastable over heads."""
    dt = x.dtype
    half = x.shape[-1] // 2
    cos = jnp.cos(angles)[..., None, :]     # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1).astype(dt)


def make_angle_fn(cfg: ArchConfig):
    """Return positions->angles for this arch (plain RoPE or M-RoPE)."""
    inv_freq = rope_inv_freq(cfg.head_dim, cfg.rope_theta)
    if cfg.mrope_sections is not None:
        sections = cfg.mrope_sections

        def angle_fn(positions):
            if positions.shape[-1] != 3:   # text-only stream: expand t==h==w
                positions = jnp.broadcast_to(positions[..., None],
                                             positions.shape + (3,))
            return mrope_angles(positions, inv_freq, sections)
        return angle_fn

    def angle_fn(positions):
        return _rope_angles(positions, inv_freq)
    return angle_fn


# --------------------------------------------------------------------------
# MLP (SwiGLU or plain GELU)
# --------------------------------------------------------------------------

def init_mlp(key, d_model: int, d_ff: int, gated: bool, dtype) -> Params:
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[1], d_model, d_ff, dtype),
         "down": dense_init(ks[2], d_ff, d_model, dtype)}
    if gated:
        p["gate"] = dense_init(ks[0], d_model, d_ff, dtype)
    return p


def mlp_forward(p: Params, x: jnp.ndarray, gated: bool) -> jnp.ndarray:
    from repro.launch import hints
    from repro.quant.paths import matmul
    if gated:
        h = jax.nn.silu(matmul(x, p["gate"])) * matmul(x, p["up"])
    else:
        h = jax.nn.gelu(matmul(x, p["up"]))
    h = hints.constrain(h, ("dp",) + (None,) * (h.ndim - 2) + ("tp",))
    return matmul(h, p["down"])


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------

def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  z_loss: float = 0.0) -> jnp.ndarray:
    """Mean next-token CE; logits (..., V) upcast to f32; labels (...)."""
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    ll = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(lse - ll)
    if z_loss:
        loss = loss + z_loss * jnp.mean(lse ** 2)
    return loss
