"""GQA attention: full-sequence (train/prefill) and cached decode with
selectable backends (the paper's §6 attention-backend matrix).

Backends for the decode step:
  sdpa     — fused jnp softmax-attention (the dispatcher default)
  math     — explicitly decomposed softmax (the paper's MATH fallback)
  split_kv — flash-decoding style partitioned KV with partial-softmax
             combine (what GSPMD emits for a sequence-sharded cache)
  pallas   — the Pallas TPU kernel (kernels/decode_attention), interpret
             mode on CPU; on the paged path this selects the FUSED
             block-table kernel (kernels/paged_decode_attention) that
             reads pages in place — no paged_view gather
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.launch import hints
from repro.models.common import dense_init

Params = Dict[str, jnp.ndarray]

DECODE_BACKENDS = ("sdpa", "math", "split_kv", "pallas")

# above this sequence length, full attention runs q-block-chunked (exact
# math, flash-style memory): scores never materialise beyond (bq, S).
# configure() lets launchers/perf-experiments retune without rebuild.
CHUNKED_ATTN_THRESHOLD = 8192
CHUNK_Q = 1024


def configure(threshold: Optional[int] = None, chunk_q: Optional[int] = None):
    global CHUNKED_ATTN_THRESHOLD, CHUNK_Q
    if threshold is not None:
        CHUNKED_ATTN_THRESHOLD = threshold
    if chunk_q is not None:
        CHUNK_Q = chunk_q


def init_attention(key, cfg: ArchConfig, dtype) -> Params:
    ks = jax.random.split(key, 4)
    hd, hq, hkv = cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    p = {
        "wq": dense_init(ks[0], cfg.d_model, hq * hd, dtype),
        "wk": dense_init(ks[1], cfg.d_model, hkv * hd, dtype),
        "wv": dense_init(ks[2], cfg.d_model, hkv * hd, dtype),
        "wo": dense_init(ks[3], hq * hd, cfg.d_model, dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * hd,), dtype)
        p["bk"] = jnp.zeros((hkv * hd,), dtype)
        p["bv"] = jnp.zeros((hkv * hd,), dtype)
    return p


def _project_qkv(p: Params, x: jnp.ndarray, cfg: ArchConfig):
    from repro.quant.paths import matmul
    B, S, _ = x.shape
    q = matmul(x, p["wq"])
    k = matmul(x, p["wk"])
    v = matmul(x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = hints.constrain(q.reshape(B, S, cfg.n_heads, cfg.head_dim),
                        ("dp", None, "tp"))
    k = hints.constrain(k.reshape(B, S, cfg.n_kv_heads, cfg.head_dim),
                        ("dp", None, "tp"))
    v = hints.constrain(v.reshape(B, S, cfg.n_kv_heads, cfg.head_dim),
                        ("dp", None, "tp"))
    return q, k, v


def _gqa_scores(q: jnp.ndarray, k: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """q (B,Sq,Hq,hd), k (B,Sk,Hkv,hd) -> scores (B,Hkv,G,Sq,Sk) f32.

    bf16 operands with an f32 accumulator (MXU-native; matches the
    paper's bf16-tensor-core SDPA semantics)."""
    B, Sq, Hq, hd = q.shape
    G = Hq // cfg.n_kv_heads
    qg = q.reshape(B, Sq, cfg.n_kv_heads, G, hd)
    return jnp.einsum("bqkgh,bskh->bkgqs", qg, k,
                      preferred_element_type=jnp.float32) * (hd ** -0.5)


def _gqa_out(probs: jnp.ndarray, v: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    """probs (B,Hkv,G,Sq,Sk) f32, v (B,Sk,Hkv,hd) -> (B,Sq,Hq*hd) f32."""
    B = probs.shape[0]
    o = jnp.einsum("bkgqs,bskh->bqkgh", probs.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    Sq = o.shape[1]
    return o.reshape(B, Sq, cfg.n_heads * cfg.head_dim)


def _causal_probs(scores: jnp.ndarray, q0: jnp.ndarray, S: int,
                  window: Optional[int]) -> jnp.ndarray:
    """scores (B,K,G,bq,S) for q rows starting at q0 -> masked softmax."""
    bq = scores.shape[3]
    qpos = q0 + jnp.arange(bq)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    scores = jnp.where(mask, scores, -jnp.inf)
    return jax.nn.softmax(scores, axis=-1)


def attention_full(p: Params, x: jnp.ndarray, angles: jnp.ndarray,
                   cfg: ArchConfig, apply_rope_fn,
                   positions: Optional[jnp.ndarray] = None
                   ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray]]:
    """Full causal attention (train / prefill). Returns (out, (k, v)).

    Long sequences (> CHUNKED_ATTN_THRESHOLD) run q-block-chunked via
    lax.scan — exact math, (bq, S) score footprint instead of (S, S)."""
    from repro.quant.paths import matmul
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, x, cfg)
    q = apply_rope_fn(q, angles)
    k = apply_rope_fn(k, angles)

    if S <= CHUNKED_ATTN_THRESHOLD:
        # prefer kv-head TP; context-parallel (query-seq) fallback for
        # head counts that don't divide the model axis
        scores = hints.constrain_first_fit(
            _gqa_scores(q, k, cfg),
            [("dp", "tp"), ("dp", None, None, "tp")])
        probs = _causal_probs(scores, jnp.int32(0), S, cfg.sliding_window)
        out = _gqa_out(probs, v, cfg).astype(x.dtype)
        return matmul(out, p["wo"]), (k, v)

    bq = CHUNK_Q
    assert S % bq == 0, (S, bq)
    qb = q.reshape(B, S // bq, bq, cfg.n_heads, cfg.head_dim)

    def body(_, inp):
        i, qi = inp                                   # qi (B,bq,Hq,hd)
        scores = hints.constrain_first_fit(
            _gqa_scores(qi, k, cfg),
            [("dp", "tp"), ("dp", None, None, "tp")])
        probs = _causal_probs(scores, i * bq, S, cfg.sliding_window)
        return None, _gqa_out(probs, v, cfg).astype(x.dtype)

    # chunk body is always rematted: the (bq, S) score tile is recomputed
    # in backward instead of saved — flash-attention residual behaviour
    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    _, blocks = jax.lax.scan(
        body, None, (jnp.arange(S // bq), jnp.moveaxis(qb, 1, 0)))
    out = jnp.moveaxis(blocks, 0, 1).reshape(B, S, cfg.n_heads * cfg.head_dim)
    return matmul(out, p["wo"]), (k, v)


# --------------------------------------------------------------------------
# decode (single new token against a static cache)
# --------------------------------------------------------------------------

def decode_mask(pos: jnp.ndarray, s_max: int, *, ring: bool = False):
    """Valid-slot mask for a decode step.

    Full cache (s_max >= ctx): slots 0..pos valid.
    Ring cache (sliding window == s_max): slots <= pos valid until the
    ring wraps (pos >= s_max), after which every slot holds an in-window
    token.  Softmax is permutation-invariant over slots, so slot order
    never matters; RoPE was applied at absolute positions on write.

    ``pos`` may be a scalar (one shared position, the static-batch path)
    or a (B,) vector of per-slot positions (continuous batching) — the
    latter yields a (B, s_max) per-slot length mask.
    """
    idx = jnp.arange(s_max)
    if jnp.ndim(pos):
        m = idx[None, :] <= pos[:, None]
        if ring:
            m = m | (pos[:, None] >= s_max)
        return m
    m = idx <= pos
    if ring:
        m = m | (pos >= s_max)
    return m


def _kv_write(dst: jnp.ndarray, new: jnp.ndarray, write_pos: jnp.ndarray,
              active: Optional[jnp.ndarray] = None):
    """Write the new (B, 1, ...) row into the cache's sequence axis.

    Scalar ``write_pos`` writes every sequence at the same slot (static
    batch); a (B,) vector writes each sequence at its own slot (slotted
    continuous batching) via a vmapped single-row update.

    ``active`` (B,) bool turns the write into a per-lane no-op: an
    inactive lane re-writes the row already under its position, so a
    horizon-K fused tick can keep finished lanes riding along in the
    batch without corrupting their cache (the multi-step analogue of the
    ring path's write clamp).
    """
    new = new.astype(dst.dtype)
    if jnp.ndim(write_pos) == 0:
        return jax.lax.dynamic_update_slice_in_dim(dst, new, write_pos, axis=1)
    if active is None:
        return jax.vmap(
            lambda d, n, p: jax.lax.dynamic_update_slice_in_dim(d, n, p, axis=0)
        )(dst, new, write_pos)

    def upd(d, n, p, a):
        old = jax.lax.dynamic_slice_in_dim(d, p, n.shape[0], axis=0)
        return jax.lax.dynamic_update_slice_in_dim(
            d, jnp.where(a, n, old), p, axis=0)
    return jax.vmap(upd)(dst, new, write_pos, active)


def _bmask(mask: jnp.ndarray, B: int) -> jnp.ndarray:
    """Normalise a valid-slot mask to (B, S): a shared (S,) mask (static
    batch, one position for all sequences) broadcasts; a (B, S) per-slot
    mask (continuous batching) passes through."""
    if mask.ndim == 2:
        return mask
    return jnp.broadcast_to(mask[None, :], (B, mask.shape[0]))


def _sdpa_decode(q, k_cache, v_cache, mask, cfg, k_scale=None, v_scale=None):
    """k_scale/v_scale (B,S,Hkv): int8-KV path.  The per-token scales are
    constant over head_dim, so they FOLD into the score/prob tensors
    exactly — the int8 codes only convert-fuse into the dots and no bf16
    KV copy is ever materialised (EXPERIMENTS.md §Perf C).

    ``mask`` is (S,) shared or (B, S) per-slot."""
    mask = _bmask(mask, q.shape[0])
    scores = _gqa_scores(q, k_cache.astype(q.dtype), cfg)    # (B,K,G,1,S)
    if k_scale is not None:
        scores = scores * k_scale.transpose(0, 2, 1)[:, :, None, None, :]
    scores = jnp.where(mask[:, None, None, None, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    if v_scale is not None:
        probs = probs * v_scale.transpose(0, 2, 1)[:, :, None, None, :]
    return _gqa_out(probs, v_cache.astype(q.dtype), cfg)


def _math_decode(q, k_cache, v_cache, mask, cfg):
    """Explicitly decomposed softmax (separate max/exp/sum/div ops)."""
    mask = _bmask(mask, q.shape[0])
    scores = _gqa_scores(q, k_cache, cfg)
    neg = jnp.float32(-1e30)
    scores = jnp.where(mask[:, None, None, None, :], scores, neg)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    z = jnp.sum(e, axis=-1, keepdims=True)
    probs = e / z
    return _gqa_out(probs, v_cache, cfg)


def _split_kv_decode(q, k_cache, v_cache, mask, cfg, n_partitions: int = 8):
    """Flash-decoding: partition the KV axis, partial softmax per
    partition, numerically-exact combine (log-sum-exp merge)."""
    mask = _bmask(mask, q.shape[0])
    B, S, Hkv, hd = k_cache.shape
    P = n_partitions
    while S % P:
        P //= 2
    sp = S // P
    kp = k_cache.reshape(B, P, sp, Hkv, hd)
    vp = v_cache.reshape(B, P, sp, Hkv, hd)
    maskp = mask.reshape(B, P, sp)

    def part(kpi, vpi, mi):
        scores = _gqa_scores(q, kpi, cfg)                    # (B,K,G,1,sp)
        scores = jnp.where(mi[:, None, None, None, :], scores, -jnp.inf)
        m = jnp.max(scores, axis=-1)                         # (B,K,G,1)
        m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
        e = jnp.exp(scores - m_safe[..., None])
        e = jnp.where(mi[:, None, None, None, :], e, 0.0)
        l = jnp.sum(e, axis=-1)
        acc = jnp.einsum("bkgqs,bskh->bkgqh", e, vpi.astype(jnp.float32))
        return m, l, acc

    ms, ls, accs = jax.vmap(part, in_axes=(1, 1, 1), out_axes=0)(kp, vp, maskp)
    m_glob = jnp.max(ms, axis=0)
    m_glob_safe = jnp.where(jnp.isfinite(m_glob), m_glob, 0.0)
    scale = jnp.exp(jnp.where(jnp.isfinite(ms), ms - m_glob_safe, -jnp.inf))
    l_glob = jnp.sum(ls * scale, axis=0)
    acc = jnp.sum(accs * scale[..., None], axis=0)
    out = acc / jnp.maximum(l_glob, 1e-30)[..., None]        # (B,K,G,1,hd)
    B_, K, G, _, hd_ = out.shape
    return out.transpose(0, 3, 1, 2, 4).reshape(B_, 1, K * G * hd_)


def _decode_attend(q, k_read, v_read, mask, cfg: ArchConfig, backend: str,
                   out_dtype, k_scale=None, v_scale=None,
                   paged=None) -> jnp.ndarray:
    """Run the selected decode backend over an (already updated) K/V view.

    Shared by the contiguous and paged decode paths — the backend matrix
    (§6) is identical in both layouts, and this is the ONE place backend
    routing happens.  ``paged`` is the
    ``(k_pool, v_pool, block_table, lengths)`` tuple of the paged cache
    (``k_read``/``v_read`` are None then), or the 6-tuple
    ``(..., k_scale_pool, v_scale_pool)`` of an int8-quantised pool:
    ``backend="pallas"`` routes to the fused paged kernel, which reads
    pages in place through the block table — no virtual view is ever
    materialised, and on the quantised pool the codes dequantise
    in-register inside the kernel's block loads (the traffic cut is
    *realised*) — while every other backend runs over the gathered
    ``paged_view`` reference; the quantised gather route materialises a
    dequantised model-dtype view first (bnb-style: stored bytes shrink
    but the per-step read traffic does not)."""
    if paged is not None:
        k_pool, v_pool, block_table, lengths = paged[:4]
        ks_pool, vs_pool = paged[4:] if len(paged) == 6 else (None, None)
        if backend == "pallas":
            from repro.kernels.paged_decode_attention import ops as pda_ops
            B = q.shape[0]
            o = pda_ops.paged_decode_attention(q[:, 0], k_pool, v_pool,
                                               block_table, lengths,
                                               k_scale_pool=ks_pool,
                                               v_scale_pool=vs_pool)
            return o.reshape(B, 1,
                             cfg.n_heads * cfg.head_dim).astype(out_dtype)
        if ks_pool is not None:
            from repro.quant import kv as kvq
            k_read = kvq.dequantize_kv(paged_view(k_pool, block_table),
                                       paged_view(ks_pool, block_table),
                                       out_dtype)
            v_read = kvq.dequantize_kv(paged_view(v_pool, block_table),
                                       paged_view(vs_pool, block_table),
                                       out_dtype)
        else:
            k_read = paged_view(k_pool, block_table)
            v_read = paged_view(v_pool, block_table)
    if backend == "sdpa":
        return _sdpa_decode(q, k_read, v_read, mask, cfg,
                            k_scale=k_scale, v_scale=v_scale).astype(out_dtype)
    if backend == "math":
        return _math_decode(q, k_read, v_read, mask, cfg).astype(out_dtype)
    if backend == "split_kv":
        return _split_kv_decode(q, k_read, v_read, mask, cfg).astype(out_dtype)
    if backend == "pallas":
        from repro.kernels.decode_attention import ops as da_ops
        B = q.shape[0]
        o = da_ops.decode_attention(q[:, 0], k_read, v_read, mask=mask)
        return o.reshape(B, 1, cfg.n_heads * cfg.head_dim).astype(out_dtype)
    raise ValueError(f"unknown decode backend {backend!r}")


def attention_decode(p: Params, x: jnp.ndarray, k_cache: jnp.ndarray,
                     v_cache: jnp.ndarray, write_pos: jnp.ndarray,
                     mask: jnp.ndarray, angles: jnp.ndarray, cfg: ArchConfig,
                     apply_rope_fn, backend: str = "sdpa",
                     k_scale=None, v_scale=None, active=None):
    """One-token decode.  x (B,1,D); cache (B,S_max,Hkv,hd).

    ``write_pos`` is the cache slot for the new K/V (== absolute pos for a
    full cache, pos % window for a ring cache) — scalar for a static
    batch, (B,) for per-slot positions (continuous batching); ``mask``
    (S_max,) or (B,S_max) marks valid slots (see ``decode_mask``).
    k_scale/v_scale (B,S_max,Hkv) enable the int8-quantised cache
    (repro.quant.kv).  ``active`` (B,) bool makes inactive lanes' cache
    writes per-lane no-ops (horizon-K fused ticks: lanes that hit EOS or
    their token budget mid-horizon stop mutating state on device).

    Returns (out, new_k, new_v[, new_k_scale, new_v_scale])."""
    from repro.quant import kv as kvq
    B, S1, _ = x.shape
    q, k_new, v_new = _project_qkv(p, x, cfg)
    q = apply_rope_fn(q, angles)
    k_new = apply_rope_fn(k_new, angles)
    quantized = k_scale is not None
    if quantized:
        kq, ks = kvq.quantize_kv_write(k_new)
        vq, vs = kvq.quantize_kv_write(v_new)
        k_cache = _kv_write(k_cache, kq, write_pos, active)
        v_cache = _kv_write(v_cache, vq, write_pos, active)
        k_scale = _kv_write(k_scale, ks, write_pos, active)
        v_scale = _kv_write(v_scale, vs, write_pos, active)
        k_read, v_read = k_cache, v_cache    # sdpa folds scales; others
        if backend != "sdpa":                # take a dequantised view
            k_read = kvq.dequantize_kv(k_cache, k_scale, x.dtype)
            v_read = kvq.dequantize_kv(v_cache, v_scale, x.dtype)
    else:
        k_cache = _kv_write(k_cache, k_new, write_pos, active)
        v_cache = _kv_write(v_cache, v_new, write_pos, active)
        k_read, v_read = k_cache, v_cache

    out = _decode_attend(q, k_read, v_read, mask, cfg, backend, x.dtype,
                         k_scale=k_scale if quantized else None,
                         v_scale=v_scale if quantized else None)
    from repro.quant.paths import matmul
    out = matmul(out, p["wo"])
    if quantized:
        return out, k_cache, v_cache, k_scale, v_scale
    return out, k_cache, v_cache


# --------------------------------------------------------------------------
# paged decode (slot -> block-table -> page-pool indirection)
# --------------------------------------------------------------------------

def paged_view(pool: jnp.ndarray, block_table: jnp.ndarray) -> jnp.ndarray:
    """Gather a slot-major contiguous K/V view out of a page pool.

    pool (n_pages, page_size, Hkv, hd); block_table (B, max_blocks) of
    page indices -> (B, max_blocks * page_size, Hkv, hd).  Every slot's
    view has the same (constant) virtual length, so the decode step stays
    ONE compiled program; which physical pages back it is pure data."""
    B, max_blocks = block_table.shape
    pages = jnp.take(pool, block_table, axis=0)
    return pages.reshape(B, max_blocks * pool.shape[1], *pool.shape[2:])


def attention_decode_paged(p: Params, x: jnp.ndarray, k_pool: jnp.ndarray,
                           v_pool: jnp.ndarray, block_table: jnp.ndarray,
                           pos: jnp.ndarray, mask: jnp.ndarray,
                           angles: jnp.ndarray, cfg: ArchConfig,
                           apply_rope_fn, backend: str = "sdpa",
                           active=None, k_scale_pool=None,
                           v_scale_pool=None):
    """One-token decode through a paged KV cache.

    x (B,1,D); k_pool/v_pool (n_pages, page_size, Hkv, hd);
    block_table (B, max_blocks); pos (B,) absolute per-slot positions.
    The new K/V row is scattered into the slot's current page
    (``block_table[b, pos[b] // page_size]`` at offset
    ``pos[b] % page_size``), then the slot-major view is gathered and the
    regular masked decode backend runs over it.  ``mask`` is the
    (B, max_blocks*page_size) valid-slot mask (``decode_mask(pos, ...)``).

    Lanes whose block-table row points at the reserved garbage page
    (free / mid-prefill slots) write there and read finite junk — their
    outputs are discarded by the scheduler.  ``active`` (B,) bool
    redirects inactive lanes' writes to the garbage page and freezes
    their position (horizon-K fused ticks: lanes that finish mid-horizon
    stop touching their allocated pages).  Returns
    (out, new_k_pool, new_v_pool[, new_k_scale_pool, new_v_scale_pool]).

    k_scale_pool/v_scale_pool (n_pages, page_size, Hkv) switch the pool
    to the int8-quantised layout: the new row is quantised on write
    (codes into k_pool, per-head scale into k_scale_pool, same page/off
    — the scale pools share the block table), and reads dequantise per
    route (in-register in the fused kernel; a materialised model-dtype
    view on the gather reference).

    ``backend="pallas"`` runs the fused paged kernel
    (kernels/paged_decode_attention): the gather is fused into the SDPA
    sweep and pages are read in place, so per-step KV traffic follows
    *allocated* pages instead of 3x the constant virtual view.  Every
    other backend takes the gather+SDPA reference route through the
    materialised ``paged_view``."""
    q, k_new, v_new = _project_qkv(p, x, cfg)
    q = apply_rope_fn(q, angles)
    k_new = apply_rope_fn(k_new, angles)
    page_size = k_pool.shape[1]
    page = jnp.take_along_axis(block_table, (pos // page_size)[:, None],
                               axis=1)[:, 0]
    if active is not None:
        page = jnp.where(active, page, 0)   # 0 = reserved garbage page
    off = pos % page_size
    quantized = k_scale_pool is not None
    if quantized:
        from repro.quant import kv as kvq
        k_new, ks = kvq.quantize_kv_write(k_new)
        v_new, vs = kvq.quantize_kv_write(v_new)
        k_scale_pool = k_scale_pool.at[page, off].set(ks[:, 0])
        v_scale_pool = v_scale_pool.at[page, off].set(vs[:, 0])
    k_pool = k_pool.at[page, off].set(k_new[:, 0].astype(k_pool.dtype))
    v_pool = v_pool.at[page, off].set(v_new[:, 0].astype(v_pool.dtype))
    # routing (fused in-place kernel vs gathered-view reference) lives in
    # _decode_attend; a slot's live length is pos+1 (the row just
    # written), matching decode_mask(pos, ...) exactly
    paged = (k_pool, v_pool, block_table, pos + 1)
    if quantized:
        paged = paged + (k_scale_pool, v_scale_pool)
    out = _decode_attend(q, None, None, mask, cfg, backend, x.dtype,
                         paged=paged)
    from repro.quant.paths import matmul
    out = matmul(out, p["wo"])
    if quantized:
        return out, k_pool, v_pool, k_scale_pool, v_scale_pool
    return out, k_pool, v_pool


def attention_prefill_paged(p: Params, x: jnp.ndarray, k_pool: jnp.ndarray,
                            v_pool: jnp.ndarray, slot_pages: jnp.ndarray,
                            start_pos: jnp.ndarray, angles: jnp.ndarray,
                            cfg: ArchConfig, apply_rope_fn,
                            k_scale_pool=None, v_scale_pool=None):
    """Prefill one chunk of ONE session through the paged cache.

    x (1, C, D) is the chunk's hidden states; ``slot_pages``
    (max_blocks,) is the session's block-table row; ``start_pos`` is the
    (page-aligned, traced) absolute position of chunk token 0.  The
    chunk's K/V are written into the slot's pages, then the chunk
    attends causally over the cached prefix + itself through the
    gathered view — exact math (masked positions contribute exact
    zeros), so chunked prefill is token-identical to whole-prompt
    prefill.  k_scale_pool/v_scale_pool select the int8-quantised pool
    layout: the chunk quantises per token on write and the attention
    reads a dequantised view, so quantisation commutes with chunking
    (chunked == whole-prompt stays exact).  Returns
    (out (1, C, D), new_k_pool, new_v_pool[, new scale pools])."""
    _, C, _ = x.shape
    page_size = k_pool.shape[1]
    q, k_new, v_new = _project_qkv(p, x, cfg)
    q = apply_rope_fn(q, angles)
    k_new = apply_rope_fn(k_new, angles)
    n_chunk_pages = -(-C // page_size)
    pad = n_chunk_pages * page_size - C

    def to_pages(t, dtype):   # (1, C, ...) -> (n_pages_c, page, ...)
        t = jnp.pad(t[0], ((0, pad),) + ((0, 0),) * (t.ndim - 2))
        return t.reshape((n_chunk_pages, page_size)
                         + t.shape[1:]).astype(dtype)

    first = start_pos // page_size
    idx = jax.lax.dynamic_slice_in_dim(slot_pages, first, n_chunk_pages)
    quantized = k_scale_pool is not None
    if quantized:
        from repro.quant import kv as kvq
        k_new, ks = kvq.quantize_kv_write(k_new)
        v_new, vs = kvq.quantize_kv_write(v_new)
        k_scale_pool = k_scale_pool.at[idx].set(to_pages(ks, jnp.float32))
        v_scale_pool = v_scale_pool.at[idx].set(to_pages(vs, jnp.float32))
    k_pool = k_pool.at[idx].set(to_pages(k_new, k_pool.dtype))
    v_pool = v_pool.at[idx].set(to_pages(v_new, v_pool.dtype))
    if quantized:
        from repro.quant import kv as kvq
        k_view = kvq.dequantize_kv(paged_view(k_pool, slot_pages[None, :]),
                                   paged_view(k_scale_pool,
                                              slot_pages[None, :]), x.dtype)
        v_view = kvq.dequantize_kv(paged_view(v_pool, slot_pages[None, :]),
                                   paged_view(v_scale_pool,
                                              slot_pages[None, :]), x.dtype)
    else:
        k_view = paged_view(k_pool, slot_pages[None, :])
        v_view = paged_view(v_pool, slot_pages[None, :])
    virtual = k_view.shape[1]
    qpos = start_pos + jnp.arange(C)
    mask = jnp.arange(virtual)[None, :] <= qpos[:, None]      # (C, virtual)
    scores = _gqa_scores(q, k_view.astype(q.dtype), cfg)      # (1,K,G,C,virt)
    scores = jnp.where(mask[None, None, None, :, :], scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, v_view.astype(q.dtype), cfg).astype(x.dtype)
    from repro.quant.paths import matmul
    out = matmul(out, p["wo"])
    if quantized:
        return out, k_pool, v_pool, k_scale_pool, v_scale_pool
    return out, k_pool, v_pool
