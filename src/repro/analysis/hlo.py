"""Collective-byte extraction from post-SPMD optimized HLO text.

``compiled.as_text()`` (AFTER partitioning — collectives only exist
post-SPMD) is parsed per computation.  Collectives inside while-loop
bodies appear ONCE in the text but execute trip-count times; the caller
supplies ``loop_multiplier`` (e.g. n_layers for the scan-over-layers
while) and every collective found inside a while-ish computation is
multiplied by it.  Validated against unrolled compiles in
EXPERIMENTS.md §Dry-run.

Byte cost per op uses ring-algorithm wire bytes per chip:
  all-reduce     2 (n-1)/n * size
  all-gather       (n-1)/n * result_size
  reduce-scatter   (n-1)/n * operand_size
  all-to-all       (n-1)/n * size
  collective-permute  size
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "s4": 0.5, "u4": 0.5,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_REPLICA_GROUPS_RE = re.compile(r"replica_groups=\{([^}]*)\}")
_REPLICA_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> float:
    """'bf16[8,128]' or '(bf16[8,128], f32[4])' -> total bytes."""
    total = 0.0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _REPLICA_GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _REPLICA_GROUPS_RE.search(line)
    if m and m.group(1).strip():
        first = m.group(1).split("}")[0].strip("{} ")
        if first:
            return len([t for t in first.split(",") if t.strip() != ""])
    return default


@dataclasses.dataclass
class CollectiveOp:
    kind: str
    result_bytes: float
    group_size: int
    computation: str
    multiplier: int

    @property
    def wire_bytes_per_chip(self) -> float:
        n = max(self.group_size, 2)
        f = (n - 1) / n
        if self.kind == "all-reduce":
            b = 2 * f * self.result_bytes
        elif self.kind == "all-gather":
            b = f * self.result_bytes
        elif self.kind == "reduce-scatter":
            b = f * self.result_bytes * n   # operand = result * n
        elif self.kind == "all-to-all":
            b = f * self.result_bytes
        else:  # collective-permute
            b = self.result_bytes
        return b * self.multiplier


_WHILE_RE = re.compile(
    r"while\(.*?\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)|"
    r"while\(.*?\).*?body=%?([\w.\-]+).*?condition=%?([\w.\-]+)")
_CALLS_RE = re.compile(r"(?:calls|to_apply|body|condition)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _computations(lines) -> Dict[str, List[str]]:
    """Split HLO text into named computations."""
    comps: Dict[str, List[str]] = {}
    name = "entry"
    for line in lines:
        s = line.strip()
        if not line.startswith("  ") and "{" in s and "(" in s:
            tok = s.split(" ")[0].lstrip("%").rstrip("{").strip()
            if tok == "ENTRY":
                tok = s.split(" ")[1].lstrip("%").strip()
            name = tok or "entry"
            comps[name] = []
        comps.setdefault(name, []).append(line)
    return comps


def _trip_count(cond_lines: List[str]) -> int:
    """Scan conditions compare the induction var to a constant; take the
    max integer constant found (trip count dominates the others)."""
    best = 1
    for line in cond_lines:
        for m in _CONST_RE.finditer(line):
            best = max(best, int(m.group(1)))
    return best


def parse_collectives(hlo_text: str, *, n_devices: int,
                      loop_multiplier: Optional[int] = None) -> List[CollectiveOp]:
    """Attribute each collective with the PRODUCT of trip counts of its
    enclosing while loops (scan lowers to while; trip counts are parsed
    from each loop's condition computation).  Nested loops (microbatch
    scan x layer scan) multiply.  ``loop_multiplier`` overrides the
    parsed trip count for every loop when given (legacy/testing)."""
    lines = hlo_text.splitlines()
    comps = _computations(lines)

    # per-computation: which computations it invokes, and while edges
    while_edges: Dict[str, List] = {}   # comp -> [(body, cond, trip)]
    calls_of: Dict[str, set] = {}
    for name, clines in comps.items():
        for line in clines:
            m = _WHILE_RE.search(line)
            if m:
                cond = m.group(1) or m.group(4)
                body = m.group(2) or m.group(3)
                trip = (loop_multiplier if loop_multiplier is not None
                        else _trip_count(comps.get(cond, [])))
                while_edges.setdefault(name, []).append((body, trip))
            for callee in _CALLS_RE.findall(line):
                calls_of.setdefault(name, set()).add(callee)

    # propagate multipliers from the entry computation
    entry = next((n for n in comps if "main" in n), None) or \
        next(iter(comps), "entry")
    mult: Dict[str, int] = {}

    def visit(name: str, m: int):
        if mult.get(name, 0) >= m:
            return
        mult[name] = m
        for body, trip in while_edges.get(name, []):
            visit(body, m * max(trip, 1))
        for callee in calls_of.get(name, ()):
            bodies = {b for b, _ in while_edges.get(name, [])}
            if callee not in bodies:
                visit(callee, m)

    visit(entry, 1)

    ops: List[CollectiveOp] = []
    for name, clines in comps.items():
        for line in clines:
            m = _OP_RE.search(line)
            if not m or "-done(" in line:
                continue
            shape_str, kind = m.group(1), m.group(2)
            ops.append(CollectiveOp(
                kind=kind,
                result_bytes=_shape_bytes(shape_str),
                group_size=_group_size(line, n_devices),
                computation=name,
                multiplier=mult.get(name, 1),
            ))
    return ops


def collective_summary(ops: List[CollectiveOp]) -> Dict:
    by_kind: Dict[str, Dict[str, float]] = {}
    for op in ops:
        d = by_kind.setdefault(op.kind, {"count": 0, "wire_bytes_per_chip": 0.0})
        d["count"] += op.multiplier
        d["wire_bytes_per_chip"] += op.wire_bytes_per_chip
    total = sum(d["wire_bytes_per_chip"] for d in by_kind.values())
    return {"by_kind": by_kind, "total_wire_bytes_per_chip": total}
