from repro.analysis import analytic, hlo, roofline  # noqa: F401
