"""A small statement-level CFG over one function body.

Built for the ``refcount-pairing`` rule, which must prove that every
page allocation reaches a release / park / ownership transfer on EVERY
path out of the function — including ``except`` handlers and early
returns, the exact edge the PR-9 ``TieredPageStore`` restore-failure
leak hid on.

Nodes are the function's AST statements; edges:

  * sequential statement flow, ``if``/``else`` branch + merge;
  * loops: body entry + fall-through, back-edge to the header,
    ``break``/``continue``;
  * ``try``: every statement in the try body gets an edge to every
    handler entry (an exception can fire anywhere inside), handlers
    and ``else`` merge after; ``finally`` runs on the merge path
    (approximation: the abrupt-completion re-raise path through
    ``finally`` is not modelled separately);
  * ``return``/``raise`` → the synthetic EXIT node.

This is an over-approximation in the usual ways (both branches of
every ``if`` are considered reachable, loop bodies run 0+ times) —
fine for a linter whose findings name a concrete structural path.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

EXIT = "<exit>"


class CFG:
    """successors: id(stmt) -> set of id(stmt) | EXIT."""

    def __init__(self, fn: ast.FunctionDef):
        self.fn = fn
        self.succ: Dict[object, Set[object]] = {}
        self.entry: Optional[object] = None
        self.exit_stmts: Dict[object, ast.stmt] = {}   # stmts edging to EXIT
        self.by_id: Dict[object, ast.stmt] = {}
        # (frm, to) pairs that model an exception jumping into a handler
        # — the source statement did NOT complete on these edges
        self.exc_edges: Set[Tuple[object, object]] = set()
        if fn.body:
            self.entry = id(fn.body[0])
        last = self._seq(fn.body, loop=None, handlers=())
        for node in last:
            self._edge(node, EXIT)

    # ------------------------------------------------------------ building
    def _edge(self, frm, to, exc: bool = False) -> None:
        self.succ.setdefault(frm, set()).add(to)
        if exc:
            self.exc_edges.add((frm, to))
        if to is EXIT and frm in self.by_id:
            self.exit_stmts[frm] = self.by_id[frm]

    def _seq(self, body: List[ast.stmt], loop, handlers) -> List[object]:
        """Wire ``body`` sequentially; returns the dangling nodes whose
        successor is whatever follows the sequence.  ``loop`` is
        (header_id, break_sinks) of the innermost loop; ``handlers`` the
        entry ids of enclosing except handlers (for exception edges)."""
        dangling: List[object] = []
        prev: List[object] = []
        for stmt in body:
            sid = id(stmt)
            self.by_id[sid] = stmt
            for p in prev:
                self._edge(p, sid)
            # any statement inside a try body may raise into a handler
            for h in handlers:
                self._edge(sid, h, exc=True)
            prev = self._stmt(stmt, loop, handlers)
        dangling.extend(prev)
        return dangling

    def _stmt(self, stmt: ast.stmt, loop, handlers) -> List[object]:
        sid = id(stmt)
        if isinstance(stmt, (ast.Return, ast.Raise)):
            self._edge(sid, EXIT)
            return []
        if isinstance(stmt, ast.Break):
            if loop is not None:
                loop[1].append(sid)
            return []
        if isinstance(stmt, ast.Continue):
            if loop is not None:
                self._edge(sid, loop[0])
            return []
        if isinstance(stmt, ast.If):
            out = []
            for branch in (stmt.body, stmt.orelse):
                if branch:
                    self._edge(sid, id(branch[0]))
                    out.extend(self._seq(branch, loop, handlers))
                else:
                    out.append(sid)       # no else: fall through
            return out
        if isinstance(stmt, (ast.For, ast.While, ast.AsyncFor)):
            breaks: List[object] = []
            if stmt.body:
                self._edge(sid, id(stmt.body[0]))
                for tail in self._seq(stmt.body, (sid, breaks), handlers):
                    self._edge(tail, sid)          # back edge
            out = list(breaks)
            infinite = (isinstance(stmt, ast.While)
                        and isinstance(stmt.test, ast.Constant)
                        and bool(stmt.test.value) and not stmt.orelse)
            if not infinite:
                if stmt.orelse:
                    self._edge(sid, id(stmt.orelse[0]))
                    out.extend(self._seq(stmt.orelse, loop, handlers))
                else:
                    out.append(sid)                # zero-iteration path
            return out
        if isinstance(stmt, ast.Try):
            h_entries = tuple(id(h.body[0]) for h in stmt.handlers
                              if h.body)
            out = []
            if stmt.body:
                self._edge(sid, id(stmt.body[0]))
                body_tail = self._seq(stmt.body, loop,
                                      h_entries + tuple(handlers))
            else:
                body_tail = [sid]
            for h in stmt.handlers:
                if h.body:
                    # the handler's first stmt is reachable from any
                    # try-body stmt (wired in _seq); record its own flow
                    self.by_id[id(h.body[0])] = h.body[0]
                    out.extend(self._seq(h.body, loop, handlers))
            if stmt.orelse:
                for t in body_tail:
                    self._edge(t, id(stmt.orelse[0]))
                out.extend(self._seq(stmt.orelse, loop, handlers))
            else:
                out.extend(body_tail)
            if stmt.finalbody:
                for t in out:
                    self._edge(t, id(stmt.finalbody[0]))
                out = self._seq(stmt.finalbody, loop, handlers)
            return out
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            if stmt.body:
                self._edge(sid, id(stmt.body[0]))
                return self._seq(stmt.body, loop, handlers)
            return [sid]
        return [sid]

    # ----------------------------------------------------------- traversal
    def successors(self, node) -> Set[object]:
        return self.succ.get(node, set())

    def is_exc(self, frm, to) -> bool:
        return (frm, to) in self.exc_edges

    def stmt(self, node) -> Optional[ast.stmt]:
        return self.by_id.get(node)


def statements_after(cfg: CFG, start: ast.stmt
                     ) -> List[Tuple[object, ast.stmt]]:
    """All (id, stmt) reachable from (excluding) ``start``."""
    seen: Set[object] = set()
    work = list(cfg.successors(id(start)))
    out = []
    while work:
        node = work.pop()
        if node in seen or node is EXIT:
            continue
        seen.add(node)
        st = cfg.stmt(node)
        if st is not None:
            out.append((node, st))
        work.extend(cfg.successors(node))
    return out
