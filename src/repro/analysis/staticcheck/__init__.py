"""staticcheck — AST-level invariant linter for the serving hot path.

``python -m repro.analysis.staticcheck src`` runs every rule over a
tree; see ``core`` for the engine and ``rules/`` for the invariants.
"""
from repro.analysis.staticcheck import rules  # noqa: F401  (registers rules)
from repro.analysis.staticcheck.core import (RULES, Finding,  # noqa: F401
                                             check_file, check_source,
                                             run_paths)

__all__ = ["RULES", "Finding", "check_source", "check_file", "run_paths"]
