import sys

from repro.analysis.staticcheck.cli import main

if __name__ == "__main__":
    sys.exit(main())
