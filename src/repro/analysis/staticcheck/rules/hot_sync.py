"""hot-sync: no implicit device→host syncs on the decode hot path.

The paper's central measurement is that batch-1 decode is throttled by
launch-side overhead — and the cheapest way to reintroduce it is an
accidental ``int()`` / ``np.asarray`` / ``.item()`` on a device array
inside the tick loop, which stalls the dispatch pipeline until the
device catches up.  Functions designated ``# staticcheck: hotpath``
must funnel ALL device reads through their one deliberate sync.

Mechanics: a linear walk of each hot function tracks which locals are
device-valued (assigned from ``jnp.*`` / ``jax.*`` / the compiled
program registry / known device-producing methods; re-assignment from
``np.asarray``/``np.array`` converts them to host values).  Flagged:

  * ``np.asarray(x)`` / ``np.array(x)`` / ``int(x)`` / ``float(x)`` /
    ``bool(x)`` where ``x`` mentions a device-valued local or a hot
    function parameter;
  * ``.item()`` / ``.tolist()`` / ``.block_until_ready()`` /
    ``jax.block_until_ready`` / ``jax.device_get`` anywhere in a hot
    function (these have no non-sync reading).

Blocks gated on a ``timed`` flag (``if self.timed:``) are exempt —
instrumentation is allowed to sync when the caller asked for walls.
The deliberate once-per-tick token sync carries an inline suppression
naming itself.
"""
from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis.staticcheck.core import (FileContext, Finding, dotted,
                                             names_in, register)

RULE = "hot-sync"

# callee dotted-name shapes whose results live on device
_DEVICE_PREFIXES = ("jnp.", "jax.")
_DEVICE_INFIX = ("._progs.",)
_DEVICE_TAILS = {
    "_run_step", "_sample", "sample", "decode_step", "decode_steps",
    "prefill", "prefill_chunk", "prefill_into_slot",
    "prefill_chunk_into_slot", "copy_kv_page", "_step", "_steps_fused",
    "_prefill", "save_kv_pages", "restore_kv_pages",
}
# converting calls: result is a host value (and the call is a sync when
# fed a device value)
_HOST_CONVERTERS = {"np.asarray", "np.array", "numpy.asarray",
                    "numpy.array"}
_SCALAR_SYNCS = {"int", "float", "bool"}
_ALWAYS_SYNC_CALLS = {"jax.block_until_ready", "jax.device_get"}
_ALWAYS_SYNC_METHODS = {"item", "tolist", "block_until_ready"}
_TIMED_GATES = {"timed"}


def _is_device_callee(call: ast.Call) -> bool:
    d = dotted(call.func)
    if d is None:
        return False
    if any(d.startswith(p) for p in _DEVICE_PREFIXES):
        # numpy-free namespaces only: jnp/jax produce device arrays
        return d not in _ALWAYS_SYNC_CALLS
    if any(infix in d for infix in _DEVICE_INFIX):
        return True
    return d.rsplit(".", 1)[-1] in _DEVICE_TAILS


def _timed_gated(test: ast.AST) -> bool:
    for node in ast.walk(test):
        if isinstance(node, ast.Name) and node.id in _TIMED_GATES:
            return True
        if isinstance(node, ast.Attribute) and node.attr in _TIMED_GATES:
            return True
    return False


class _HotWalker:
    def __init__(self, ctx: FileContext, fn: ast.FunctionDef):
        self.ctx = ctx
        self.fn = fn
        self.qual = ctx.qualname_of(fn)
        self.device: Set[str] = {
            a.arg for a in (fn.args.posonlyargs + fn.args.args
                            + fn.args.kwonlyargs)
            if a.arg not in ("self", "cls")}
        self.findings: List[Finding] = []

    # ------------------------------------------------------------- helpers
    def _mentions_device(self, node: ast.AST) -> bool:
        if names_in(node) & self.device:
            return True
        # a device-producing call nested right in the argument
        return any(isinstance(c, ast.Call) and _is_device_callee(c)
                   for c in ast.walk(node))

    def _flag(self, node: ast.AST, what: str) -> None:
        self.findings.append(self.ctx.finding(
            RULE, node,
            f"{what} inside hot-path function (device→host sync on the "
            f"decode tick; gate on `timed` or move off the hot path)",
            self.qual))

    def _scan_expr(self, node: ast.AST) -> None:
        """Flag sync calls anywhere inside one expression tree."""
        for call in ast.walk(node):
            if not isinstance(call, ast.Call):
                continue
            d = dotted(call.func)
            if d in _ALWAYS_SYNC_CALLS:
                self._flag(call, f"`{d}(...)`")
            elif (isinstance(call.func, ast.Attribute)
                    and call.func.attr in _ALWAYS_SYNC_METHODS):
                self._flag(call, f"`.{call.func.attr}()`")
            elif d in _HOST_CONVERTERS:
                if call.args and self._mentions_device(call.args[0]):
                    self._flag(call, f"`{d}` on a device value")
            elif d in _SCALAR_SYNCS:
                if call.args and self._mentions_device(call.args[0]):
                    self._flag(call, f"`{d}()` on a device value")

    def _assign_targets(self, stmt: ast.Assign) -> List[str]:
        names: List[str] = []
        for t in stmt.targets:
            if isinstance(t, ast.Name):
                names.append(t.id)
            elif isinstance(t, ast.Tuple):
                names.extend(e.id for e in t.elts
                             if isinstance(e, ast.Name))
        return names

    # ------------------------------------------------------------ the walk
    def walk(self) -> List[Finding]:
        self._walk_body(self.fn.body)
        return self.findings

    def _walk_body(self, body) -> None:
        for stmt in body:
            self._walk_stmt(stmt)

    def _walk_stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, ast.If):
            if _timed_gated(stmt.test):
                self._walk_body(stmt.orelse)   # gated body is exempt
                return
            self._scan_expr(stmt.test)
            self._walk_body(stmt.body)
            self._walk_body(stmt.orelse)
            return
        if isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._scan_expr(stmt.iter)
            self._walk_body(stmt.body)
            self._walk_body(stmt.orelse)
            return
        if isinstance(stmt, ast.While):
            self._scan_expr(stmt.test)
            self._walk_body(stmt.body)
            self._walk_body(stmt.orelse)
            return
        if isinstance(stmt, ast.Try):
            self._walk_body(stmt.body)
            for h in stmt.handlers:
                self._walk_body(h.body)
            self._walk_body(stmt.orelse)
            self._walk_body(stmt.finalbody)
            return
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._scan_expr(item.context_expr)
            self._walk_body(stmt.body)
            return
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return          # nested defs are designated separately
        # flat statement: scan for syncs, then update device tracking
        self._scan_expr(stmt)
        if isinstance(stmt, ast.Assign):
            targets = self._assign_targets(stmt)
            value = stmt.value
            makes_device = (
                (isinstance(value, ast.Call) and _is_device_callee(value))
                or (not isinstance(value, ast.Call)
                    and self._mentions_device(value)))
            if isinstance(value, ast.Call) and \
                    dotted(value.func) in _HOST_CONVERTERS:
                makes_device = False    # explicit device→host conversion
            for name in targets:
                (self.device.add if makes_device
                 else self.device.discard)(name)


@register(RULE, "hot-path functions sync the device once, deliberately")
def check(ctx: FileContext) -> List[Finding]:
    findings: List[Finding] = []
    for fn in ctx.functions():
        if ctx.directives.is_hotpath_def(fn.lineno):
            findings.extend(_HotWalker(ctx, fn).walk())
    return findings
