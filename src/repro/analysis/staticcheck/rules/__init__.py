"""Rule modules register themselves on import (core.RULES)."""
from repro.analysis.staticcheck.rules import (donation, hot_sync,  # noqa: F401
                                              prng, recompile, refcount)

__all__ = ["hot_sync", "recompile", "donation", "prng", "refcount"]
