"""prng-discipline: every key value feeds exactly one consumer.

JAX PRNG keys are values, not stateful generators: passing the same
key to two sampling sites yields *correlated* (often identical)
streams — in serving terms, every lane of a horizon scan sampling the
same token.  The invariant: between any two consuming uses of a key
there must be a ``split`` / ``fold_in`` deriving a fresh key.

The pass walks each function linearly (loop bodies twice, to surface
loop-carried reuse where the key is consumed but never re-derived),
tracking key-typed values by textual id:

  * producers: ``jax.random.PRNGKey`` / ``*.random.split`` /
    ``*.random.fold_in`` assignments (split results are key *arrays*;
    their ``ks[i]`` subscripts are tracked individually);
  * derivation (``split(key)`` / ``fold_in(key, x)``) does not count
    as consumption; any other call taking the key does;
  * re-assignment of the name bumps its generation, resetting the
    consumed state.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.staticcheck.core import (FileContext, Finding, dotted,
                                             register)

RULE = "prng-discipline"

_PRODUCER_TAILS = {"PRNGKey"}
_DERIVE_TAILS = {"split", "fold_in"}          # require a random. prefix


def _callee_tail(call: ast.Call) -> Optional[str]:
    d = dotted(call.func)
    return d.rsplit(".", 1)[-1] if d else None


def _is_key_producer(call: ast.Call) -> bool:
    d = dotted(call.func)
    if d is None:
        return False
    tail = d.rsplit(".", 1)[-1]
    if tail in _PRODUCER_TAILS:
        return True
    # split/fold_in are producers too, but only under a random module
    # (str.split would otherwise mint keys out of thin air)
    return tail in _DERIVE_TAILS and "random." in d


def _is_derive(call: ast.Call) -> bool:
    d = dotted(call.func)
    return (d is not None and d.rsplit(".", 1)[-1] in _DERIVE_TAILS
            and "random." in d)


def _terminates(body: List[ast.stmt]) -> bool:
    """Does the branch end in a statement that leaves the if entirely?"""
    return bool(body) and isinstance(
        body[-1], (ast.Return, ast.Raise, ast.Continue, ast.Break))


class _KeyTracker:
    def __init__(self, ctx: FileContext, fn: ast.FunctionDef):
        self.ctx = ctx
        self.qual = ctx.qualname_of(fn)
        self.fn = fn
        self.gen: Dict[str, int] = {}              # key text -> generation
        self.consumed: Dict[Tuple[str, int], int] = {}  # -> first line
        self.findings: List[Finding] = []
        self.reported: Set[Tuple[int, str]] = set()
        # parameters named like keys are key-typed on entry
        for a in fn.args.posonlyargs + fn.args.args + fn.args.kwonlyargs:
            if "key" in a.arg.lower():
                self.gen[a.arg] = 0

    # ----------------------------------------------------------- plumbing
    def _key_texts_in(self, node: ast.AST) -> List[str]:
        """Tracked key texts read inside ``node`` (name, attr or
        subscript form — whichever granularity is tracked).  Subtrees
        under a derive call are excluded: ``f(fold_in(key, i))``
        consumes the derived key, not ``key``."""
        out: List[str] = []

        def visit(n: ast.AST) -> None:
            if isinstance(n, ast.Call):
                # nested calls consume their own args on their own turn
                # in the outer walk (and derive calls never consume)
                return
            if isinstance(n, ast.Subscript):
                base = dotted(n.value)
                if base is not None and base in self.gen:
                    if isinstance(n.slice, ast.Constant):
                        # element of a split result: track per index,
                        # inheriting the array's generation
                        text = f"{base}[{n.slice.value!r}]"
                        self.gen.setdefault(text, self.gen[base])
                        out.append(text)
                    # dynamic index (ks[i] in a loop): each iteration is
                    # a distinct element — nothing trackable, stay quiet
                    return
            text = self._text(n)
            if text is not None and text in self.gen:
                out.append(text)
                return        # ks[0] consumes the element, not `ks` too
            for child in ast.iter_child_nodes(n):
                visit(child)

        visit(node)
        return out

    def _text(self, node: ast.AST) -> Optional[str]:
        d = dotted(node)
        if d is not None:
            return d
        if isinstance(node, ast.Subscript):
            base = dotted(node.value)
            if base is not None and isinstance(node.slice, ast.Constant):
                return f"{base}[{node.slice.value!r}]"
        return None

    def _bump(self, text: str) -> None:
        self.gen[text] = self.gen.get(text, -1) + 1
        # re-splitting an array invalidates its tracked elements
        for elt in [k for k in self.gen if k.startswith(f"{text}[")]:
            del self.gen[elt]

    def _consume(self, text: str, line: int) -> None:
        state = (text, self.gen[text])
        first = self.consumed.get(state)
        if first is None:
            self.consumed[state] = line
            return
        # a second consumption — including the same site on the second
        # loop pass (loop-carried reuse of an un-rederived key)
        mark = (line, text)
        if mark in self.reported:
            return
        self.reported.add(mark)
        self.findings.append(Finding(
            RULE, self.ctx.path, line, 0,
            f"PRNG key `{text}` consumed again without an interposing "
            f"split/fold_in (first consumed at line {first}) — both "
            f"sites draw the same stream", self.qual))

    # ----------------------------------------------------------- the walk
    def walk(self) -> List[Finding]:
        self._body(self.fn.body)
        return self.findings

    def _body(self, body) -> None:
        for stmt in body:
            self._stmt(stmt)

    def _stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return
        if isinstance(stmt, ast.If):
            self._expr(stmt.test)
            # branches are mutually exclusive: each starts from the
            # pre-if consumption state; afterwards both contribute
            # (a key consumed in either arm is spent for code below)
            before = dict(self.consumed)
            before_gen = dict(self.gen)
            self._body(stmt.body)
            after_body = self.consumed
            self.consumed = dict(before)
            self.gen = before_gen
            self._body(stmt.orelse)
            # a branch ending in return/raise never reaches the code
            # below the if — its consumptions stay local to it
            if _terminates(stmt.orelse):
                self.consumed = dict(before)
            if not _terminates(stmt.body):
                for state, line in after_body.items():
                    self.consumed.setdefault(state, line)
            return
        if isinstance(stmt, (ast.For, ast.AsyncFor, ast.While)):
            if isinstance(stmt, ast.While):
                self._expr(stmt.test)
            else:
                self._expr(stmt.iter)
            # two passes expose loop-carried reuse of an un-rederived key
            self._body(stmt.body)
            self._body(stmt.body)
            self._body(stmt.orelse)
            return
        if isinstance(stmt, ast.Try):
            self._body(stmt.body)
            for h in stmt.handlers:
                self._body(h.body)
            self._body(stmt.orelse)
            self._body(stmt.finalbody)
            return
        if isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                self._expr(item.context_expr)
            self._body(stmt.body)
            return
        if isinstance(stmt, ast.Assign):
            self._expr(stmt.value)
            is_key = isinstance(stmt.value, ast.Call) and \
                _is_key_producer(stmt.value)
            for t in stmt.targets:
                for tgt in (t.elts if isinstance(t, ast.Tuple) else [t]):
                    text = self._text(tgt)
                    if text is None:
                        continue
                    if is_key:
                        self._bump(text)
                    elif text in self.gen:
                        self._bump(text)     # overwritten by a non-key
            return
        if isinstance(stmt, ast.AugAssign):
            self._expr(stmt.value)
            return
        if isinstance(stmt, ast.Return) and stmt.value is not None:
            self._expr(stmt.value, returning=True)
            return
        for node in ast.iter_child_nodes(stmt):
            if isinstance(node, ast.expr):
                self._expr(node)

    def _expr(self, node: ast.AST, returning: bool = False) -> None:
        """Register consumptions for every call inside ``node``."""
        for call in ast.walk(node):
            if not isinstance(call, ast.Call):
                continue
            if _is_derive(call):
                continue                     # derivation, not consumption
            args = list(call.args) + [kw.value for kw in call.keywords]
            for a in args:
                for text in self._key_texts_in(a):
                    self._consume(text, call.lineno)


@register(RULE, "a PRNG key is consumed once between derivations")
def check(ctx: FileContext) -> List[Finding]:
    findings: List[Finding] = []
    for fn in ctx.functions():
        findings.extend(_KeyTracker(ctx, fn).walk())
    return findings
