"""refcount-pairing: every page alloc/retain reaches a release on
every path out of the function.

The page pool is the serving stack's load-bearing ledger: a page whose
refcount never comes back down is capacity lost until process restart.
PR 9's `TieredPageStore` restore-failure bug had exactly this shape —
pages allocated for a restore, then a `TierCopyError` handler returned
without releasing them.  The allocator soak only catches that *after*
a chaos run; this rule catches it in the diff.

Per function, every *open* event —

  * ``v = <...>.alloc(...)`` / ``.alloc_free(...)`` /
    ``._alloc_or_preempt(...)`` (names configurable below), and
  * ``<store>.retain(x)`` calls —

starts a breadth-first walk of the statement-level CFG (`cfgutil`,
with exception edges into handlers).  A path *closes* when the pages

  * are passed to a ``release`` / ``park`` call,
  * are appended/extended into a container,
  * are stored into an attribute / subscript / other name (ownership
    transfer: ``sess.pages = got``, ``self._holds.append(got)``),
  * are returned, or
  * the variable is rebound.

``if v is None: ...`` / ``if not v:`` / ``if v:`` guards are branch-
sensitive: only the non-None arm stays open (a failed alloc holds no
pages).  Reaching EXIT while still open is a finding, reported at the
open site and naming the leaking exit statement.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set, Tuple

from repro.analysis.staticcheck.cfgutil import CFG, EXIT
from repro.analysis.staticcheck.core import (FileContext, Finding, dotted,
                                             register)

RULE = "refcount-pairing"

ALLOC_TAILS = {"alloc", "alloc_free", "_alloc_or_preempt", "alloc_pages"}
RETAIN_TAILS = {"retain"}
CLOSE_TAILS = {"release", "park", "release_pages", "free", "drop"}
APPEND_TAILS = {"append", "extend", "add", "appendleft", "insert", "push"}


def _callee_tail(call: ast.Call) -> Optional[str]:
    d = dotted(call.func)
    return d.rsplit(".", 1)[-1] if d else None


def _base_name(node: ast.AST) -> Optional[str]:
    """The Name a simple expr hangs off (``got``, ``got[0]``…)."""
    while isinstance(node, (ast.Subscript, ast.Starred)):
        node = node.value
    if isinstance(node, ast.Name):
        return node.id
    return None


def _mentions(node: ast.AST, var: str) -> bool:
    return any(isinstance(n, ast.Name) and n.id == var
               for n in ast.walk(node))


def _guard_polarity(test: ast.AST, var: str) -> Optional[bool]:
    """True → truthy branch holds pages; None → not a guard on var."""
    if isinstance(test, ast.Compare) and len(test.ops) == 1 and \
            isinstance(test.comparators[0], ast.Constant) and \
            test.comparators[0].value is None and \
            isinstance(test.left, ast.Name) and test.left.id == var:
        if isinstance(test.ops[0], ast.Is):
            return False             # `v is None`: truthy arm is empty
        if isinstance(test.ops[0], ast.IsNot):
            return True
    if isinstance(test, ast.Name) and test.id == var:
        return True                  # `if v:`
    if isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not) \
            and isinstance(test.operand, ast.Name) \
            and test.operand.id == var:
        return False                 # `if not v:`
    return None


def _header(stmt: ast.stmt) -> ast.AST:
    """CFG nodes for compound statements are just their headers (the
    bodies are separate nodes) — don't scan into bodies here."""
    if isinstance(stmt, (ast.For, ast.AsyncFor)):
        return stmt.iter
    if isinstance(stmt, (ast.While, ast.If)):
        return stmt.test
    if isinstance(stmt, (ast.With, ast.AsyncWith)):
        return ast.Tuple(elts=[i.context_expr for i in stmt.items],
                         ctx=ast.Load())
    if isinstance(stmt, ast.Try):
        return ast.Tuple(elts=[], ctx=ast.Load())
    return stmt


def _closes(stmt: ast.stmt, var: str) -> bool:
    """Does executing ``stmt`` (its header, for compounds) settle
    ownership of ``var``?"""
    stmt = _header(stmt)
    # passed to release/park/…, or appended into a container
    for call in ast.walk(stmt):
        if not isinstance(call, ast.Call):
            continue
        tail = _callee_tail(call)
        args = list(call.args) + [kw.value for kw in call.keywords]
        if tail in CLOSE_TAILS | APPEND_TAILS and any(
                _mentions(a, var) for a in args):
            return True
    if isinstance(stmt, ast.Assign):
        if _mentions(stmt.value, var):
            # stored somewhere: attr/subscript = transfer; fresh name =
            # alias that now carries ownership (tracked no further)
            return True
        # rebinding the variable itself abandons the old value — treat
        # as settled to keep the rule structural, not alias-chasing
        if any(_mentions(t, var) for t in stmt.targets):
            return True
    if isinstance(stmt, ast.AugAssign) and _mentions(stmt.target, var):
        return True
    if isinstance(stmt, ast.Return) and stmt.value is not None and \
            _mentions(stmt.value, var):
        return True                  # escapes to the caller
    if isinstance(stmt, ast.Raise) and stmt.exc is not None and \
            _mentions(stmt.exc, var):
        return True
    return False


def _open_events(fn: ast.FunctionDef, cfg: CFG
                 ) -> List[Tuple[ast.stmt, str, str]]:
    """(statement, var, kind) for each alloc/retain in the CFG."""
    events = []
    for sid, stmt in cfg.by_id.items():
        if isinstance(stmt, ast.Assign) and isinstance(
                stmt.value, ast.Call):
            tail = _callee_tail(stmt.value)
            if tail in ALLOC_TAILS and len(stmt.targets) == 1 and \
                    isinstance(stmt.targets[0], ast.Name):
                events.append((stmt, stmt.targets[0].id, tail))
        for call in ast.walk(stmt):
            if isinstance(call, ast.Call) and \
                    _callee_tail(call) in RETAIN_TAILS and call.args:
                var = _base_name(call.args[0])
                # a retain on a *tracked variable* opens an obligation
                # only when the stmt is the bare retain call (not part
                # of a larger ownership-transferring statement)
                if var is not None and isinstance(stmt, ast.Expr) and \
                        stmt.value is call:
                    events.append((stmt, var, "retain"))
    return events


def _walk_open(ctx: FileContext, cfg: CFG, open_stmt: ast.stmt, var: str,
               kind: str, qual: str) -> Optional[Finding]:
    """BFS from the open event; a finding if any path reaches EXIT with
    the obligation still open."""
    seen: Set[object] = set()
    work: List[object] = list(cfg.successors(id(open_stmt)))
    while work:
        node = work.pop()
        if node in seen:
            continue
        seen.add(node)
        if node is EXIT:
            # fell off the function end while open
            return ctx.finding(
                RULE, open_stmt,
                f"pages from `{var} = …{kind}(…)` may leave the function "
                f"without release/park/ownership transfer (falls off the "
                f"end while held)", qual)
        stmt = cfg.stmt(node)
        if stmt is None:
            continue
        if stmt is open_stmt:
            continue                 # loop back to a re-open: fresh event
        if isinstance(stmt, ast.If):
            pol = _guard_polarity(stmt.test, var)
            if pol is not None:
                body_entry = id(stmt.body[0]) if stmt.body else None
                for succ in cfg.successors(node):
                    is_body = succ == body_entry
                    # only the pages-holding arm stays open
                    if (is_body and pol) or (not is_body and not pol):
                        work.append(succ)
                continue
        if _closes(stmt, var):
            # the close only covers paths where the statement COMPLETES;
            # an exception edge out of it (into a handler) fires before
            # the close takes effect, so the obligation stays open there
            # — this is exactly how the PR-9 restore leak hid
            for succ in cfg.successors(node):
                if cfg.is_exc(node, succ):
                    work.append(succ)
            continue
        for succ in cfg.successors(node):
            if succ is EXIT and isinstance(stmt, (ast.Return, ast.Raise)):
                exit_kind = ("return" if isinstance(stmt, ast.Return)
                             else "raise")
                return ctx.finding(
                    RULE, open_stmt,
                    f"pages from `{var}` ({kind} at line "
                    f"{open_stmt.lineno}) leak on the {exit_kind} at "
                    f"line {stmt.lineno} — no release/park/ownership "
                    f"transfer on that path", qual)
            work.append(succ)
    return None


@register(RULE, "every alloc/retain is paired with release/park or an "
                "ownership transfer on all exit paths")
def check(ctx: FileContext) -> List[Finding]:
    findings: List[Finding] = []
    for fn in ctx.functions():
        if fn.name in ALLOC_TAILS | RETAIN_TAILS | CLOSE_TAILS:
            # delegation wrappers (PageStore.retain → allocator.retain)
            # forward the pairing obligation to their caller
            continue
        src_has = any(isinstance(n, ast.Call) and
                      _callee_tail(n) in (ALLOC_TAILS | RETAIN_TAILS)
                      for n in ast.walk(fn))
        if not src_has:
            continue
        cfg = CFG(fn)
        for open_stmt, var, kind in _open_events(fn, cfg):
            f = _walk_open(ctx, cfg, open_stmt, var, kind,
                           ctx.qualname_of(fn))
            if f is not None:
                findings.append(f)
    return findings
