"""recompile-hazard: jit wrappers built once, traced once per shape.

Launch overhead is the paper's bottleneck term; a silent retrace
multiplies it by compile time.  Two shapes reintroduce it:

  * a ``jax.jit`` wrapper constructed inside a loop / comprehension /
    immediately-invoked expression — every construction starts a fresh
    trace cache, so nothing is ever reused;
  * a jitted callable fed Python scalar or tuple literals in positions
    not declared ``static_argnums`` / ``static_argnames`` — weak-typed
    scalars hash into the trace key, so every distinct value (or an
    int where a float was traced) compiles a new program.

Only bindings whose static declarations are visible in the same file
are checked for the literal-argument hazard; calls through opaque
registries are the jit-cache-size guard's job at runtime.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Tuple

from repro.analysis.staticcheck.core import (FileContext, Finding, dotted,
                                             register)

RULE = "recompile-hazard"

_LOOPY = (ast.For, ast.AsyncFor, ast.While, ast.ListComp, ast.SetComp,
          ast.DictComp, ast.GeneratorExp)


def _is_jit_call(node: ast.Call) -> bool:
    d = dotted(node.func)
    if d in ("jax.jit", "jit"):
        return True
    # functools.partial(jax.jit, ...) used as a deferred wrapper factory
    if d in ("functools.partial", "partial") and node.args:
        return dotted(node.args[0]) in ("jax.jit", "jit")
    return False


def _literal_static(node: ast.AST) -> bool:
    """A tuple of int literals, as in ``static_argnums=(0, 2)``."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return True
    return (isinstance(node, (ast.Tuple, ast.List))
            and all(isinstance(e, ast.Constant) for e in node.elts))


class _JitBinding:
    """One ``name = jax.jit(fn, ...)`` whose static decls we can read."""

    def __init__(self, call: ast.Call):
        self.argnums: Optional[Tuple[int, ...]] = ()
        self.argnames_declared = False
        self.argnames: Tuple[str, ...] = ()
        self.resolvable = True
        for kw in call.keywords:
            if kw.arg == "static_argnums":
                if _literal_static(kw.value):
                    if isinstance(kw.value, ast.Constant):
                        self.argnums = (kw.value.value,)
                    else:
                        self.argnums = tuple(e.value for e in kw.value.elts)
                else:
                    self.resolvable = False
            elif kw.arg == "static_argnames":
                self.argnames_declared = True
                if isinstance(kw.value, (ast.Tuple, ast.List)) and all(
                        isinstance(e, ast.Constant) for e in kw.value.elts):
                    self.argnames = tuple(e.value for e in kw.value.elts)
                else:
                    self.resolvable = False


def _scalar_literal(node: ast.AST) -> Optional[str]:
    """Describe a retrace-prone literal argument, else None."""
    if isinstance(node, ast.Constant) and isinstance(
            node.value, (int, float, bool)) and node.value is not None:
        return f"scalar literal {node.value!r}"
    if isinstance(node, ast.Tuple) and node.elts and all(
            isinstance(e, ast.Constant) for e in node.elts):
        return "tuple literal"
    if isinstance(node, ast.UnaryOp) and isinstance(
            node.operand, ast.Constant):
        return "scalar literal"
    return None


@register(RULE, "jit wrappers are built once and literals are static")
def check(ctx: FileContext) -> List[Finding]:
    findings: List[Finding] = []
    parents: Dict[int, ast.AST] = {}
    for node in ast.walk(ctx.tree):
        for child in ast.iter_child_nodes(node):
            parents[id(child)] = node

    def enclosing_context(node: ast.AST) -> str:
        cur = parents.get(id(node))
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
                return ctx.qualname_of(cur)
            cur = parents.get(id(cur))
        return "<module>"

    bindings: Dict[str, _JitBinding] = {}

    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call) or not _is_jit_call(node):
            continue
        qual = enclosing_context(node)

        # (a) wrapper constructed inside a loop or comprehension
        cur = parents.get(id(node))
        while cur is not None and not isinstance(
                cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Module)):
            if isinstance(cur, _LOOPY):
                findings.append(ctx.finding(
                    RULE, node,
                    "jax.jit wrapper constructed inside a loop/"
                    "comprehension — a fresh trace cache every iteration "
                    "(hoist the wrapper out; trace caches only pay off "
                    "when reused)", qual))
                break
            cur = parents.get(id(cur))

        # (b) immediately-invoked: jax.jit(f)(x) — rebuilt per call
        parent = parents.get(id(node))
        if isinstance(parent, ast.Call) and parent.func is node:
            findings.append(ctx.finding(
                RULE, node,
                "jax.jit(...) immediately invoked — the wrapper and its "
                "compile cache are rebuilt on every call (bind it once)",
                qual))

        # record same-file bindings for the literal-argument pass
        assign = parents.get(id(node))
        if isinstance(assign, ast.Assign) and len(assign.targets) == 1:
            tgt = assign.targets[0]
            key = None
            if isinstance(tgt, ast.Name):
                key = tgt.id
            elif isinstance(tgt, ast.Attribute) and isinstance(
                    tgt.value, ast.Name) and tgt.value.id == "self":
                key = f"self.{tgt.attr}"
            if key is not None and dotted(node.func) in ("jax.jit", "jit"):
                bindings[key] = _JitBinding(node)

    # (c) literal scalars/tuples at call sites of known bindings
    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        d = dotted(node.func)
        binding = bindings.get(d) if d else None
        if binding is None or not binding.resolvable:
            continue
        qual = enclosing_context(node)
        for i, arg in enumerate(node.args):
            desc = _scalar_literal(arg)
            if desc is None or i in (binding.argnums or ()):
                continue
            if binding.argnames_declared:
                # positions may be covered by names we can't map; only
                # flag when no static machinery exists at all
                continue
            findings.append(ctx.finding(
                RULE, arg,
                f"{desc} at position {i} of jitted `{d}` is not declared "
                f"static — each distinct value (or weak-type flip) "
                f"retraces the program", qual))
        for kw in node.keywords:
            if kw.arg is None:
                continue
            desc = _scalar_literal(kw.value)
            if desc is None:
                continue
            if binding.argnames_declared and kw.arg not in binding.argnames:
                findings.append(ctx.finding(
                    RULE, kw.value,
                    f"{desc} for keyword `{kw.arg}` of jitted `{d}` is "
                    f"not in static_argnames — each distinct value "
                    f"retraces the program", qual))
    return findings
