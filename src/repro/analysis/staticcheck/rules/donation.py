"""donation-safety: donated buffers are dead after the call.

``donate_argnums`` is how the serving stack keeps KV memory flat: the
caller's page pool / cache buffer is surrendered to the compiled
program and its storage reused for the output.  Reading a donated
argument *after* the call touches a deleted buffer —
``RuntimeError: invalid buffer`` at best, silent garbage under some
backends' async dispatch at worst.

Two sources of donation knowledge:

  * same-file bindings ``X = jax.jit(fn, donate_argnums=(...))`` with a
    literal tuple;
  * ``KNOWN_DONATING`` — the donation map of the compiled-program
    registry (`repro.serving.programs.SchedulerPrograms`), keyed by
    dotted-callee suffix, so scheduler call sites are checked across
    module boundaries.

For each donating call we walk the CFG forward: a path that *reads*
the donated expression before any statement rebinds it is a finding.
The call's own assignment targets count as rebinds (the canonical
``cache = step(params, cache, ...)`` shape is safe, including around
loop back-edges).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.staticcheck.cfgutil import CFG, EXIT
from repro.analysis.staticcheck.core import (FileContext, Finding, dotted,
                                             register)

RULE = "donation-safety"

# dotted-callee suffix -> donated positional indices; mirrors
# serving/programs.py's donate_argnums declarations (and the tiered
# store's restore movers).  Keys starting with "." match by suffix.
KNOWN_DONATING: Dict[str, Tuple[int, ...]] = {
    "._progs.prefill_chunk": (2,),
    "._progs.copy_page": (0,),
    "._progs.restore_pages": (0,),
    "._progs.prefill_slot": (2,),
    "._progs.step": (1,),
    "._progs.steps": (1,),
    ".restore_kv_pages": (0,),
}


def _match_known(d: str) -> Optional[Tuple[int, ...]]:
    for key, pos in KNOWN_DONATING.items():
        if key.startswith(".") and d.endswith(key):
            return pos
        if d == key:
            return pos
    return None


def _literal_argnums(call: ast.Call) -> Optional[Tuple[int, ...]]:
    for kw in call.keywords:
        if kw.arg != "donate_argnums":
            continue
        if isinstance(kw.value, ast.Constant) and isinstance(
                kw.value.value, int):
            return (kw.value.value,)
        if isinstance(kw.value, (ast.Tuple, ast.List)) and all(
                isinstance(e, ast.Constant) for e in kw.value.elts):
            return tuple(e.value for e in kw.value.elts)
        return None        # dynamic (e.g. a variable) — unresolvable
    return ()


def _expr_text(node: ast.AST) -> Optional[str]:
    """Stable text for simple donated exprs (names / dotted attrs)."""
    return dotted(node)


def _header(stmt: ast.stmt) -> ast.AST:
    """CFG nodes for compound statements represent only their header —
    bodies are separate nodes — so read/store checks must not walk into
    them (the donating call itself usually lives there)."""
    if isinstance(stmt, (ast.For, ast.AsyncFor)):
        return stmt.iter
    if isinstance(stmt, (ast.While, ast.If)):
        return stmt.test
    if isinstance(stmt, (ast.With, ast.AsyncWith)):
        return ast.Tuple(elts=[i.context_expr for i in stmt.items],
                         ctx=ast.Load())
    if isinstance(stmt, ast.Try):
        return ast.Tuple(elts=[], ctx=ast.Load())
    return stmt


def _stores(stmt: ast.stmt) -> Set[str]:
    out: Set[str] = set()
    targets: List[ast.AST] = []
    if isinstance(stmt, ast.Assign):
        targets = list(stmt.targets)
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        targets = [stmt.target]
    elif isinstance(stmt, (ast.For, ast.AsyncFor)):
        targets = [stmt.target]
    for t in targets:
        for node in ast.walk(t):
            d = dotted(node)
            if d:
                out.add(d)
    return out


def _reads(stmt: ast.stmt, text: str) -> bool:
    """Does ``stmt``'s header read ``text`` (outside its own store
    targets)?"""
    skip: Set[int] = set()
    if isinstance(stmt, ast.Assign):
        for t in stmt.targets:
            skip.update(id(n) for n in ast.walk(t))
    elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
        skip.update(id(n) for n in ast.walk(stmt.target))
    for node in ast.walk(_header(stmt)):
        if id(node) in skip:
            continue
        if dotted(node) == text and isinstance(
                node, (ast.Name, ast.Attribute)):
            return True
    return False


def _check_call(ctx: FileContext, fn: ast.FunctionDef, cfg: CFG,
                call_stmt: ast.stmt, call: ast.Call,
                donated: Tuple[int, ...], qual: str) -> List[Finding]:
    findings: List[Finding] = []
    for pos in donated:
        if pos >= len(call.args):
            continue
        text = _expr_text(call.args[pos])
        if text is None or text in ("self",):
            continue
        # the donating statement's own targets rebinding the expr makes
        # the canonical `cache = step(..., cache, ...)` safe: every
        # later read sees the freshly returned buffer
        own_store = text in _stores(call_stmt)
        if own_store:
            continue
        seen: Set[object] = set()
        work = list(cfg.successors(id(call_stmt)))
        while work:
            node = work.pop()
            if node in seen:
                continue
            seen.add(node)
            if node is EXIT:
                continue
            stmt = cfg.stmt(node)
            if stmt is None:
                continue
            if stmt is call_stmt:
                # back around the loop: safe iff the call rebinds it
                if own_store:
                    continue
                findings.append(ctx.finding(
                    RULE, call.args[pos],
                    f"`{text}` is donated (arg {pos}) and re-passed on "
                    f"the next loop iteration without being rebound — "
                    f"the second call reads a deleted buffer", qual))
                break
            if _reads(stmt, text):
                findings.append(ctx.finding(
                    RULE, call.args[pos],
                    f"`{text}` is donated to the callee (arg {pos}) but "
                    f"read again at line {stmt.lineno} — donated buffers "
                    f"are deleted by the call (rebind the result or drop "
                    f"the read)", qual))
                break
            if text in _stores(stmt):
                continue           # rebound: this path is safe
            work.extend(cfg.successors(node))
    return findings


@register(RULE, "arguments listed in donate_argnums are not read after "
                "the jitted call")
def check(ctx: FileContext) -> List[Finding]:
    findings: List[Finding] = []

    # same-file literal bindings: name/self-attr -> donated positions
    local: Dict[str, Tuple[int, ...]] = {}
    for node in ast.walk(ctx.tree):
        if isinstance(node, ast.Assign) and len(node.targets) == 1 and \
                isinstance(node.value, ast.Call) and \
                dotted(node.value.func) in ("jax.jit", "jit"):
            nums = _literal_argnums(node.value)
            if not nums:
                continue
            tgt = node.targets[0]
            if isinstance(tgt, ast.Name):
                local[tgt.id] = nums
            elif isinstance(tgt, ast.Attribute) and isinstance(
                    tgt.value, ast.Name) and tgt.value.id == "self":
                local[f"self.{tgt.attr}"] = nums

    for fn in ctx.functions():
        cfg = None
        qual = ctx.qualname_of(fn)
        for stmt in ast.walk(fn):
            if not isinstance(stmt, ast.stmt):
                continue
            # anchor each donating call at the statement whose HEADER
            # holds it (compound bodies are their own CFG nodes)
            for call in ast.walk(_header(stmt)):
                if not isinstance(call, ast.Call):
                    continue
                d = dotted(call.func)
                if d is None:
                    continue
                donated = local.get(d)
                if donated is None:
                    donated = _match_known(d)
                if not donated:
                    continue
                if cfg is None:
                    cfg = CFG(fn)
                if id(stmt) not in cfg.by_id:
                    continue       # e.g. inside a nested def
                findings.extend(_check_call(
                    ctx, fn, cfg, stmt, call, donated, qual))
        del cfg
    return findings
