"""staticcheck CLI.

Exit codes: 0 clean; 1 findings (new findings, unused suppressions, or
baseline entries missing a justification); 2 usage error.

``--write-baseline`` grandfathers the current findings: each entry
needs a hand-written ``justification`` string (the write keeps any
already present); the run fails until every entry has one, so a
baseline is never a silent rug.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.analysis.staticcheck import rules  # noqa: F401  (registers)
from repro.analysis.staticcheck.core import (RULES, apply_baseline,
                                             load_baseline, run_paths,
                                             write_baseline)

DEFAULT_BASELINE = "staticcheck-baseline.json"


def _list_rules() -> str:
    width = max(len(r) for r in RULES)
    return "\n".join(f"{name:<{width}}  {rule.invariant}"
                     for name, rule in sorted(RULES.items()))


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis.staticcheck",
        description="AST-level invariant linter for the serving hot path")
    parser.add_argument("paths", nargs="*",
                        help="files or directories to scan")
    parser.add_argument("--select", action="append", default=None,
                        metavar="RULE",
                        help="run only this rule (repeatable)")
    parser.add_argument("--baseline", default=DEFAULT_BASELINE,
                        help="baseline JSON of grandfathered findings "
                             f"(default: {DEFAULT_BASELINE}; missing file "
                             "= empty)")
    parser.add_argument("--write-baseline", action="store_true",
                        help="rewrite the baseline from current findings "
                             "(keeps existing justifications)")
    parser.add_argument("--json", dest="json_out", metavar="PATH",
                        help="also write the full report as JSON "
                             "('-' for stdout)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule registry and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        print(_list_rules())
        return 0
    if not args.paths:
        parser.print_usage(sys.stderr)
        print("staticcheck: error: no paths given", file=sys.stderr)
        return 2
    unknown = [r for r in (args.select or [])
               if r not in RULES]
    if unknown:
        print(f"staticcheck: error: unknown rule(s): "
              f"{', '.join(unknown)} (see --list-rules)", file=sys.stderr)
        return 2

    findings, n_files = run_paths(args.paths, args.select)
    baseline = load_baseline(args.baseline)

    if args.write_baseline:
        empty = write_baseline(args.baseline, findings, baseline)
        print(f"staticcheck: wrote {len(findings)} entr"
              f"{'y' if len(findings) == 1 else 'ies'} to "
              f"{args.baseline}")
        if empty:
            print(f"staticcheck: {empty} entr"
                  f"{'y needs' if empty == 1 else 'ies need'} a "
                  f"justification before the baseline is valid",
                  file=sys.stderr)
            return 1
        return 0

    new, grandfathered, stale, unjustified = apply_baseline(
        findings, baseline)

    if args.json_out:
        report = {
            "files_scanned": n_files,
            "rules": sorted(RULES),
            "new": [f.to_json() for f in new],
            "baselined": [f.to_json() for f in grandfathered],
            "stale_baseline_entries": stale,
            "unjustified_baseline_entries": unjustified,
        }
        blob = json.dumps(report, indent=2) + "\n"
        if args.json_out == "-":
            sys.stdout.write(blob)
        else:
            with open(args.json_out, "w", encoding="utf-8") as fh:
                fh.write(blob)

    for f in new:
        print(f.render())
    for e in unjustified:
        print(f"{e['path']}: baseline: entry {e['fingerprint']} "
              f"({e['rule']}) has no justification — write one or fix "
              f"the finding")
    for e in stale:
        print(f"staticcheck: note: stale baseline entry "
              f"{e['fingerprint']} ({e['rule']} in {e['path']}) no "
              f"longer fires — remove it", file=sys.stderr)

    ok = not new and not unjustified
    summary = (f"staticcheck: {n_files} files, "
               f"{len(new)} finding{'s' if len(new) != 1 else ''}"
               + (f", {len(grandfathered)} baselined"
                  if grandfathered else ""))
    print(summary, file=sys.stderr if ok else sys.stdout)
    return 0 if ok else 1
