"""staticcheck engine: rule registry, directives, baseline, reporting.

The serving stack's load-bearing invariants — the decode tick is ONE
compiled program, no stray device→host syncs, no leaked KV pages —
are enforced dynamically by jit-cache guards and allocator soaks, which
means a regression is only caught after a benchmark runs.  This package
enforces the same invariants *statically*, at lint time, over the AST.

Vocabulary:

  * **Rule** — a named AST pass over one file (``rules/``).  Each rule
    guards one invariant and reports ``Finding``s.
  * **Directive** — a ``# staticcheck: ...`` comment in the scanned
    source.  ``disable=<rule>[,<rule>...] [-- justification]``
    suppresses matching findings on its line (or, on a standalone
    comment line, the next line); ``hotpath`` designates the
    function defined on / below it as a serving hot path (consumed by
    the ``hot-sync`` rule).  Suppressions that match no finding are
    themselves findings (``unused-suppression``) — dead suppressions
    hide future regressions.
  * **Baseline** — a JSON file of grandfathered findings (matched by a
    line-insensitive fingerprint) with a *mandatory written
    justification* per entry; an empty justification fails the run.

``check_source`` / ``check_file`` run the pipeline on one buffer/file;
``run_paths`` walks trees; the CLI lives in ``cli.py``.
"""
from __future__ import annotations

import ast
import dataclasses
import hashlib
import io
import json
import os
import tokenize
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

DIRECTIVE_PREFIX = "staticcheck:"
UNUSED_SUPPRESSION = "unused-suppression"
PARSE_ERROR = "parse-error"


@dataclasses.dataclass
class Finding:
    """One rule violation at a source location.

    ``context`` is the enclosing function's qualified name (or
    ``<module>``); fingerprints hash (rule, path, context, message) but
    NOT the line, so baselines survive unrelated edits above them."""

    rule: str
    path: str
    line: int
    col: int
    message: str
    context: str = "<module>"
    baselined: bool = False

    def fingerprint(self) -> str:
        key = f"{self.rule}|{self.path}|{self.context}|{self.message}"
        return hashlib.sha1(key.encode()).hexdigest()[:16]

    def render(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.rule}: "
                f"{self.message} [{self.context}]")

    def to_json(self) -> Dict:
        d = dataclasses.asdict(self)
        d["fingerprint"] = self.fingerprint()
        return d


@dataclasses.dataclass
class Suppression:
    line: int            # line the suppression applies to
    comment_line: int    # line the directive comment sits on
    rules: Tuple[str, ...]
    justification: str
    used: bool = False

    def covers(self, finding: Finding) -> bool:
        return finding.line == self.line and (
            "all" in self.rules or finding.rule in self.rules)


class Directives:
    """Parsed ``# staticcheck:`` comments of one file."""

    def __init__(self, suppressions: List[Suppression],
                 hotpath_lines: frozenset):
        self.suppressions = suppressions
        self.hotpath_lines = hotpath_lines

    def is_hotpath_def(self, def_line: int) -> bool:
        """A def is hot when the marker sits on the def line or the
        line directly above it (above any decorators counts too)."""
        return (def_line in self.hotpath_lines
                or def_line - 1 in self.hotpath_lines)


def _parse_directive(text: str, comment_line: int, own_line: bool
                     ) -> Tuple[Optional[Suppression], bool]:
    """Parse one comment's directive → (suppression | None, is_hotpath)."""
    body = text.split(DIRECTIVE_PREFIX, 1)[1].strip()
    if body == "hotpath":
        return None, True
    if body.startswith("disable="):
        rest = body[len("disable="):]
        justification = ""
        if "--" in rest:
            rest, justification = rest.split("--", 1)
            justification = justification.strip()
        rules = tuple(r.strip() for r in rest.split(",") if r.strip())
        target = comment_line + 1 if own_line else comment_line
        return Suppression(target, comment_line, rules, justification), False
    return None, False


def scan_directives(src: str) -> Directives:
    suppressions: List[Suppression] = []
    hotpath: set = set()
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(src).readline))
    except (tokenize.TokenError, IndentationError):
        return Directives([], frozenset())
    lines = src.splitlines()
    for tok in tokens:
        if tok.type != tokenize.COMMENT:
            continue
        text = tok.string.lstrip("#").strip()
        if not text.startswith(DIRECTIVE_PREFIX):
            continue
        line_no = tok.start[0]
        code_before = lines[line_no - 1][:tok.start[1]].strip()
        supp, is_hot = _parse_directive(text, line_no, not code_before)
        if supp is not None:
            suppressions.append(supp)
        if is_hot:
            # a standalone marker designates the NEXT line's def; a
            # trailing marker designates its own line
            hotpath.add(line_no if code_before else line_no + 1)
    return Directives(suppressions, frozenset(hotpath))


class FileContext:
    """Everything one rule pass needs about one file."""

    def __init__(self, path: str, src: str, tree: ast.Module,
                 directives: Directives):
        self.path = path
        self.src = src
        self.tree = tree
        self.directives = directives
        self._qualnames: Dict[int, str] = {}
        self._index_scopes()

    def _index_scopes(self) -> None:
        def walk(node, prefix):
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    q = f"{prefix}.{child.name}" if prefix else child.name
                    self._mark(child, q)
                    walk(child, q)
                elif isinstance(child, ast.ClassDef):
                    q = f"{prefix}.{child.name}" if prefix else child.name
                    walk(child, q)
                else:
                    walk(child, prefix)
        walk(self.tree, "")

    def _mark(self, fn: ast.AST, qualname: str) -> None:
        # keyed by function node id: unambiguous for nested defs
        self._qualnames[id(fn)] = qualname

    def qualname_of(self, fn: ast.AST) -> str:
        return self._qualnames.get(id(fn), getattr(fn, "name", "<module>"))

    def finding(self, rule: str, node: ast.AST, message: str,
                context: str = "<module>") -> Finding:
        return Finding(rule, self.path, getattr(node, "lineno", 0),
                       getattr(node, "col_offset", 0), message, context)

    def functions(self) -> Iterable[ast.FunctionDef]:
        for node in ast.walk(self.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield node


# --------------------------------------------------------------- registry
@dataclasses.dataclass
class Rule:
    name: str
    invariant: str                      # one-line invariant guarded
    check: Callable[[FileContext], List[Finding]]


RULES: Dict[str, Rule] = {}


def register(name: str, invariant: str):
    """Decorator: register ``fn(ctx) -> [Finding]`` as rule ``name``."""
    def deco(fn):
        assert name not in RULES, f"duplicate rule {name}"
        RULES[name] = Rule(name, invariant, fn)
        return fn
    return deco


def dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None (shared helper:
    most rules match callees by their dotted text)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def names_in(node: ast.AST) -> set:
    """All Name ids read anywhere inside ``node``."""
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


# ----------------------------------------------------------------- checking
def check_source(src: str, path: str = "<string>",
                 select: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run the (selected) rules over one source buffer, apply
    suppressions, and append unused-suppression findings.  Returns the
    surviving findings — baseline filtering is the caller's job."""
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [Finding(PARSE_ERROR, path, e.lineno or 0, e.offset or 0,
                        f"syntax error: {e.msg}")]
    directives = scan_directives(src)
    ctx = FileContext(path, src, tree, directives)
    findings: List[Finding] = []
    for rule in RULES.values():
        if select and rule.name not in select:
            continue
        findings.extend(rule.check(ctx))
    kept: List[Finding] = []
    for f in findings:
        supp = next((s for s in directives.suppressions if s.covers(f)),
                    None)
        if supp is not None:
            supp.used = True
        else:
            kept.append(f)
    for s in directives.suppressions:
        if not s.used and (select is None
                           or any(r in select or r == "all"
                                  for r in s.rules)):
            kept.append(Finding(
                UNUSED_SUPPRESSION, path, s.comment_line, 0,
                f"suppression of {', '.join(s.rules)} matches no finding",
            ))
    kept.sort(key=lambda f: (f.path, f.line, f.rule))
    return kept


def check_file(path: str, rel: Optional[str] = None,
               select: Optional[Sequence[str]] = None) -> List[Finding]:
    with open(path, encoding="utf-8") as fh:
        src = fh.read()
    return check_source(src, rel or path, select)


def iter_py_files(paths: Sequence[str]) -> List[Tuple[str, str]]:
    """(abs, display) pairs for every .py under ``paths`` (files pass
    through), sorted for deterministic reports."""
    out = []
    for p in paths:
        if os.path.isfile(p):
            out.append((p, p))
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(d for d in dirs
                             if d not in ("__pycache__", ".git"))
            for name in sorted(files):
                if name.endswith(".py"):
                    full = os.path.join(root, name)
                    out.append((full, os.path.relpath(full)))
    return sorted(out, key=lambda t: t[1])


def run_paths(paths: Sequence[str],
              select: Optional[Sequence[str]] = None
              ) -> Tuple[List[Finding], int]:
    findings: List[Finding] = []
    files = iter_py_files(paths)
    for full, rel in files:
        findings.extend(check_file(full, rel, select))
    return findings, len(files)


# ----------------------------------------------------------------- baseline
def load_baseline(path: str) -> Dict[str, Dict]:
    """fingerprint -> entry.  Missing file = empty baseline."""
    if not os.path.exists(path):
        return {}
    with open(path, encoding="utf-8") as fh:
        data = json.load(fh)
    return {e["fingerprint"]: e for e in data.get("entries", [])}


def write_baseline(path: str, findings: Sequence[Finding],
                   old: Dict[str, Dict]) -> int:
    """Write ``findings`` as the new baseline, keeping justifications
    already written for surviving fingerprints.  Returns the number of
    entries that still need a justification filled in."""
    entries, empty = [], 0
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule)):
        fp = f.fingerprint()
        just = old.get(fp, {}).get("justification", "")
        empty += not just
        entries.append({"fingerprint": fp, "rule": f.rule, "path": f.path,
                        "context": f.context, "message": f.message,
                        "justification": just})
    with open(path, "w", encoding="utf-8") as fh:
        json.dump({"version": 1, "entries": entries}, fh, indent=2)
        fh.write("\n")
    return empty


def apply_baseline(findings: Sequence[Finding], baseline: Dict[str, Dict]
                   ) -> Tuple[List[Finding], List[Finding], List[Dict],
                              List[Dict]]:
    """Split into (new, grandfathered, stale-entries, unjustified).

    ``unused-suppression`` findings are never baselineable — a dead
    suppression must be deleted, not grandfathered."""
    new, old_hits, seen = [], [], set()
    for f in findings:
        fp = f.fingerprint()
        if f.rule != UNUSED_SUPPRESSION and fp in baseline:
            f.baselined = True
            seen.add(fp)
            old_hits.append(f)
        else:
            new.append(f)
    stale = [e for fp, e in baseline.items() if fp not in seen]
    unjustified = [e for fp, e in baseline.items()
                   if fp in seen and not e.get("justification")]
    return new, old_hits, stale, unjustified
