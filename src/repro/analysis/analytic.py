"""Closed-form FLOP / HBM-byte model per (arch x shape) cell.

Why this exists: XLA's ``cost_analysis()`` counts a while-loop body ONCE
(scan-over-layers => ~L-fold undercount), so the roofline needs an
independent, exact napkin model.  The dry-run records BOTH (and we
cross-validate on unrolled compiles, see EXPERIMENTS.md §Dry-run).

Conventions: FLOPs are global per step (divide by chips outside);
MODEL_FLOPS follows the assignment: 6*N*D tokens for train (dense) with
N = active params; HBM bytes are per-device given sharding degrees.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.configs.base import ArchConfig, ShapeSpec
from repro.core import floor as fl


@dataclasses.dataclass
class CellEstimate:
    flops: float               # global per step (fwd+bwd for train)
    hbm_bytes_per_chip: float  # per device per step
    model_flops: float         # assignment's 6*N*D (or 6*N_active*D)
    detail: Dict


def _attn_flops_full(cfg: ArchConfig, B: int, S: int) -> float:
    """Causal QK^T + PV: 2 * 2 * B * S^2/2 * Hq * hd (per layer)."""
    if cfg.n_heads == 0:
        return 0.0
    per_layer = 2 * 2 * B * (S * S / 2) * cfg.n_heads * cfg.head_dim
    return per_layer * cfg.n_attn_layers


def _ssd_flops_full(cfg: ArchConfig, B: int, S: int, chunk: int = 128) -> float:
    """Chunked SSD per layer: intra-chunk (S*chunk quadratic) + state ops."""
    if cfg.n_ssm_layers == 0:
        return 0.0
    H, P, N = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    q = min(chunk, S)
    intra = 2 * B * S * q * H * (N + P)          # CB^T L + (.)x
    states = 2 * B * S * H * P * N * 2           # build + apply chunk states
    return (intra + states) * cfg.n_ssm_layers


def forward_flops(cfg: ArchConfig, B: int, S: int) -> float:
    """Full-sequence forward FLOPs (matmul-dominated terms)."""
    n_act = fl.active_param_count(cfg)
    # every weight param does 2 flops per token (matmul)
    mat = 2.0 * n_act * B * S
    return mat + _attn_flops_full(cfg, B, S) + _ssd_flops_full(cfg, B, S)


def decode_flops(cfg: ArchConfig, B: int, ctx: int) -> float:
    n_act = fl.active_param_count(cfg)
    mat = 2.0 * n_act * B
    if cfg.n_heads:
        eff = min(ctx, cfg.sliding_window) if cfg.sliding_window else ctx
        mat += 2 * 2 * B * eff * cfg.n_heads * cfg.head_dim * cfg.n_attn_layers
    if cfg.n_ssm_layers:
        H, P, N = cfg.n_ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        mat += 2 * B * H * P * N * 2 * cfg.n_ssm_layers
    return mat


def estimate(cfg: ArchConfig, shape: ShapeSpec, *, n_chips: int,
             tp: int, dp: int, weight_dtype_bytes: float = 2,
             kv_dtype_bytes: float = 2, remat: str = "blocks") -> CellEstimate:
    B, S = shape.global_batch, shape.seq_len
    W = fl.weight_bytes(cfg, weight_dtype_bytes)
    n_params = fl.param_count(cfg)
    n_active = fl.active_param_count(cfg)
    d = {}

    if shape.kind == "train":
        fwd = forward_flops(cfg, B, S)
        # bwd ~ 2x fwd; remat="blocks" adds ~1 extra fwd of the blocks
        remat_extra = {"none": 0.0, "blocks": 1.0, "full": 1.0}[remat]
        flops = fwd * (3.0 + remat_extra)
        model_flops = 6.0 * n_active * B * S
        # per-chip HBM: params read(fwd+bwd) + grad write + adam moments r/w
        w_chip = W / n_chips          # fsdp/zero shards across all chips
        opt = 8.0 * n_params / n_chips * 2      # f32 mu+nu read+write
        act = 2.0 * cfg.n_layers * B * S * cfg.d_model * 2 / dp * 2
        hbm = 3 * w_chip + opt + act
        d.update(fwd_flops=fwd, opt_bytes=opt, act_bytes=act)
    elif shape.kind == "prefill":
        flops = forward_flops(cfg, B, S)
        model_flops = 2.0 * n_active * B * S
        w_chip = W / tp
        act = 2.0 * cfg.n_layers * B * S * cfg.d_model * 2 / dp
        kv_write = fl.kv_bytes(cfg, S, kv_dtype_bytes) * B / n_chips
        hbm = w_chip + act + kv_write
        d.update(kv_write=kv_write)
    else:  # decode
        flops = decode_flops(cfg, B, S)
        model_flops = 2.0 * n_active * B
        w_chip = fl.weight_bytes(cfg, weight_dtype_bytes, active=B == 1) / tp
        kv = fl.kv_bytes(cfg, S, kv_dtype_bytes) * B / n_chips
        hbm = w_chip + kv
        d.update(kv_bytes=kv, w_chip=w_chip)

    return CellEstimate(flops, hbm, model_flops, d)
