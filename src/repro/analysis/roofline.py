"""Three-term roofline from dry-run artifacts (assignment §Roofline).

  compute_t    = FLOPs / (chips x peak_FLOP/s)
  memory_t     = HBM bytes / (chips x HBM bw)
  collective_t = collective wire bytes per chip / ICI link bw

Sources per cell JSON (written by launch/dryrun.py):
  * cost_analysis flops/bytes (XLA; undercounts while bodies — recorded
    as *_xla), * the analytic model (analysis/analytic.py; exact in
    layer count — used for the headline terms), * parsed collective
    bytes (analysis/hlo.py, while-corrected).

Emits the per-cell table for EXPERIMENTS.md §Roofline including the
dominant term, MODEL_FLOPS/HLO_FLOPs usefulness ratio, and a one-line
"what would move the dominant term" hint.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
from typing import Dict, List, Optional

from repro.core.hardware import DEFAULT_CHIP, ChipSpec


@dataclasses.dataclass
class RooflineRow:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    compute_t: float
    memory_t: float
    collective_t: float
    model_flops: float
    total_flops: float
    dominant: str
    useful_ratio: float
    hint: str

    @property
    def step_t(self) -> float:
        return max(self.compute_t, self.memory_t, self.collective_t)

    @property
    def roofline_fraction(self) -> float:
        """max-term / sum-of-terms: 1.0 = perfectly overlapped single
        bottleneck; low = badly balanced."""
        s = self.compute_t + self.memory_t + self.collective_t
        return self.step_t / s if s else 0.0


_HINTS = {
    "compute": ("more chips on the batch axes, or cut recompute "
                "(remat policy) / MoE capacity factor"),
    "memory": ("quantise streamed weights (int8/int4 fused), shard "
               "weights wider, or quantise the KV cache"),
    "collective": ("reshard to cut per-block all-reduces (2D sharding, "
                   "all-gather-weights vs all-reduce-activations), "
                   "overlap collectives with compute"),
}


def build_row(cell: Dict, chip: ChipSpec = DEFAULT_CHIP) -> RooflineRow:
    n = cell["n_chips"]
    flops = cell["analytic"]["flops"]
    hbm = cell["analytic"]["hbm_bytes_per_chip"]
    coll = cell["collectives"]["total_wire_bytes_per_chip"]
    compute_t = flops / (n * chip.peak_flops_bf16)
    memory_t = hbm / chip.hbm_bw
    collective_t = coll / chip.ici_bw
    terms = {"compute": compute_t, "memory": memory_t, "collective": collective_t}
    dominant = max(terms, key=terms.get)
    mf = cell["analytic"]["model_flops"]
    return RooflineRow(
        arch=cell["arch"], shape=cell["shape"], mesh=cell["mesh"],
        n_chips=n, compute_t=compute_t, memory_t=memory_t,
        collective_t=collective_t, model_flops=mf, total_flops=flops,
        dominant=dominant,
        useful_ratio=mf / flops if flops else 0.0,
        hint=_HINTS[dominant])


def load_cells(result_dir: str) -> List[Dict]:
    cells = []
    for p in sorted(glob.glob(os.path.join(result_dir, "*.json"))):
        with open(p) as f:
            cells.append(json.load(f))
    return cells


def markdown_table(rows: List[RooflineRow]) -> str:
    hdr = ("| arch | shape | chips | compute (ms) | memory (ms) | "
           "collective (ms) | bound | useful | step floor (ms) |\n"
           "|---|---|---:|---:|---:|---:|---|---:|---:|\n")
    body = ""
    for r in rows:
        body += (f"| {r.arch} | {r.shape} | {r.n_chips} | "
                 f"{r.compute_t*1e3:.3f} | {r.memory_t*1e3:.3f} | "
                 f"{r.collective_t*1e3:.3f} | **{r.dominant}** | "
                 f"{r.useful_ratio:.2f} | {r.step_t*1e3:.3f} |\n")
    return hdr + body


def main(result_dir: str = "results/dryrun", mesh: Optional[str] = "pod"):
    cells = [c for c in load_cells(result_dir)
             if c.get("status") == "ok" and (mesh is None or c["mesh"] == mesh)]
    rows = [build_row(c) for c in cells]
    print(markdown_table(rows))
    for r in rows:
        print(f"{r.arch}/{r.shape}: {r.dominant}-bound -> {r.hint}")


if __name__ == "__main__":
    import sys
    main(*sys.argv[1:])
