"""The paper's primary contribution as a library: the batch-1 decode
step-time decomposition — analytic HBM floor model (floor), hardware tier
registry (hardware), the measurement protocol (protocol/stats), and the
dispatch-mode executors that are the TPU analogue of the CUDA-Graphs A/B
(dispatch)."""
from repro.core import dispatch, floor, hardware, protocol, stats  # noqa: F401
