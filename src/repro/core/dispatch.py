"""Dispatch-mode executors — the TPU/JAX analogue of the paper's
CUDA-Graphs A/B (§5).

The paper's single-knob intervention replaces per-kernel CPU launches
with one graph replay.  In JAX the same axis is:

  eager     — every primitive dispatched from the host, one at a time
              (= per-kernel launch; the paper's eager PyTorch arm)
  stage_jit — each stage (embedding / decoder block / head) is its own
              compiled program, host Python loops over them
              (= fused kernels but per-layer launches; a midpoint the
              paper's instruments cannot express)
  full_jit  — the entire decode step is ONE compiled program
              (= CUDA Graphs replay; also how a production TPU serving
              stack runs)

``StepProgram`` decomposes a step into stages so all three executors run
*the same math*; only the dispatch schedule differs — exactly the
paper's "touch the launch term and only the launch term" requirement.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List

import jax

Stage = Callable[[Any], Any]   # state pytree -> state pytree

MODES = ("eager", "stage_jit", "full_jit")


@dataclasses.dataclass
class StepProgram:
    """A step decomposed into sequential stages over a carried state."""
    stages: List[Stage]

    def compose(self) -> Stage:
        def full(state):
            for st in self.stages:
                state = st(state)
            return state
        return full

    def executor(self, mode: str) -> Stage:
        """Build a callable state->state for the given dispatch mode."""
        if mode == "eager":
            # jax.disable_jit() makes *nested* jits run op-by-op too, so
            # every primitive is a separate host dispatch.
            def run(state):
                with jax.disable_jit():
                    return self.compose()(state)
            return run
        if mode == "stage_jit":
            # staticcheck: disable=recompile-hazard -- one wrapper per distinct stage, built once at executor construction and closed over by `run`; per-stage dispatch cost is the point of this mode
            jitted = [jax.jit(st) for st in self.stages]

            def run(state):
                for st in jitted:
                    state = st(state)
                return state
            return run
        if mode == "full_jit":
            return jax.jit(self.compose())
        raise ValueError(f"mode must be one of {MODES}, got {mode!r}")

    def launch_count(self, mode: str) -> int:
        """Host-dispatch count per step for ``mode`` (method form of the
        module-level ``launch_count``)."""
        return launch_count(self, mode)


def launch_count(program: StepProgram, mode: str) -> int:
    """Host-dispatch count per step (the paper's ~283-launch anchor, App D).

    eager: ~#primitives (unknown statically; returns -1), stage_jit: one
    per stage, full_jit: 1.
    """
    if mode == "eager":
        return -1
    if mode == "stage_jit":
        return len(program.stages)
    return 1
