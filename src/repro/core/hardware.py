"""Hardware tier registry.

The paper ladders four NVIDIA GPUs by peak HBM bandwidth; we ladder TPU
generations the same way and keep the paper's GPU specs so the floor
arithmetic can be validated against the paper's own Table 9 numbers.

All bandwidths are *decimal* bytes/s, matching the paper's convention
(it quotes W in decimal GB).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional


@dataclasses.dataclass(frozen=True)
class ChipSpec:
    name: str
    kind: str                  # "tpu" | "gpu"
    peak_flops_bf16: float     # FLOP/s
    hbm_bw: float              # bytes/s (decimal)
    hbm_bytes: float           # capacity, bytes
    ici_bw: Optional[float]    # bytes/s per ICI link (TPU); None for GPU
    usd_per_hour: float        # list price used for the cost ladder

    def t_floor_s(self, bytes_streamed: float) -> float:
        return bytes_streamed / self.hbm_bw


# --- TPU ladder (the deployment ladder under test on our side) ----------
# v5e constants are pinned by the assignment: 197 TFLOP/s bf16, 819 GB/s
# HBM, ~50 GB/s/link ICI.
TPU_V5E = ChipSpec("tpu-v5e", "tpu", 197e12, 819e9, 16e9, 50e9, 1.20)
TPU_V4 = ChipSpec("tpu-v4", "tpu", 275e12, 1228e9, 32e9, 50e9, 3.22)
TPU_V6E = ChipSpec("tpu-v6e", "tpu", 918e12, 1640e9, 32e9, 90e9, 2.70)
TPU_V5P = ChipSpec("tpu-v5p", "tpu", 459e12, 2765e9, 95e9, 90e9, 4.20)

# --- the paper's GPUs (validation of the floor model only) --------------
# B_peak from paper §3.3; prices: paper quotes Modal $3.50/hr H100 and
# $0.30/hr L4 (May 2026); A100/L40S filled from Modal list prices.
GPU_H100 = ChipSpec("h100-sxm5", "gpu", 989e12, 3350e9, 80e9, None, 3.50)
GPU_A100 = ChipSpec("a100-80gb", "gpu", 312e12, 2039e9, 80e9, None, 2.50)
GPU_L40S = ChipSpec("l40s", "gpu", 362e12, 864e9, 48e9, None, 1.95)
GPU_L4 = ChipSpec("l4", "gpu", 121e12, 300e9, 24e9, None, 0.30)

CHIPS: Dict[str, ChipSpec] = {
    c.name: c
    for c in [TPU_V5E, TPU_V4, TPU_V6E, TPU_V5P, GPU_H100, GPU_A100, GPU_L40S, GPU_L4]
}

TPU_LADDER = [TPU_V5E, TPU_V4, TPU_V6E, TPU_V5P]          # ordered by HBM bw
GPU_LADDER = [GPU_L4, GPU_L40S, GPU_A100, GPU_H100]       # the paper's ladder

# Primary roofline target (assignment-pinned).
DEFAULT_CHIP = TPU_V5E


def get_chip(name: str) -> ChipSpec:
    return CHIPS[name]
