"""Paper-faithful statistics: medians, bootstrap CIs, CV (paper §5, App D)."""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np


def p50(xs: Sequence[float]) -> float:
    return float(np.median(np.asarray(xs, dtype=np.float64)))


def mean(xs: Sequence[float]) -> float:
    return float(np.mean(np.asarray(xs, dtype=np.float64)))


def std(xs: Sequence[float]) -> float:
    return float(np.std(np.asarray(xs, dtype=np.float64), ddof=1)) if len(xs) > 1 else 0.0


def cv(xs: Sequence[float]) -> float:
    """Coefficient of variation (paper reports cross-session CV)."""
    m = mean(xs)
    return std(xs) / m if m else 0.0


def bootstrap_ci_mean(xs: Sequence[float], *, n_resamples: int = 10_000,
                      alpha: float = 0.05, seed: int = 0) -> Tuple[float, float]:
    """Percentile bootstrap CI on the mean (paper: 10000-resample 95% CI).

    Degenerate samples short-circuit instead of feeding the resampler:
    an empty sample has no mean — ``(nan, nan)`` — and a singleton's
    bootstrap distribution is the point itself — ``(x, x)`` — so quick
    benchmark runs with 1 repeat get an honest answer rather than a
    ``rng.integers(0, 0)`` ValueError or a vacuous resample."""
    arr = np.asarray(xs, dtype=np.float64)
    if arr.size == 0:
        return float("nan"), float("nan")
    if arr.size == 1:
        return float(arr[0]), float(arr[0])
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, len(arr), size=(n_resamples, len(arr)))
    means = arr[idx].mean(axis=1)
    lo, hi = np.quantile(means, [alpha / 2, 1 - alpha / 2])
    return float(lo), float(hi)


def paired_speedups(baseline: Sequence[float], treated: Sequence[float]) -> np.ndarray:
    """Within-session paired ratios (paper: eager/graphed per session)."""
    b = np.asarray(baseline, dtype=np.float64)
    t = np.asarray(treated, dtype=np.float64)
    assert b.shape == t.shape
    return b / t
