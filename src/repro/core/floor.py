"""The paper's analytic memory-floor model, exact per architecture.

t_floor(G, M, ctx) = (W(M) + K(M, ctx)) / B_peak(G)          (paper §3.4)
R_floor           = t_floor / t_obs

W is exact parameter-count × dtype-bytes arithmetic per family (dense /
moe / ssm / hybrid / vlm / audio).  K is the per-decode-step KV bytes
touched: 2 · n_attn_layers · n_kv_heads · head_dim · ctx · dtype_bytes
(paper §3.4); for SSM archs K degenerates to a constant-size state term.

Everything here is closed-form and unit-tested against the paper's own
Table 9 numbers (Qwen-2.5-7B / Mistral-7B / Llama-3.1-8B × 4 GPUs).
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import ArchConfig
from repro.core.hardware import ChipSpec


# --------------------------------------------------------------------------
# Parameter counting (exact)
# --------------------------------------------------------------------------

def _attn_params(cfg: ArchConfig) -> int:
    hd = cfg.head_dim
    q = cfg.d_model * cfg.n_heads * hd
    kv = 2 * cfg.d_model * cfg.n_kv_heads * hd
    o = cfg.n_heads * hd * cfg.d_model
    bias = (cfg.n_heads + 2 * cfg.n_kv_heads) * hd if cfg.qkv_bias else 0
    return q + kv + o + bias


def _dense_mlp_params(d_model: int, d_ff: int, gated: bool) -> int:
    return (3 if gated else 2) * d_model * d_ff


def _norm_params(cfg: ArchConfig) -> int:
    return 0 if cfg.norm == "nonparametric" else cfg.d_model


def _moe_layer_params(cfg: ArchConfig) -> int:
    router = cfg.d_model * cfg.n_experts
    routed = cfg.n_experts * _dense_mlp_params(cfg.d_model, cfg.moe_d_ff, cfg.mlp_gated)
    shared = (_dense_mlp_params(cfg.d_model, cfg.shared_d_ff, cfg.mlp_gated)
              if cfg.shared_d_ff else 0)
    return router + routed + shared


def _moe_layer_active_params(cfg: ArchConfig) -> int:
    router = cfg.d_model * cfg.n_experts
    routed = cfg.top_k * _dense_mlp_params(cfg.d_model, cfg.moe_d_ff, cfg.mlp_gated)
    shared = (_dense_mlp_params(cfg.d_model, cfg.shared_d_ff, cfg.mlp_gated)
              if cfg.shared_d_ff else 0)
    return router + routed + shared


def _mamba_layer_params(cfg: ArchConfig) -> int:
    d_in = cfg.d_inner
    h = cfg.n_ssm_heads
    in_proj = cfg.d_model * (2 * d_in + 2 * cfg.ssm_groups * cfg.ssm_state + h)
    conv = cfg.conv_channels * cfg.ssm_conv + cfg.conv_channels  # depthwise + bias
    scalars = 3 * h                      # A_log, D, dt_bias
    gated_norm = d_in
    out_proj = d_in * cfg.d_model
    in_norm = _norm_params(cfg)
    return in_proj + conv + scalars + gated_norm + out_proj + in_norm


def _embedding_params(cfg: ArchConfig) -> int:
    tables = max(1, cfg.n_codebooks)     # musicgen: one table per codebook
    embed = tables * cfg.vocab_size * cfg.d_model
    head = 0 if cfg.tie_embeddings else tables * cfg.vocab_size * cfg.d_model
    return embed + head


def _attn_block_params(cfg: ArchConfig) -> int:
    """One full attention block: norms + attention + dense MLP."""
    p = _attn_params(cfg) + 2 * _norm_params(cfg)
    if cfg.d_ff:
        p += _dense_mlp_params(cfg.d_model, cfg.d_ff, cfg.mlp_gated)
    return p


def param_count(cfg: ArchConfig) -> int:
    """Exact total parameter count."""
    p = _embedding_params(cfg) + _norm_params(cfg)     # + final norm
    if cfg.family in ("dense", "vlm", "audio"):
        p += cfg.n_layers * _attn_block_params(cfg)
    elif cfg.family == "moe":
        per_layer = (_attn_params(cfg) + 2 * _norm_params(cfg)
                     + _moe_layer_params(cfg))
        p += cfg.n_layers * per_layer
    elif cfg.family == "ssm":
        p += cfg.n_layers * _mamba_layer_params(cfg)
    elif cfg.family == "hybrid":
        p += cfg.n_layers * _mamba_layer_params(cfg)
        p += _attn_block_params(cfg)                   # ONE shared attn block
    else:
        raise ValueError(f"unknown family {cfg.family}")
    return p


def active_param_count(cfg: ArchConfig) -> int:
    """Per-token streamed parameters (MoE: shared + top-k experts only)."""
    if cfg.family != "moe":
        return param_count(cfg)
    per_layer = (_attn_params(cfg) + 2 * _norm_params(cfg)
                 + _moe_layer_active_params(cfg))
    return _embedding_params(cfg) + _norm_params(cfg) + cfg.n_layers * per_layer


# --------------------------------------------------------------------------
# Byte accounting (the paper's W and K terms)
# --------------------------------------------------------------------------

def weight_bytes(cfg: ArchConfig, dtype_bytes: float = 2, active: bool = False) -> float:
    n = active_param_count(cfg) if active else param_count(cfg)
    return n * dtype_bytes


def kv_bytes_per_token(cfg: ArchConfig, dtype_bytes: float = 2) -> float:
    """Per-token KV-cache bytes: 2 * L_attn * H_kv * d_head * bytes (paper §3.4)."""
    return 2.0 * cfg.n_attn_layers * cfg.n_kv_heads * cfg.head_dim * dtype_bytes


def ssm_state_bytes(cfg: ArchConfig, dtype_bytes: float = 2) -> float:
    """Constant recurrent-state bytes (ctx-independent)."""
    if cfg.n_ssm_layers == 0:
        return 0.0
    per_layer = (cfg.n_ssm_heads * cfg.ssm_head_dim * cfg.ssm_state   # SSD state h
                 + cfg.conv_channels * (cfg.ssm_conv - 1))            # conv window
    return cfg.n_ssm_layers * per_layer * dtype_bytes


def kv_bytes(cfg: ArchConfig, ctx: int, dtype_bytes: float = 2) -> float:
    """The paper's K(M, ctx): per-step cache bytes swept at context ``ctx``.

    Attention archs: linear in ctx (window-capped when cfg.sliding_window).
    SSM/hybrid archs additionally sweep the constant recurrent state.
    """
    eff_ctx = min(ctx, cfg.sliding_window) if cfg.sliding_window else ctx
    return kv_bytes_per_token(cfg, dtype_bytes) * eff_ctx + ssm_state_bytes(cfg, dtype_bytes)


@dataclasses.dataclass(frozen=True)
class FloorCell:
    """One (arch, chip, ctx) cell of the paper's floor table."""
    arch: str
    chip: str
    ctx: int
    batch: int
    weight_bytes: float
    kv_bytes: float
    t_floor_s: float

    @property
    def t_floor_ms(self) -> float:
        return self.t_floor_s * 1e3

    def r_floor(self, t_obs_s: float) -> float:
        return self.t_floor_s / t_obs_s


def floor_cell(cfg: ArchConfig, chip: ChipSpec, ctx: int, *,
               batch: int = 1,
               weight_dtype_bytes: float = 2,
               kv_dtype_bytes: float = 2,
               active_weights: bool = True,
               n_chips: int = 1) -> FloorCell:
    """Analytic decode-step floor.

    batch-1: streamed weights = active set (MoE benefit).  batch>1: routed
    experts are touched ~min(E, batch*top_k)/E of fully, interpolated.
    ``n_chips`` divides the streamed bytes (weights and KV are sharded).
    """
    w_act = weight_bytes(cfg, weight_dtype_bytes, active=True)
    w_tot = weight_bytes(cfg, weight_dtype_bytes, active=False)
    if not active_weights or cfg.family != "moe":
        w = w_tot if not active_weights else w_act if batch == 1 else w_tot
    else:
        coverage = min(1.0, batch * max(cfg.top_k, 1) / max(cfg.n_experts, 1))
        w = w_act + coverage * (w_tot - w_act)
    k = kv_bytes(cfg, ctx, kv_dtype_bytes) * batch
    streamed = (w + k) / n_chips
    return FloorCell(cfg.name, chip.name, ctx, batch, w, k,
                     streamed / chip.hbm_bw)


def r_floor(t_floor_s: float, t_obs_s: float) -> float:
    return t_floor_s / t_obs_s
