"""The paper's measurement protocol (§3.1, App D), as reusable machinery.

A *cell* = one configuration measured as: 5 warmup steps + 30 measured
steps, report the median (within-session).  A *session* = a fresh
environment (we approximate the paper's fresh Modal container with
``jax.clear_caches()`` + a fresh PRNG); N sessions give the
cross-session replication with bootstrap CI on the mean paired speedup.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List, Optional

import jax

from repro.core import stats

WARMUP_STEPS = 5
MEASURED_STEPS = 30


@dataclasses.dataclass
class CellResult:
    name: str
    step_times_s: List[float]
    meta: Dict

    @property
    def p50_s(self) -> float:
        return stats.p50(self.step_times_s)

    @property
    def p50_ms(self) -> float:
        return self.p50_s * 1e3

    @property
    def within_cv(self) -> float:
        return stats.cv(self.step_times_s)

    def to_json(self) -> Dict:
        return {"name": self.name, "p50_ms": self.p50_ms,
                "step_times_ms": [t * 1e3 for t in self.step_times_s],
                "within_cv": self.within_cv, **self.meta}


def _block(x):
    jax.block_until_ready(x)
    return x


def measure_cell(step_fn: Callable[[], object], *, name: str = "cell",
                 warmup: int = WARMUP_STEPS, steps: int = MEASURED_STEPS,
                 meta: Optional[Dict] = None) -> CellResult:
    """5 warmup + 30 measured single steps, wall-clock each, paper-style.

    ``step_fn`` must carry its own state (closure) and return a jax value
    we can block on.
    """
    for _ in range(warmup):
        _block(step_fn())
    times: List[float] = []
    for _ in range(steps):
        t0 = time.perf_counter()
        _block(step_fn())
        times.append(time.perf_counter() - t0)
    return CellResult(name, times, dict(meta or {}, warmup=warmup, steps=steps))


@dataclasses.dataclass
class ABResult:
    """Within-session paired A/B across N sessions (paper Table 2)."""
    name: str
    baseline_p50s: List[float]     # seconds, one per session
    treated_p50s: List[float]

    @property
    def speedups(self):
        return stats.paired_speedups(self.baseline_p50s, self.treated_p50s)

    def summary(self) -> Dict:
        sp = self.speedups
        lo, hi = stats.bootstrap_ci_mean(sp)
        return {
            "name": self.name,
            "n_sessions": len(self.baseline_p50s),
            "baseline_mean_ms": stats.mean(self.baseline_p50s) * 1e3,
            "baseline_cv": stats.cv(self.baseline_p50s),
            "treated_mean_ms": stats.mean(self.treated_p50s) * 1e3,
            "treated_cv": stats.cv(self.treated_p50s),
            "mean_speedup": stats.mean(sp),
            "speedup_std": stats.std(sp),
            "speedup_cv": stats.cv(sp),
            "speedup_ci95": [lo, hi],
            "per_session": [
                {"baseline_ms": b * 1e3, "treated_ms": t * 1e3, "speedup": float(s)}
                for b, t, s in zip(self.baseline_p50s, self.treated_p50s, sp)
            ],
        }


def run_ab(make_baseline: Callable[[int], Callable[[], object]],
           make_treated: Callable[[int], Callable[[], object]],
           *, n_sessions: int = 10, name: str = "ab",
           warmup: int = WARMUP_STEPS, steps: int = MEASURED_STEPS,
           fresh_session: bool = True) -> ABResult:
    """Paper §5 protocol: per session, run baseline arm then treated arm
    (within-session A/B), p50 each; pair the ratios across sessions.

    ``make_*`` take the session index (used as seed) and return a step fn.
    """
    base_p50s, treat_p50s = [], []
    for s in range(n_sessions):
        if fresh_session:
            jax.clear_caches()
        b = measure_cell(make_baseline(s), name=f"{name}/s{s}/baseline",
                         warmup=warmup, steps=steps)
        t = measure_cell(make_treated(s), name=f"{name}/s{s}/treated",
                         warmup=warmup, steps=steps)
        base_p50s.append(b.p50_s)
        treat_p50s.append(t.p50_s)
    return ABResult(name, base_p50s, treat_p50s)
