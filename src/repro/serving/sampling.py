"""Token sampling: greedy / temperature / top-k, batched, jit-safe."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample(logits: jnp.ndarray, key, *, temperature: float = 0.0,
           top_k: int = 0) -> jnp.ndarray:
    """logits (..., V) -> token ids (...)."""
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    lf = logits.astype(jnp.float32) / temperature
    if top_k:
        kth = jnp.sort(lf, axis=-1)[..., -top_k][..., None]
        lf = jnp.where(lf < kth, -jnp.inf, lf)
    return jax.random.categorical(key, lf, axis=-1).astype(jnp.int32)
