"""Batch-1 / batched streaming decode engine — the paper's workload.

The engine is built around the paper's own conclusion: the decode step
must be ONE compiled program (the CUDA-Graphs-equivalent ``full_jit``
mode), with the KV cache donated so steps run allocation-free.  For the
dispatch A/B experiments the engine can be opened up to ``stage_jit`` /
``eager`` execution of the same math (core.dispatch).

Generation offers two drivers:
  step-streamed — one host dispatch per token (what a Python serving
                  loop does; pays the launch tax once per token)
  fused-loop    — ``lax.scan`` over N tokens inside ONE program (the
                  TPU-idiomatic schedule: zero per-token host work;
                  this is "CUDA Graphs over the whole generation")
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.core.dispatch import StepProgram
from repro.models.model import Model
from repro.quant import quantize_tree
from repro.serving.sampling import sample


@dataclasses.dataclass
class GenerationResult:
    tokens: jnp.ndarray           # (B, n_new)
    step_times_s: List[float]     # per-token wall times (step-streamed only)
    tokens_per_s: float


class DecodeEngine:
    def __init__(self, model: Model, params, *, quant_path: str = "bf16",
                 kv_dtype=None, donate_cache: bool = True):
        self.model = model
        self.cfg: ArchConfig = model.cfg
        self.params = quantize_tree(params, quant_path) if quant_path != "bf16" else params
        self.quant_path = quant_path
        self.kv_dtype = kv_dtype
        donate = (1,) if donate_cache else ()
        self._prefill = jax.jit(model.prefill)
        self._step = jax.jit(model.decode_step, donate_argnums=donate)
        # the fused-generation driver: same multi-step program family as
        # the scheduler's horizon-K macro-ticks (one executable per
        # distinct horizon)
        self._steps_fused = jax.jit(
            model.decode_steps,
            static_argnames=("horizon", "temperature", "top_k", "eos_id"),
            donate_argnums=donate)

    # -------------------------------------------------------------- API
    def new_cache(self, batch: int, max_len: int):
        return self.model.init_cache(batch, max_len, kv_dtype=self.kv_dtype)

    def prefill(self, batch: Dict, max_len: int):
        B = next(iter(batch.values())).shape[0]
        cache = self.new_cache(B, max_len)
        logits, cache = self._prefill(self.params, batch, cache)
        return logits, cache

    def _token_shape(self, ids: jnp.ndarray) -> jnp.ndarray:
        if self.cfg.n_codebooks and ids.ndim == 2:
            return ids[:, None, :]          # (B, K) -> (B, 1, K)
        if ids.ndim == 1:
            return ids[:, None]
        return ids

    def generate_streamed(self, batch: Dict, *, max_len: int, n_new: int,
                          temperature: float = 0.0, top_k: int = 0,
                          seed: int = 0, timed: bool = False) -> GenerationResult:
        """One host dispatch per token (the paper's streaming workload).

        The generation wall is always timed (``tokens_per_s`` is real
        whether or not per-step instrumentation is on); ``timed=True``
        additionally records per-step walls for percentile reporting."""
        logits, cache = self.prefill(batch, max_len)
        key = jax.random.PRNGKey(seed)
        out, times = [], []
        tok = sample(logits[:, -1], key, temperature=temperature, top_k=top_k)
        out.append(tok)
        t_gen = time.perf_counter()
        for i in range(n_new - 1):
            key = jax.random.fold_in(key, i)
            t0 = time.perf_counter()
            logits, cache = self._step(self.params, cache, self._token_shape(tok))
            tok = sample(logits[:, -1], key, temperature=temperature, top_k=top_k)
            jax.block_until_ready(tok)
            if timed:
                times.append(time.perf_counter() - t0)
            out.append(tok)
        jax.block_until_ready(tok)
        wall = time.perf_counter() - t_gen
        tokens = jnp.stack(out, axis=1)
        tps = (n_new - 1) / wall if n_new > 1 and wall > 0 else float("nan")
        return GenerationResult(tokens, times, tps)

    def generate_fused(self, batch: Dict, *, max_len: int, n_new: int,
                       seed: int = 0, temperature: float = 0.0,
                       top_k: int = 0) -> GenerationResult:
        """N tokens inside one compiled program — zero per-token host
        dispatch, the beyond-CUDA-Graphs schedule available on an
        AOT-compiled stack.  Runs the SAME multi-step program
        (``Model.decode_steps``) the continuous scheduler's horizon-K
        macro-ticks dispatch, with the horizon spanning the whole
        generation and every lane active throughout."""
        logits, cache = self.prefill(batch, max_len)
        key = jax.random.PRNGKey(seed)
        tok0 = sample(logits[:, -1], key, temperature=temperature,
                      top_k=top_k)
        t0 = time.perf_counter()
        # staticcheck: disable=prng-discipline -- decode_steps fold_ins key per scan step, so its draws are disjoint from tok0's; re-deriving here would change golden token streams
        toks, _ = self._steps_fused(self.params, cache,
                                    self._token_shape(tok0), key, None,
                                    horizon=n_new - 1,
                                    temperature=temperature, top_k=top_k)
        toks = jax.block_until_ready(toks)
        dt = time.perf_counter() - t0
        tokens = jnp.concatenate([tok0[:, None], toks], axis=1)
        return GenerationResult(tokens, [], (n_new - 1) / dt)

    def generate_continuous(self, sessions, *, n_slots: int, max_len: int,
                            temperature: float = 0.0, top_k: int = 0,
                            seed: int = 0, dispatch_mode: str = "full_jit",
                            paged: bool = False, page_size: int = 16,
                            n_pages: Optional[int] = None,
                            prefill_chunk: Optional[int] = None,
                            steps_per_tick: int = 1,
                            eos_id: Optional[int] = None,
                            timed: bool = True,
                            prefix_cache: bool = False,
                            adaptive_k: bool = False,
                            min_steps_per_tick: int = 1,
                            priority_preemption: bool = True,
                            virtual_step_s: float = 1e-3,
                            virtual_dispatch_s: float = 4e-3,
                            shared_programs: bool = False,
                            kv_tier: str = "none",
                            tier_policy="spill",
                            host_pages: Optional[int] = None,
                            virtual_host_copy_s: float = 5e-4,
                            fault_injector=None,
                            retry_budget: int = 2,
                            session_ttl_s: Optional[float] = None,
                            restore_patience: int = 0,
                            quarantine_budget: int = 2,
                            self_audit: bool = False,
                            logit_screen: Optional[bool] = None):
        """Continuous batching: serve ``sessions`` (SessionRequest list)
        through a fixed-capacity slotted cache — admission, per-slot
        prefill, shared batched decode, eviction, FIFO backfill.  The
        decode step is the same ONE compiled program for the whole run
        (``dispatch_mode='full_jit'``); the eager/stage_jit executors
        remain available for the dispatch-tax A/B on the live workload.
        ``paged=True`` serves out of a page pool with per-slot block
        tables instead of per-slot ``max_len`` rows — ``n_pages`` below
        full backing oversubscribes memory, ``prefill_chunk`` admits
        long prompts chunk-by-chunk between decode ticks.
        ``steps_per_tick=K > 1`` fuses K decode steps into one
        macro-tick program (on-device sampling, one token transfer per
        macro-tick) — the horizon-K launch-overhead amortisation;
        ``eos_id`` ends sessions early on sampling that token.
        ``prefix_cache=True`` (paged only) shares page-aligned prompt
        prefixes across sessions through refcounted CoW pages — matched
        runs skip prefill entirely; greedy streams stay token-identical
        to the no-sharing baseline, stochastic streams draw under
        different sampling salts (see repro.serving.scheduler).

        Sessions whose requests carry ``arrival_s > 0`` are *replayed*:
        released into the admission queue by virtual arrival time
        against the scheduler's deterministic clock (``virtual_step_s``
        per device decode step + ``virtual_dispatch_s`` launch tax per
        dispatched program) — the trace-driven load-harness mode
        (serving/trace.py builds traces and scores the SLO metrics).
        ``adaptive_k=True`` lets each macro-tick pick its horizon from
        the [min_steps_per_tick, steps_per_tick] ladder based on queue
        depth and resident budgets; ``priority_preemption=False``
        degrades page-pressure eviction to the youngest-first baseline.

        ``kv_tier='host'`` (paged only) adds a host-DRAM page tier:
        preempted sessions *park* their full KV pages host-side and
        re-admission restores them instead of re-prefilling, and
        LRU-evicted prefix pages get a second life in a host prefix
        index — placement steered by ``tier_policy``
        (prefer-device | spill | lookahead), capacity by ``host_pages``,
        virtual migration cost by ``virtual_host_copy_s`` per page.

        ``fault_injector`` (serving/faults.py) arms a seeded chaos plan
        against the run: injected copy failures retry with backoff
        (``retry_budget``) then degrade to re-prefill, poisoned logits
        quarantine their lane (``quarantine_budget`` requeues, then
        fail-closed), aborts and the ``session_ttl_s`` deadline free a
        session's slot and pages with a terminal event, and
        ``self_audit`` checks the page accounting on idle ticks.
        ``restore_patience`` holds a parked host copy that many ticks
        before re-prefill admission supersedes it.  Returns a
        ``ContinuousResult``."""
        from repro.serving.scheduler import SlotScheduler
        sched = SlotScheduler(self.model, self.params, n_slots=n_slots,
                              max_len=max_len, dispatch_mode=dispatch_mode,
                              temperature=temperature, top_k=top_k,
                              seed=seed, kv_dtype=self.kv_dtype,
                              paged=paged, page_size=page_size,
                              n_pages=n_pages, prefill_chunk=prefill_chunk,
                              steps_per_tick=steps_per_tick, eos_id=eos_id,
                              timed=timed, prefix_cache=prefix_cache,
                              adaptive_k=adaptive_k,
                              min_steps_per_tick=min_steps_per_tick,
                              priority_preemption=priority_preemption,
                              virtual_step_s=virtual_step_s,
                              virtual_dispatch_s=virtual_dispatch_s,
                              shared_programs=shared_programs,
                              kv_tier=kv_tier, tier_policy=tier_policy,
                              host_pages=host_pages,
                              virtual_host_copy_s=virtual_host_copy_s,
                              fault_injector=fault_injector,
                              retry_budget=retry_budget,
                              session_ttl_s=session_ttl_s,
                              restore_patience=restore_patience,
                              quarantine_budget=quarantine_budget,
                              self_audit=self_audit,
                              logit_screen=logit_screen)
        for req in sessions:
            sched.submit(req)
        return sched.run()

    # ------------------------------------------------- dispatch A/B hooks
    def step_program(self, cache) -> StepProgram:
        return self.model.step_program(self.params, cache)
