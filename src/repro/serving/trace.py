"""Seeded request-trace generation + SLO metrics for the load harness.

The paper's central claim is that batch-1 decode latency is what the
*session* feels — aggregate tok/s hides launch overhead and runtime
slack because those only surface in per-token latency under realistic
load.  The serving stack's lockstep waves (every benchmark so far)
never exercise that: nothing arrives while the batch is busy, nothing
queues, nothing competes.  This module supplies the missing workload:

  * **Traces**: seeded, fully deterministic request streams with
    Poisson or bursty (on/off modulated) arrivals, mixed prompt/output
    length distributions, and *session classes* — named request
    populations with a priority and per-class SLOs (a TTFT bound and a
    per-token latency bound), e.g. a latency-critical ``interactive``
    class sharing the server with a throughput ``batch`` class.
  * **Replay**: trace requests are plain ``SessionRequest``s carrying
    ``arrival_s``/``priority``/``klass``; ``SlotScheduler`` releases
    them by virtual arrival time against its deterministic clock
    (``virtual_dispatch_s`` launch tax per dispatched program +
    ``virtual_step_s`` per device decode step — the paper's two latency
    terms as an explicit cost model), so queueing/admission/horizon
    policy is measurable machine-independently, while wall-clock TTFT
    rides along when the scheduler is ``timed``.
  * **Metrics**: ``slo_report`` turns per-session token emission stamps
    into TTFT and per-token latency percentiles (p50/p95/p99) and
    **goodput-under-SLO** — generated tokens belonging to sessions that
    met BOTH their class's TTFT and per-token bounds, per virtual
    second of makespan.  Throughput that blows the deadline counts for
    nothing, which is exactly how serving capacity is quoted in
    production and exactly what aggregate tok/s cannot see.

Determinism contract: generation uses ``random.Random`` (whose stream
is stable across Python versions) and serialisation uses fixed float
formatting, so a (config, seed) pair regenerates its trace
byte-for-byte — the golden-trace regression test pins this.
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

import numpy as np

from repro.serving.scheduler import ContinuousResult, SessionRequest

_FMT = "%.6f"                    # fixed-width times: byte-stable text


@dataclasses.dataclass(frozen=True)
class SessionClass:
    """One request population inside a trace."""
    name: str
    mix: float                   # sampling weight (normalised over classes)
    priority: int = 0            # scheduler preemption priority
    prompt_lo: int = 4           # prompt length range (uniform, inclusive)
    prompt_hi: int = 16
    new_lo: int = 4              # token budget range (uniform, inclusive)
    new_hi: int = 16
    slo_ttft_s: float = 0.5      # virtual-seconds bound on TTFT
    slo_tpot_s: float = 0.05     # virtual-seconds bound on p95 inter-token

    def __post_init__(self):
        assert self.mix > 0 and self.prompt_lo >= 1 and self.new_lo >= 1
        assert self.prompt_hi >= self.prompt_lo
        assert self.new_hi >= self.new_lo
        assert self.slo_ttft_s > 0 and self.slo_tpot_s > 0
        assert " " not in self.name and self.name, "class names are tokens"


@dataclasses.dataclass(frozen=True)
class TraceConfig:
    """Everything that determines a trace, and nothing else."""
    seed: int = 0
    n_requests: int = 16
    vocab_size: int = 512
    process: str = "poisson"     # "poisson" | "bursty"
    rate_rps: float = 20.0       # mean arrivals per virtual second
    burst_len: int = 4           # bursty: requests per on-burst
    burst_factor: float = 8.0    # bursty: intra-burst rate multiplier
    classes: Tuple[SessionClass, ...] = (
        SessionClass("interactive", mix=0.6, priority=1,
                     prompt_lo=4, prompt_hi=12, new_lo=4, new_hi=10,
                     slo_ttft_s=0.2, slo_tpot_s=0.02),
        SessionClass("batch", mix=0.4, priority=0,
                     prompt_lo=12, prompt_hi=32, new_lo=8, new_hi=24,
                     slo_ttft_s=1.0, slo_tpot_s=0.1),
    )

    def __post_init__(self):
        assert self.process in ("poisson", "bursty"), self.process
        assert self.n_requests >= 1 and self.vocab_size >= 2
        assert self.rate_rps > 0 and self.burst_len >= 1
        assert self.burst_factor >= 1.0
        assert self.classes
        names = [c.name for c in self.classes]
        assert len(set(names)) == len(names), "duplicate class names"


@dataclasses.dataclass(frozen=True)
class Trace:
    config: TraceConfig
    requests: Tuple[SessionRequest, ...]

    @property
    def classes(self) -> Dict[str, SessionClass]:
        return {c.name: c for c in self.config.classes}

    def max_len(self) -> int:
        """Smallest cache ``max_len`` that fits every session (last
        decode write lands at S + new - 2)."""
        return max(len(r.prompt) + r.max_new_tokens for r in self.requests)


def _exp(r: random.Random, rate: float) -> float:
    """Inverse-transform exponential gap — ``random.Random.random`` is
    version-stable, unlike library distribution helpers."""
    return -math.log(1.0 - r.random()) / rate


def generate_trace(cfg: TraceConfig) -> Trace:
    """Deterministically expand a config into a request stream.

    Poisson: i.i.d. exponential inter-arrival gaps at ``rate_rps``.
    Bursty: on/off modulation — bursts of ``burst_len`` requests whose
    intra-burst gaps run at ``rate_rps * burst_factor``, separated by
    off-gaps sized so the long-run mean rate stays ``rate_rps`` (the
    same offered load, maximally unfriendly arrangement — what an
    admission policy actually has to survive)."""
    r = random.Random(cfg.seed)
    weights = [c.mix for c in cfg.classes]
    total_w = sum(weights)
    reqs: List[SessionRequest] = []
    t = 0.0
    for i in range(cfg.n_requests):
        if cfg.process == "poisson":
            t += _exp(r, cfg.rate_rps)
        else:
            hi = cfg.rate_rps * cfg.burst_factor
            if i and i % cfg.burst_len == 0:
                # off-gap: the burst's saved time plus a fresh mean gap,
                # so bursts cluster without raising the offered load
                t += _exp(r, cfg.rate_rps / cfg.burst_len) \
                    + _exp(r, cfg.rate_rps)
            else:
                t += _exp(r, hi)
        # class choice by cumulative weight
        u = r.random() * total_w
        klass = cfg.classes[-1]
        for c in cfg.classes:
            if u < c.mix:
                klass = c
                break
            u -= c.mix
        plen = r.randrange(klass.prompt_lo, klass.prompt_hi + 1)
        n_new = r.randrange(klass.new_lo, klass.new_hi + 1)
        prompt = np.asarray([r.randrange(cfg.vocab_size)
                             for _ in range(plen)], np.int32)
        reqs.append(SessionRequest(
            session_id=f"t{i:03d}", prompt=prompt, max_new_tokens=n_new,
            arrival_s=t, priority=klass.priority, klass=klass.name))
    trace = Trace(cfg, tuple(reqs))
    validate_trace(trace)
    return trace


def validate_trace(trace: Trace) -> None:
    """Schema validity: unique session ids, positive monotone arrivals,
    positive lengths, known class labels, in-vocab tokens.  Raises
    ``ValueError`` with the offending session named (explicit raises,
    not asserts — a hand-edited trace file must fail loudly even under
    ``python -O``; the golden-trace test runs this on the checked-in
    file too)."""
    def bad(msg: str) -> None:
        raise ValueError(f"invalid trace: {msg}")

    classes = trace.classes
    seen: set = set()
    last = 0.0
    for req in trace.requests:
        if req.session_id in seen:
            bad(f"duplicate session id {req.session_id!r} — replay "
                f"results key sessions by id, duplicates would collide")
        seen.add(req.session_id)
        if req.arrival_s <= 0:
            bad(f"{req.session_id}: arrival_s={req.arrival_s!r} must be "
                f"> 0 (non-positive arrivals bypass trace release)")
        if req.arrival_s < last:
            bad(f"{req.session_id}: arrivals must be monotone "
                f"({req.arrival_s!r} after {last!r})")
        last = req.arrival_s
        if len(req.prompt) < 1:
            bad(f"{req.session_id}: empty prompt")
        if req.max_new_tokens < 1:
            bad(f"{req.session_id}: no token budget")
        if req.klass not in classes:
            bad(f"{req.session_id}: unknown class {req.klass!r}")
        if req.priority != classes[req.klass].priority:
            bad(f"{req.session_id}: priority disagrees with its class")
        toks = np.asarray(req.prompt)
        if toks.min() < 0 or toks.max() >= trace.config.vocab_size:
            bad(f"{req.session_id}: token out of vocab")


# --------------------------------------------------------------- text I/O
def trace_to_text(trace: Trace) -> str:
    """Serialise byte-stably: a header line pinning the config, one
    ``class`` line per session class, one request line per arrival with
    the prompt tokens inline (the trace IS the workload — no hidden
    regeneration step between a saved trace and its replay)."""
    cfg = trace.config
    lines = [
        "# trace v1 seed=%d n=%d vocab=%d process=%s rate=%s "
        "burst_len=%d burst_factor=%s"
        % (cfg.seed, cfg.n_requests, cfg.vocab_size, cfg.process,
           _FMT % cfg.rate_rps, cfg.burst_len, _FMT % cfg.burst_factor)]
    for c in cfg.classes:
        lines.append(
            "# class %s mix=%s prio=%d prompt=%d:%d new=%d:%d "
            "slo_ttft=%s slo_tpot=%s"
            % (c.name, _FMT % c.mix, c.priority, c.prompt_lo, c.prompt_hi,
               c.new_lo, c.new_hi, _FMT % c.slo_ttft_s,
               _FMT % c.slo_tpot_s))
    for r in trace.requests:
        toks = ",".join(str(int(t)) for t in np.asarray(r.prompt))
        lines.append("%s t=%s class=%s prio=%d new=%d prompt=%s"
                     % (r.session_id, _FMT % r.arrival_s, r.klass,
                        r.priority, r.max_new_tokens, toks))
    return "\n".join(lines) + "\n"


def trace_from_text(text: str) -> Trace:
    """Parse ``trace_to_text`` output back into a Trace (validated)."""
    header: Optional[dict] = None
    classes: List[SessionClass] = []
    reqs: List[SessionRequest] = []
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        parts = line.split()
        if parts[0] == "#" and parts[1] == "trace":
            assert parts[2] == "v1", f"unknown trace version {parts[2]}"
            kv = dict(p.split("=", 1) for p in parts[3:])
            header = kv
        elif parts[0] == "#" and parts[1] == "class":
            kv = dict(p.split("=", 1) for p in parts[3:])
            plo, phi = kv["prompt"].split(":")
            nlo, nhi = kv["new"].split(":")
            classes.append(SessionClass(
                parts[2], mix=float(kv["mix"]), priority=int(kv["prio"]),
                prompt_lo=int(plo), prompt_hi=int(phi),
                new_lo=int(nlo), new_hi=int(nhi),
                slo_ttft_s=float(kv["slo_ttft"]),
                slo_tpot_s=float(kv["slo_tpot"])))
        else:
            kv = dict(p.split("=", 1) for p in parts[1:])
            prompt = np.asarray([int(t) for t in kv["prompt"].split(",")],
                                np.int32)
            reqs.append(SessionRequest(
                session_id=parts[0], prompt=prompt,
                max_new_tokens=int(kv["new"]), arrival_s=float(kv["t"]),
                priority=int(kv["prio"]), klass=kv["class"]))
    assert header is not None, "missing trace header"
    cfg = TraceConfig(
        seed=int(header["seed"]), n_requests=int(header["n"]),
        vocab_size=int(header["vocab"]), process=header["process"],
        rate_rps=float(header["rate"]), burst_len=int(header["burst_len"]),
        burst_factor=float(header["burst_factor"]),
        classes=tuple(classes))
    trace = Trace(cfg, tuple(reqs))
    validate_trace(trace)
    return trace


# ----------------------------------------------------------- SLO metrics
def _percentiles(xs: Sequence[float]) -> Dict[str, float]:
    a = np.asarray(xs, float)
    return {"p50": float(np.percentile(a, 50)),
            "p95": float(np.percentile(a, 95)),
            "p99": float(np.percentile(a, 99))}


def session_meets_slo(sess, klass: SessionClass) -> bool:
    """TTFT within bound AND p95 of the per-token latency stream within
    bound (1-token sessions have no inter-token stream and pass that
    half trivially)."""
    if sess.ttft_s is None or sess.ttft_s > klass.slo_ttft_s:
        return False
    lat = sess.token_latencies_s()
    return lat.size == 0 or \
        float(np.percentile(lat, 95)) <= klass.slo_tpot_s


def slo_report(result: ContinuousResult,
               classes: Mapping[str, SessionClass],
               skip_prefix: str = "warm_") -> dict:
    """Aggregate + per-class SLO metrics of a replayed trace.

    Latencies are *virtual* (the scheduler's deterministic clock), so
    the numbers are machine-independent and byte-reproducible; wall
    TTFT percentiles ride along when the run was timed.  JSON-safe by
    construction: every value is a finite float, int, bool or None —
    never NaN (``json.dumps(report, allow_nan=False)`` must succeed,
    which the latency-field tests pin for timed and untimed runs)."""
    pool = [s for s in result.sessions.values()
            if not s.session_id.startswith(skip_prefix)]
    failed = [s for s in pool if s.status != "ok"]
    sessions = [s for s in pool
                if s.status == "ok" and s.token_times_s.size]
    n_total = len(sessions) + len(failed)
    statuses: dict = {}
    for s in failed:
        statuses[s.status] = statuses.get(s.status, 0) + 1
    # non-ok sessions (aborted / failed / expired) never enter the
    # latency percentile streams — a truncated stream's TPOT would
    # flatter the tail — but they stay in every SLO denominator: a
    # dropped session is a missed SLO, and its tokens are not goodput
    report: dict = {"sessions": n_total, "classes": {},
                    "failed_sessions": len(failed),
                    "statuses": dict(sorted(statuses.items()))}
    if not sessions:
        report.update(ttft=None, tpot=None, goodput_tok_s=0.0,
                      slo_sessions=0, makespan_s=0.0)
        if failed:
            report["slo_frac"] = 0.0
        return report
    t0 = min(s.arrival_s for s in sessions)
    t1 = max(float(s.token_times_s[-1]) for s in sessions)
    makespan = max(t1 - t0, 1e-12)
    all_lat = [lat for s in sessions
               for lat in s.token_latencies_s().tolist()]
    walls = [s.ttft_wall_s for s in sessions if s.ttft_wall_s is not None]
    ok_sessions = [s for s in sessions
                   if s.klass in classes
                   and session_meets_slo(s, classes[s.klass])]
    good_tokens = sum(len(s.tokens) for s in ok_sessions)
    report.update(
        ttft=_percentiles([s.ttft_s for s in sessions]),
        tpot=_percentiles(all_lat) if all_lat else None,
        ttft_wall=_percentiles(walls) if walls else None,
        slo_sessions=len(ok_sessions),
        slo_frac=len(ok_sessions) / n_total,
        goodput_tok_s=good_tokens / makespan,
        tokens_per_s_virtual=sum(len(s.tokens)
                                 for s in sessions) / makespan,
        makespan_s=makespan)
    for name, klass in classes.items():
        cs = [s for s in sessions if s.klass == name]
        cf = [s for s in failed if s.klass == name]
        if not cs and not cf:
            continue
        c_lat = [lat for s in cs for lat in s.token_latencies_s().tolist()]
        c_ok = [s for s in cs if session_meets_slo(s, klass)]
        report["classes"][name] = {
            "sessions": len(cs) + len(cf),
            "failed_sessions": len(cf),
            "priority": klass.priority,
            "ttft": _percentiles([s.ttft_s for s in cs]) if cs else None,
            "tpot": _percentiles(c_lat) if c_lat else None,
            "slo_ttft_s": klass.slo_ttft_s,
            "slo_tpot_s": klass.slo_tpot_s,
            "slo_frac": len(c_ok) / (len(cs) + len(cf)),
            "goodput_tok_s": sum(len(s.tokens) for s in c_ok) / makespan,
        }
    return report


# ------------------------------------------------------- canned configs
def poisson_config(seed: int = 0, n_requests: int = 16,
                   vocab_size: int = 512, rate_rps: float = 20.0,
                   classes: Optional[Tuple[SessionClass, ...]] = None
                   ) -> TraceConfig:
    kw = {} if classes is None else {"classes": classes}
    return TraceConfig(seed=seed, n_requests=n_requests,
                       vocab_size=vocab_size, process="poisson",
                       rate_rps=rate_rps, **kw)


def bursty_config(seed: int = 0, n_requests: int = 16,
                  vocab_size: int = 512, rate_rps: float = 20.0,
                  burst_len: int = 4, burst_factor: float = 8.0,
                  classes: Optional[Tuple[SessionClass, ...]] = None
                  ) -> TraceConfig:
    kw = {} if classes is None else {"classes": classes}
    return TraceConfig(seed=seed, n_requests=n_requests,
                       vocab_size=vocab_size, process="bursty",
                       rate_rps=rate_rps, burst_len=burst_len,
                       burst_factor=burst_factor, **kw)
