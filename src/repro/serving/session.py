"""Session-facing dataclasses of the continuous-batching scheduler.

Moved out of serving/scheduler.py so the request/result surface (what
callers construct and consume) is separable from the scheduling engine;
``repro.serving`` re-exports everything here, so existing imports keep
working.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

Event = Tuple
# ("admit"|"token"|"finish"|"preempt", session_id, slot[, token]) plus
# the fault/recovery kinds: "pressure"|"corrupt"|"degraded"|
# "quarantine"|"audit" and the terminal "aborted"|"failed"|"expired"


@dataclasses.dataclass(frozen=True)
class SessionRequest:
    """One user session: a prompt, a token budget, and (for trace
    replay) an arrival time plus class/priority metadata.

    ``arrival_s`` is in *virtual seconds relative to the ``run()`` that
    serves the request*: 0.0 (the default) keeps the legacy behaviour —
    the request is queued the moment it is submitted.  ``priority``
    orders preemption victims (higher = more important; equal
    priorities degrade to the youngest-first rule).  ``klass`` is a
    free-form session-class label carried through to ``SessionResult``
    so per-class SLO metrics can be grouped (serving/trace.py)."""
    session_id: str
    prompt: Sequence[int]            # (S,) token ids
    max_new_tokens: int
    arrival_s: float = 0.0           # virtual arrival (0 = immediate)
    priority: int = 0                # preemption priority (higher wins)
    klass: str = ""                  # session-class label (SLO grouping)


@dataclasses.dataclass
class SessionResult:
    session_id: str
    tokens: np.ndarray               # (max_new_tokens,) generated ids
    slot: int                        # slot the session was served in
    admitted_tick: int
    finished_tick: int
    step_times_s: List[float]        # shared-batch decode-step walls
    klass: str = ""                  # session-class label (from request)
    priority: int = 0
    status: str = "ok"               # "ok" | "aborted" | "failed" |
                                     # "expired" — non-ok sessions ended
                                     # early (tokens is the committed
                                     # prefix, not the full budget)
    arrival_s: float = 0.0           # virtual arrival on the run clock
    token_times_s: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0))
    # virtual emission timestamp per generated token (same clock as
    # ``arrival_s``) — queueing, prefill, preemption stalls and macro-
    # tick position all included, so diffs are the per-token latency
    # the session FELT, not the shared-batch service wall
    ttft_s: Optional[float] = None   # token_times_s[0] - arrival_s
    ttft_wall_s: Optional[float] = None
    # wall-clock TTFT (queue release -> first token); None when the
    # scheduler ran timed=False — never NaN, so JSON stays clean

    def token_latencies_s(self) -> np.ndarray:
        """Virtual inter-token latencies (the TPOT stream): gaps
        between consecutive emission stamps.  Empty for 1-token
        sessions."""
        return np.diff(self.token_times_s)


@dataclasses.dataclass
class ContinuousResult:
    """Outcome of one ``SlotScheduler.run()`` call.

    ``run()`` may be called repeatedly on one scheduler (submit → run →
    submit → run); every field belongs to exactly one of two groups,
    and which group is part of its contract:

    **Cumulative** over the scheduler's lifetime (all ``run()`` calls so
    far): ``sessions``, ``events``, ``decode_steps``.
    ``step_cache_size``, ``launches_per_step``, ``steps_per_tick``,
    ``kv_tier``, and ``tier_policy`` describe the compiled program /
    configuration, not a count.

    **This ``run()`` call only** (delta since the call started):
    ``ticks``, ``wall_s``, ``tokens_per_s``, ``preemptions``,
    ``dispatches``, ``run_tokens``, ``step_kv_blocks``,
    ``host_dispatch_s``, ``host_sync_s``, ``prefill_tokens``,
    ``prefix_hits``, ``prefix_tokens_saved``, ``cow_copies``,
    ``arrivals``, ``horizon_hist``, the tier counters
    ``pages_spilled`` / ``pages_restored`` / ``tier_restores`` /
    ``host_prefix_hits``, and every fault/recovery counter
    (``fault_counts`` through ``retry_backoff_s``).  (``dispatches`` is
    the per-run delta of the cumulative ``decode_steps``;
    ``host_pages_used`` is the host-pool occupancy at the END of the
    call.)

    ``now_s`` is the scheduler's virtual clock at the end of the call —
    monotone across calls (a clock, not a counter); per-run virtual
    makespan is the difference of consecutive ``now_s`` readings."""
    sessions: Dict[str, SessionResult]  # cumulative: every finished session
    ticks: int                       # scheduler iterations this run()
    decode_steps: int                # batched decode dispatches (cumulative)
    wall_s: float
    tokens_per_s: float              # aggregate generated tokens / wall
    step_cache_size: Optional[int]   # compiled decode-step count (full_jit)
    launches_per_step: int           # host dispatches per decode step
    events: List[Event]              # cumulative event log
    preemptions: int = 0             # paged: sessions requeued for pages
    step_kv_blocks: Optional[List[int]] = None
    # paged: per decode step, summed ceil(live_len/page_size) over the
    # active lanes — the pages the fused kernel actually walks.  None
    # for contiguous runs.
    steps_per_tick: int = 1          # horizon K of the fused macro-tick
    dispatches: int = 0              # decode dispatches this run() call
    run_tokens: int = 0              # tokens generated this run() call
    host_dispatch_s: float = 0.0     # host wall building + dispatching
                                     # decode work (the launch term the
                                     # horizon amortises)
    host_sync_s: float = 0.0         # host wall blocked on the per-tick
                                     # token transfer
    prefill_tokens: int = 0          # tokens actually dispatched through
                                     # prefill programs this run()
    prefix_hits: int = 0             # admissions that matched a cached
                                     # prefix (prefix sharing; resumed
                                     # re-admissions count too, so this
                                     # may exceed the session count)
    prefix_tokens_saved: int = 0     # sequence tokens (prompt, plus the
                                     # generated prefix on resume) whose
                                     # prefill was skipped via shared
                                     # pages
    cow_copies: int = 0              # copy-on-write page faults served
    now_s: float = 0.0               # virtual clock at the end of the
                                     # call (monotone across calls)
    arrivals: int = 0                # trace requests released from the
                                     # arrival queue this run()
    adaptive_k: bool = False         # horizon chosen per tick (config)
    horizon_hist: Dict[int, int] = dataclasses.field(default_factory=dict)
    # macro-ticks dispatched per horizon K this run() — the adaptive
    # policy's visible footprint ({} for single-step runs)
    kv_tier: str = "none"            # page-tier config ("none" | "host")
    tier_policy: Optional[str] = None   # placement policy name (tiered)
    pages_spilled: int = 0           # KV pages copied device->host
    pages_restored: int = 0          # KV pages copied host->device
    tier_restores: int = 0           # parked sessions resumed via restore
    host_prefix_hits: int = 0        # pages served from the host prefix
                                     # index on admission
    host_pages_used: int = 0         # host-pool occupancy at call end
    # ---- fault injection / graceful degradation (serving/faults.py) ----
    fault_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    # injected faults that LANDED this run, by kind ({} without an
    # injector); ``faults_injected`` is their sum
    faults_injected: int = 0
    save_retries: int = 0            # host-tier save attempts repeated
    restore_retries: int = 0         # host-tier restore attempts repeated
    degraded_restores: int = 0       # restores abandoned for re-prefill
                                     # (retry budget spent / checksum)
    corrupt_blobs: int = 0           # parked blobs failing verify-on-
                                     # restore
    quarantines: int = 0             # lanes pulled by the logit screen
    aborted_sessions: int = 0        # mid-stream disconnects applied
    failed_sessions: int = 0         # fail-closed terminations
    expired_sessions: int = 0        # per-session TTL enforcements
    audit_failures: int = 0          # idle-tick self-audits that found
                                     # accounting damage
    retry_backoff_s: float = 0.0     # virtual seconds charged to retry
                                     # backoff (inside ``now_s``)

    def tokens_for(self, session_id: str) -> np.ndarray:
        return self.sessions[session_id].tokens


@dataclasses.dataclass
class _Session:
    """Scheduler-internal live-session state (one per submitted
    request); the public view is ``SessionResult``."""
    request: SessionRequest
    tokens: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    admitted_tick: int = -1
    finished_tick: int = -1
    step_times_s: List[float] = dataclasses.field(default_factory=list)
    # ---- paged bookkeeping ----
    pages: List[int] = dataclasses.field(default_factory=list)
    pos: int = 0                     # host mirror of cache["pos"][slot]
    prefilled: int = 0               # prefill_seq tokens written so far
    prefill_seq: Optional[np.ndarray] = None   # sequence being prefilled
    seq_cache: Optional[np.ndarray] = None     # memoised admission seq
                                     # (valid while waiting: tokens only
                                     # grow while resident in a slot)
    resume: bool = False             # re-admission after preemption
    admit_seq: int = -1              # monotone admission order (preempt prio)
    status: str = "ok"               # terminal status (see SessionResult)
    quarantines: int = 0             # logit-screen pulls so far
    tier_waits: int = 0              # restore-gate patience ticks spent
    arrival_s: float = 0.0           # virtual arrival on the run clock
    release_wall: Optional[float] = None   # perf_counter at queue entry
    token_times_s: List[float] = dataclasses.field(default_factory=list)
    first_token_wall: Optional[float] = None

    @property
    def sid(self) -> str:
        return self.request.session_id

    @property
    def priority(self) -> int:
        return self.request.priority

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.request.max_new_tokens

    @property
    def decoding(self) -> bool:
        """Prefill complete: the session takes part in decode steps."""
        return (self.prefill_seq is not None
                and self.prefilled >= len(self.prefill_seq))

    @property
    def next_input_token(self) -> int:
        """Token the next decode step feeds this lane.  Normally the
        last generated token; a fully-prefix-matched fresh admission has
        generated nothing yet and replays the last prompt token (its KV
        row is rewritten in place — into the CoW private copy — and the
        step's logits stand in for the skipped prefill's)."""
        return (self.tokens[-1] if self.tokens
                else int(self.prefill_seq[-1]))

    def to_result(self) -> SessionResult:
        return SessionResult(
            session_id=self.request.session_id,
            tokens=np.asarray(self.tokens, np.int32),
            slot=self.slot,
            admitted_tick=self.admitted_tick,
            finished_tick=self.finished_tick,
            step_times_s=self.step_times_s,
            klass=self.request.klass,
            priority=self.request.priority,
            status=self.status,
            arrival_s=self.arrival_s,
            token_times_s=np.asarray(self.token_times_s),
            ttft_s=(self.token_times_s[0] - self.arrival_s
                    if self.token_times_s else None),
            ttft_wall_s=(self.first_token_wall - self.release_wall
                         if self.first_token_wall is not None
                         and self.release_wall is not None else None))
