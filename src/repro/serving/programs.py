"""Compiled-program registry for the continuous-batching scheduler.

One place owns every ``jax.jit`` wrapper the scheduler dispatches —
prefill (whole-prompt or chunked), the CoW page copy, the tier's
save/restore page movers, and the decode step (single or horizon-K
fused).  Pulled out of scheduler.py so program wiring (what compiles,
what donates, what is shared) is separable from scheduling policy.

``shared_programs``: A/B drivers that build many schedulers over ONE
model (e.g. table13's arm sweep) pay a full recompile per instance,
because each jax.jit wrapper carries its own trace cache.  Opting in
parks the wrappers on the model so every scheduler over it reuses the
same compiled executables — donation is per call, so sharing the
callable is safe.  The scheduler's ``step_cache_size()`` then reports
a delta since its construction, keeping the "one executable per
(backend, K)" recompile guard meaningful per instance.
"""
from __future__ import annotations

from typing import Optional

import jax

from repro.models.model import Model


def jit_cache_size(fn) -> Optional[int]:
    """Compiled-executable count of a ``jax.jit`` callable.

    ``_cache_size()`` is a private jax internal (the only hook that
    exposes the per-callable executable cache today); wrap it so a jax
    upgrade that renames it degrades the recompile guard to ``None``
    (= "unknown") instead of crashing the scheduler.
    """
    try:
        return fn._cache_size()
    except Exception:
        return None


class SchedulerPrograms:
    """The jit wrappers one ``SlotScheduler`` dispatches.

    Attributes are ``None`` when the configuration doesn't use them:
    ``prefill_chunk``/``copy_page`` exist only paged, ``save_pages``/
    ``restore_pages`` only with the host KV tier, ``prefill_slot`` only
    contiguous, and exactly one of ``step`` (K=1) / ``steps``
    (horizon-K fused) under ``full_jit`` — both ``None`` for the
    stage/eager dispatch A/B, whose executor the scheduler builds
    itself (it needs the live cache)."""

    def __init__(self, model: Model, *, paged: bool, kv_tier: str,
                 dispatch_mode: str, steps_per_tick: int,
                 shared_programs: bool):
        if shared_programs:
            _shared = model.__dict__.setdefault("_shared_sched_jits", {})

            def _jit(name, make):
                if name not in _shared:
                    _shared[name] = make()
                return _shared[name]
        else:
            def _jit(name, make):
                return make()

        self.prefill_chunk = self.copy_page = None
        self.save_pages = self.restore_pages = None
        self.prefill_slot = None
        self.step = self.steps = None
        if paged:
            self.prefill_chunk = _jit(
                "prefill_chunk",
                lambda: jax.jit(model.prefill_chunk_into_slot,
                                donate_argnums=(2,)))
            self.copy_page = _jit(
                "copy_page",
                lambda: jax.jit(model.copy_kv_page, donate_argnums=(0,)))
            if kv_tier == "host":
                # one gather / one scatter program per pow-2 run length
                # (save_kv_blobs pads with the garbage page); the save
                # must NOT donate — the pool stays live under it
                self.save_pages = _jit(
                    "save_kv_pages", lambda: jax.jit(model.save_kv_pages))
                self.restore_pages = _jit(
                    "restore_kv_pages",
                    lambda: jax.jit(model.restore_kv_pages,
                                    donate_argnums=(0,)))
        else:
            self.prefill_slot = _jit(
                "prefill_slot",
                lambda: jax.jit(model.prefill_into_slot,
                                donate_argnums=(2,)))
        if dispatch_mode == "full_jit":
            # the production hot path: the whole step is one program,
            # cache donated so steps run allocation-free.  With
            # steps_per_tick > 1 it is the horizon-K multi-step scan —
            # ONE executable per (backend, K); lanes that finish
            # mid-horizon are masked off on device.
            if steps_per_tick > 1:
                self.steps = _jit(
                    "decode_steps",
                    lambda: jax.jit(
                        model.decode_steps,
                        static_argnames=("horizon", "temperature",
                                         "top_k", "eos_id"),
                        donate_argnums=(1,)))
            else:
                self.step = _jit(
                    "decode_step",
                    lambda: jax.jit(model.decode_step,
                                    donate_argnums=(1,)))

    def raw_step_cache_size(self) -> Optional[int]:
        if self.steps is not None:
            return jit_cache_size(self.steps)
        if self.step is not None:
            return jit_cache_size(self.step)
        return None
