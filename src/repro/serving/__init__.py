from repro.serving.engine import DecodeEngine, GenerationResult  # noqa: F401
from repro.serving.sampling import sample  # noqa: F401
from repro.serving.memory import BlockAllocator, PrefixCache  # noqa: F401
from repro.serving.programs import jit_cache_size  # noqa: F401
from repro.serving.scheduler import SlotScheduler  # noqa: F401
from repro.serving.session import (ContinuousResult,  # noqa: F401
                                   SessionRequest, SessionResult)
from repro.serving.trace import (SessionClass, Trace,  # noqa: F401
                                 TraceConfig, bursty_config,
                                 generate_trace, poisson_config,
                                 slo_report, trace_from_text,
                                 trace_to_text, validate_trace)
