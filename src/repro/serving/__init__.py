from repro.serving.engine import DecodeEngine, GenerationResult  # noqa: F401
from repro.serving.sampling import sample  # noqa: F401
from repro.serving.scheduler import (BlockAllocator,  # noqa: F401
                                     ContinuousResult, PrefixCache,
                                     SessionRequest, SessionResult,
                                     SlotScheduler, jit_cache_size)
from repro.serving.trace import (SessionClass, Trace,  # noqa: F401
                                 TraceConfig, bursty_config,
                                 generate_trace, poisson_config,
                                 slo_report, trace_from_text,
                                 trace_to_text, validate_trace)
