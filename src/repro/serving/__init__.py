from repro.serving.engine import DecodeEngine, GenerationResult  # noqa: F401
from repro.serving.sampling import sample  # noqa: F401
from repro.serving.scheduler import (ContinuousResult,  # noqa: F401
                                     SessionRequest, SessionResult,
                                     SlotScheduler)
