from repro.serving.engine import DecodeEngine, GenerationResult  # noqa: F401
from repro.serving.sampling import sample  # noqa: F401
from repro.serving.scheduler import (BlockAllocator,  # noqa: F401
                                     ContinuousResult, PrefixCache,
                                     SessionRequest, SessionResult,
                                     SlotScheduler, jit_cache_size)
