from repro.serving.engine import DecodeEngine, GenerationResult  # noqa: F401
from repro.serving.faults import (FaultInjector, FaultPlan,  # noqa: F401
                                  FaultPlanConfig, FaultSpec,
                                  InjectedFault, generate_fault_plan,
                                  plan_from_text, plan_to_text,
                                  validate_plan)
from repro.serving.sampling import sample  # noqa: F401
from repro.serving.memory import BlockAllocator, PrefixCache  # noqa: F401
from repro.serving.programs import jit_cache_size  # noqa: F401
from repro.serving.scheduler import SlotScheduler  # noqa: F401
from repro.serving.session import (ContinuousResult,  # noqa: F401
                                   SessionRequest, SessionResult)
from repro.serving.trace import (SessionClass, Trace,  # noqa: F401
                                 TraceConfig, bursty_config,
                                 generate_trace, poisson_config,
                                 slo_report, trace_from_text,
                                 trace_to_text, validate_trace)
