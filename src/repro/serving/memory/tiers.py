"""Page tiers: the device pool facade and the host-DRAM spill tier.

The paper's serving-side finding is that for batch-1 physical-AI
fleets *capacity*, not bandwidth, caps concurrent sessions — the regime
a 10x-capacity host-memory tier targets.  Two stores implement one
narrow interface the scheduler programs against:

  * ``PageStore`` — the single-tier baseline.  Owns the
    ``BlockAllocator``, the optional ``PrefixCache``, and the
    host-authoritative block-table / position mirrors; eviction and
    preemption destroy KV (sessions re-prefill on resume).
  * ``TieredPageStore`` — adds a fixed-capacity ``HostPagePool``.
    Preemption *parks*: a session's full KV pages are copied
    device→host (``Model.save_kv_pages``, one compiled program per
    pow-2 run length) before its device pages are released, and copied
    back (``Model.restore_kv_pages``) on re-admission — the tail past
    the parked blocks re-prefills as usual, so streams are greedy
    token-identical to the re-prefill baseline.  LRU-evicted prefix
    pages spill into a host prefix index (keyed by the exact token
    path) instead of dying, and admissions can restore a matching
    continuation.  What spills and when is a ``TierPolicy``
    (memory/policy.py); every migrated page is charged to the virtual
    clock through ``charge_cb``.

Restored bytes are the very bytes prefill/decode originally wrote, so
restore == re-prefill == no-preemption for greedy streams by
construction — the identity tests and table14 pin it end to end.
"""
from __future__ import annotations

import collections
import zlib
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.serving.memory.allocator import GARBAGE_PAGE, BlockAllocator
from repro.serving.memory.prefix import PrefixCache

# One page's host-side slabs in Model.save_kv_pages order: (k, v) for
# bf16 pools, (k, v, k_scale, v_scale) for int8-quantised ones — the
# tier is slab-structure-agnostic, codes and scales park together.
Blob = Tuple[np.ndarray, ...]


class TierCopyError(RuntimeError):
    """A host-tier page copy failed past its retry budget, or a parked
    blob failed verify-on-restore.  The store's state is left so the
    caller can degrade cleanly: on restore failure the parked entry and
    its handles survive (``drop_parked`` releases them) and NO device
    pages or refcounts were consumed by this call."""


def blob_checksum(blob: Blob) -> int:
    """CRC32 chained over a blob's slab components — cheap enough to
    run on every park and verify on every restore."""
    c = 0
    for comp in blob:
        c = zlib.crc32(np.ascontiguousarray(comp).tobytes(), c)
    return c


def _pad_pow2(n: int) -> int:
    """Pages per save/restore program are padded to the next power of
    two, so the compiled-program count stays O(log max_blocks) instead
    of one executable per distinct run length."""
    p = 1
    while p < n:
        p *= 2
    return p


def save_kv_blobs(save_jit, cache, pages: Sequence[int]) -> List[Blob]:
    """Batched device→host copy of ``pages``; padding gathers the
    garbage page (never read, content irrelevant) and is sliced off."""
    n = len(pages)
    ids = np.full((_pad_pow2(n),), GARBAGE_PAGE, np.int32)
    ids[:n] = pages
    slabs = [np.asarray(s) for s in save_jit(cache, jnp.asarray(ids))]
    return [tuple(s[:, i] for s in slabs) for i in range(n)]


def restore_kv_blobs(restore_jit, cache, pages: Sequence[int],
                     blobs: Sequence[Blob]):
    """Batched host→device copy of ``blobs`` into ``pages``; padding
    writes zeros into the garbage page (a write sink by contract)."""
    n = len(pages)
    assert n == len(blobs)
    pad = _pad_pow2(n)
    ids = np.full((pad,), GARBAGE_PAGE, np.int32)
    ids[:n] = pages
    slabs = [
        np.stack([b[c] for b in blobs]
                 + [np.zeros_like(blobs[0][c])] * (pad - n), axis=1)
        for c in range(len(blobs[0]))]
    return restore_jit(cache, jnp.asarray(ids),
                       *(jnp.asarray(s) for s in slabs))


class PageStore:
    """Single-tier page store: the narrow seam the scheduler programs
    against — allocation (with prefix-cache pressure relief), prefix
    match/register, and the block-table / position mirrors whose dirty
    flags gate the H2D upload (``sync``).  Tier hooks are no-ops here;
    ``TieredPageStore`` overrides them."""

    kv_tier = "none"
    policy = None
    # tier counters (class-level zeros on the single-tier store)
    pages_spilled = 0
    pages_restored = 0
    tier_restores = 0
    host_prefix_hits = 0
    park_fails = 0
    save_retries = 0
    restore_retries = 0
    corrupt_blobs = 0

    def __init__(self, *, n_slots: int, max_blocks: int, page_size: int,
                 n_pages: int, prefix_cache: bool = False):
        self.page_size = page_size
        self.n_pages = n_pages
        self.allocator = BlockAllocator(n_pages)
        self.prefix = PrefixCache(self.allocator) if prefix_cache else None
        self._bt = np.zeros((n_slots, max_blocks), np.int32)
        self._bt_dirty = True
        self._pos = np.zeros((n_slots,), np.int32)
        self._pos_dirty = True

    # ------------------------------------------------------- capacity
    def pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    @property
    def free_pages(self) -> int:
        return self.allocator.n_free

    @property
    def cached_pages(self) -> Optional[int]:
        return len(self.prefix) if self.prefix is not None else None

    # ----------------------------------------------------- allocation
    def alloc(self, n: int) -> Optional[List[int]]:
        """``allocator.alloc`` with prefix-cache pressure relief: when
        the free list is short, unreferenced cached prefix pages are
        reclaimed LRU-first to cover the shortfall.  Cached pages are a
        soft reserve — they never deny a MANDATORY allocation the bare
        pool could have served."""
        got = self.allocator.alloc(n)
        if got is None and self.prefix is not None:
            self.prefix.reclaim(n - self.allocator.n_free)
            got = self.allocator.alloc(n)
        return got

    def alloc_free(self, n: int) -> Optional[List[int]]:
        """Free-list-only allocation (optional horizon lookahead):
        speculative pages never drain the prefix cache."""
        return self.allocator.alloc(n)

    def can_cover(self, need: int, exclude: Sequence[int] = ()) -> bool:
        """Could ``need`` pages be obtained without preempting anyone —
        free list first, cache reclaim cascade as the fallback
        (``exclude``: matched pages an admission in flight is about to
        retain, which must count as pinned)?"""
        if self.allocator.n_free >= need:
            return True
        if self.prefix is None:
            return False
        return (self.allocator.n_free
                + self.prefix.reclaimable(exclude)) >= need

    def retain(self, pages: Sequence[int]) -> None:
        self.allocator.retain(pages)

    def release(self, pages: Sequence[int]) -> None:
        self.allocator.release(pages)

    # --------------------------------------------------------- prefix
    def match(self, seq: np.ndarray) -> List[int]:
        if self.prefix is None:
            return []
        return self.prefix.match(seq, self.page_size)

    def register(self, seq: np.ndarray, pages: Sequence[int],
                 n_blocks: int) -> None:
        if self.prefix is not None and n_blocks:
            self.prefix.register(seq, self.page_size, pages, n_blocks)

    def flush_prefix(self) -> int:
        return self.prefix.flush() if self.prefix is not None else 0

    # ---------------------------------------------------- block table
    def map_pages(self, slot: int, start_blk: int,
                  pages: Sequence[int]) -> None:
        self._bt[slot, start_blk:start_blk + len(pages)] = pages
        self._bt_dirty = True

    def set_pos(self, slot: int, pos: int) -> None:
        self._pos[slot] = pos
        self._pos_dirty = True

    def mirror_pos(self, slot: int, pos: int) -> None:
        """Update the host pos mirror WITHOUT dirtying: the device
        already holds this value (its decode step advanced it), so no
        upload is owed — only host-side resets dirty the vector."""
        self._pos[slot] = pos

    def clear_slot(self, slot: int) -> None:
        self._bt[slot, :] = GARBAGE_PAGE
        self._bt_dirty = True
        self._pos[slot] = 0
        self._pos_dirty = True

    def sync(self, cache, pos_always: bool = True) -> None:
        """Push the host-authoritative block table + positions into the
        cache pytree (pure data: never changes compiled shapes).  The
        block table only uploads when admission/eviction/allocation
        dirtied it; ``pos_always`` re-syncs positions every tick (the
        K=1 path — its decode step advances every lane's device pos),
        while the horizon-K path passes False (device steps clamp
        inactive lanes, so only host-side resets need an upload)."""
        if self._bt_dirty:
            cache["block_table"] = jnp.asarray(self._bt)
            self._bt_dirty = False
        if pos_always or self._pos_dirty:
            cache["pos"] = jnp.asarray(self._pos)
            self._pos_dirty = False

    # ------------------------------------------------------ self-audit
    def check(self, live_pages: Sequence[int] = ()) -> List[str]:
        """Consistency audit of the page accounting: allocator free
        list/set/refcounts, prefix-cache linkage, and (optionally) the
        resident sessions' ``live_pages``, which must all be held.
        Returns human-readable issue strings — empty means clean.  Pure
        host reads: safe to run on idle ticks."""
        issues = self.allocator.check()
        if self.prefix is not None:
            issues += self.prefix.check()
        for p in live_pages:
            if not 0 < p < self.n_pages:
                issues.append(f"block table maps bad page id {p}")
            elif self.allocator.refcount(p) <= 0:
                issues.append(f"mapped page {p} has no holder")
        return issues

    # ---------------------------------------------- tier hooks (no-op)
    def park(self, sid: str, n_full: int, pages: Sequence[int],
             cache) -> Optional[int]:
        """Single tier: nothing to park into — preemption re-prefills."""
        return None

    def parked_blocks(self, sid: str) -> int:
        return 0

    def take_parked(self, sid: str, skip: int, pages: Sequence[int],
                    cache):
        raise NotImplementedError("single-tier store parks nothing")

    def drop_parked(self, sid: str) -> None:
        pass

    def drop_shadows(self, sid: str) -> None:
        pass

    def host_match(self, seq: np.ndarray, from_blk: int,
                   max_blocks: int) -> List[Tuple[int, ...]]:
        return []

    def restore_host_prefix(self, paths, pages, cache):
        raise NotImplementedError("single-tier store has no host index")

    def flush_host(self) -> int:
        return 0

    @property
    def host_used(self) -> int:
        return 0


class HostPagePool:
    """Fixed-capacity pool of spilled KV page blobs in host DRAM.

    Handles are opaque ints; *pinned* blobs (parked sessions and shadow
    pre-spills — KV a waiting session will need back) are never
    evicted, unpinned blobs (the host prefix index) are LRU-evicted to
    make room.  ``on_drop`` tells the owner an unpinned handle was
    evicted so its index entry can be forgotten."""

    def __init__(self, capacity: int):
        assert capacity >= 1, "a host tier needs at least one page"
        self.capacity = capacity
        self._blobs: Dict[int, Blob] = {}
        self._pinned: set = set()
        self._lru: "collections.OrderedDict[int, None]" = \
            collections.OrderedDict()
        self._next = 0
        self.spilled = 0                 # total puts
        self.dropped = 0                 # LRU evictions of unpinned blobs
        self.on_drop: Optional[Callable[[int], None]] = None

    @property
    def used(self) -> int:
        return len(self._blobs)

    @property
    def free(self) -> int:
        return self.capacity - len(self._blobs)

    def reserve(self, n: int) -> bool:
        """Make room for ``n`` blobs by LRU-dropping unpinned entries;
        False (and no change beyond the drops) when pinned blobs alone
        leave the pool too full."""
        while self.free < n and self._lru:
            h, _ = self._lru.popitem(last=False)
            del self._blobs[h]
            self.dropped += 1
            if self.on_drop is not None:
                self.on_drop(h)
        return self.free >= n

    def put(self, blob: Blob, pinned: bool) -> Optional[int]:
        if not self.reserve(1):
            return None
        h = self._next
        self._next += 1
        self._blobs[h] = blob
        if pinned:
            self._pinned.add(h)
        else:
            self._lru[h] = None
        self.spilled += 1
        return h

    def touch(self, handle: int) -> None:
        if handle in self._lru:
            self._lru.move_to_end(handle)

    def get(self, handle: int) -> Blob:
        return self._blobs[handle]

    def pop(self, handle: int) -> Blob:
        blob = self._blobs.pop(handle)
        self._pinned.discard(handle)
        self._lru.pop(handle, None)
        return blob

    def replace(self, handle: int, blob: Blob) -> None:
        """Swap a resident blob's bytes in place (pin/LRU state keeps):
        the fault injector's corruption hook."""
        assert handle in self._blobs, f"unknown handle {handle}"
        self._blobs[handle] = blob


class TieredPageStore(PageStore):
    """Device pool + host-DRAM spill tier behind the ``PageStore``
    seam.  See the module docstring for the migration contract."""

    kv_tier = "host"

    def __init__(self, *, host_pages: int, policy, save_fn, restore_fn,
                 get_cache, charge_cb=None, retry_budget: int = 2,
                 retry_cb=None, verify_checksums: bool = True, **kw):
        super().__init__(**kw)
        assert retry_budget >= 0
        self.policy = policy
        self.host = HostPagePool(host_pages)
        self.host.on_drop = self._forget_handle
        self._save = save_fn             # (cache, pages) -> [Blob]
        self._restore = restore_fn       # (cache, pages, blobs) -> cache
        self._get_cache = get_cache      # live cache for the evict hook
        self._charge = charge_cb or (lambda n_pages: None)
        self.retry_budget = retry_budget
        self._retry = retry_cb or (lambda attempt: None)
        self.verify_checksums = verify_checksums
        self._parked: Dict[str, List[Optional[int]]] = {}  # sid -> handles
        self._shadow: Dict[Tuple[str, int], int] = {}      # (sid, blk) -> h
        self._shadow_sids: Dict[str, set] = {}
        self._hpath: Dict[Tuple[int, ...], int] = {}       # token path -> h
        self._by_handle: Dict[int, Tuple[int, ...]] = {}
        self._crc: Dict[int, int] = {}   # handle -> put-time checksum
        # instance counters shadow the class-level zeros
        self.pages_spilled = 0
        self.pages_restored = 0
        self.tier_restores = 0
        self.host_prefix_hits = 0
        self.park_fails = 0
        self.save_retries = 0
        self.restore_retries = 0
        self.corrupt_blobs = 0
        if policy.spill_prefix and self.prefix is not None:
            self.prefix.on_evict = self._spill_evicted_prefix

    # ------------------------------------------- guarded page movers
    def _save_guarded(self, cache, pages: Sequence[int]) -> List[Blob]:
        """``save_fn`` under the bounded retry budget; each retry is
        charged to the virtual clock via ``retry_cb(attempt)``."""
        last = None
        for attempt in range(self.retry_budget + 1):
            if attempt:
                self.save_retries += 1
                self._retry(attempt)
            try:
                return self._save(cache, pages)
            except Exception as e:           # noqa: BLE001 — transport
                last = e                     # faults are type-agnostic
        raise TierCopyError(
            f"save of {len(pages)} page(s) failed after "
            f"{self.retry_budget + 1} attempts") from last

    def _restore_guarded(self, cache, pages, blobs):
        last = None
        for attempt in range(self.retry_budget + 1):
            if attempt:
                self.restore_retries += 1
                self._retry(attempt)
            try:
                return self._restore(cache, pages, blobs)
            except Exception as e:           # noqa: BLE001
                last = e
        raise TierCopyError(
            f"restore of {len(pages)} page(s) failed after "
            f"{self.retry_budget + 1} attempts") from last

    def _put(self, blob: Blob, pinned: bool) -> Optional[int]:
        """``host.put`` recording the blob's put-time checksum."""
        h = self.host.put(blob, pinned)
        if h is not None:
            self._crc[h] = blob_checksum(blob)
        return h

    def _pop(self, h: int) -> Blob:
        self._crc.pop(h, None)
        return self.host.pop(h)

    def _verify(self, handles: Sequence[int]) -> int:
        """Blobs among ``handles`` whose bytes no longer match their
        put-time checksum (0 when verification is off)."""
        if not self.verify_checksums:
            return 0
        bad = sum(1 for h in handles
                  if blob_checksum(self.host.get(h)) != self._crc.get(h))
        self.corrupt_blobs += bad
        return bad

    # ------------------------------------------------- host prefix index
    def _forget_handle(self, handle: int) -> None:
        self._crc.pop(handle, None)
        path = self._by_handle.pop(handle, None)
        if path is not None:
            self._hpath.pop(path, None)

    def _spill_evicted_prefix(self, path: Tuple[int, ...],
                              page: int) -> None:
        """PrefixCache eviction hook: copy the dying page host-side and
        index it by its exact token path (content == f(token path), so
        the path is a collision-free key)."""
        if path in self._hpath:
            return
        try:
            (blob,) = self._save_guarded(self._get_cache(), [page])
        except TierCopyError:
            return                       # the page just dies single-tier
        h = self._put(blob, pinned=False)
        if h is None:
            return                       # pinned blobs own the pool
        self._hpath[path] = h
        self._by_handle[h] = path
        self.pages_spilled += 1
        self._charge(1)

    def host_match(self, seq: np.ndarray, from_blk: int,
                   max_blocks: int) -> List[Tuple[int, ...]]:
        """Token paths of host-index blocks continuing ``seq`` from
        block ``from_blk`` (exclusive-capped at ``max_blocks`` so a
        fresh prompt always keeps >= 1 tail token to prefill — its
        first sample comes from the tail's logits)."""
        paths = []
        for blk in range(from_blk, max_blocks):
            path = tuple(int(t) for t in seq[:(blk + 1) * self.page_size])
            if path not in self._hpath:
                break
            paths.append(path)
        return paths

    def restore_host_prefix(self, paths: Sequence[Tuple[int, ...]],
                            pages: Sequence[int], cache):
        """Copy matched host-index blobs back into fresh device pages
        (the entries move back to the device tier — the caller registers
        the pages in the device prefix cache).  Entries are consumed
        only on success: checksum mismatches drop the damaged entries
        and raise ``TierCopyError`` (the caller re-prefills); a
        restore failure past the retry budget raises with the entries
        kept (the bytes are fine — a later admission may succeed)."""
        handles = [self._hpath[p] for p in paths]
        if self._verify(handles):
            for h in handles:            # detected damage: purge it
                self._forget_handle(h)
                self._pop(h)
            raise TierCopyError(
                f"{len(paths)} host-prefix blob(s) failed checksum")
        blobs = [self.host.get(h) for h in handles]
        cache = self._restore_guarded(cache, pages, blobs)
        for h in handles:
            self._forget_handle(h)
            self._pop(h)
        self.pages_restored += len(pages)
        self.host_prefix_hits += len(pages)
        self._charge(len(pages))
        return cache

    def flush_host(self) -> int:
        """Drop every host prefix-index entry (end-of-run accounting;
        parked/shadow blobs — pinned KV a session still owns — stay)."""
        n = 0
        for path, h in list(self._hpath.items()):
            self._pop(h)
            self._by_handle.pop(h, None)
            del self._hpath[path]
            n += 1
        return n

    @property
    def host_used(self) -> int:
        return self.host.used

    def host_stats(self) -> Dict[str, int]:
        return {"capacity": self.host.capacity, "used": self.host.used,
                "parked": sum(len(h) for h in self._parked.values()),
                "shadow": len(self._shadow),
                "prefix": len(self._hpath)}

    # -------------------------------------------------- park / restore
    def park(self, sid: str, n_full: int, pages: Sequence[int],
             cache) -> Optional[int]:
        """Spill a preempted session's ``n_full`` full KV pages to the
        host pool (reusing shadow pre-spills — LookAheadSpill — where
        present).  Returns the pages copied *now*, or None when parking
        was impossible (no full pages, or pinned blobs already fill the
        host pool) — the caller then falls back to plain re-prefill."""
        assert sid not in self._parked, f"{sid} parked twice"
        shadows = self._shadow_sids.get(sid, set())
        fresh = [b for b in range(n_full) if b not in shadows]
        if n_full == 0 or not self.host.reserve(len(fresh)):
            self.drop_shadows(sid)
            self.park_fails += 1
            return None
        handles: List[Optional[int]] = [None] * n_full
        if fresh:
            try:
                blobs = self._save_guarded(cache, [pages[b] for b in fresh])
            except TierCopyError:
                # save failed past the retry budget before any blob was
                # admitted to the pool: nothing to unwind host-side, the
                # session degrades to plain re-prefill
                self.drop_shadows(sid)
                self.park_fails += 1
                return None
            for b, blob in zip(fresh, blobs):
                handles[b] = self._put(blob, pinned=True)
                assert handles[b] is not None, "reserve() covered park"
        for b in range(n_full):           # adopt shadows, drop overshoot
            if b in shadows:
                handles[b] = self._shadow.pop((sid, b))
        for b in shadows - set(range(n_full)):
            self._pop(self._shadow.pop((sid, b)))
        self._shadow_sids.pop(sid, None)
        self._parked[sid] = handles
        self.pages_spilled += len(fresh)
        if fresh:
            self._charge(len(fresh))
        return len(fresh)

    def parked_blocks(self, sid: str) -> int:
        return len(self._parked.get(sid, ()))

    def take_parked(self, sid: str, skip: int, pages: Sequence[int],
                    cache):
        """Restore a parked session's blocks ``skip..n_full-1`` into
        fresh device ``pages`` (blocks below ``skip`` were covered by a
        device prefix match — same tokens, same content) and retire the
        parked entry.

        The entry is consumed only AFTER verify + restore succeed: a
        checksum mismatch or a restore failure past the retry budget
        raises ``TierCopyError`` with the parked handles (and the host
        pool's accounting) intact, so the caller can release its device
        pages, ``drop_parked`` the dead copy, and degrade to re-prefill
        without leaking either pool."""
        handles = self._parked[sid]
        assert len(pages) == len(handles) - skip
        take = handles[skip:]
        if self._verify(take):
            raise TierCopyError(
                f"parked blob(s) of {sid} failed verify-on-restore")
        blobs = [self.host.get(h) for h in take]
        cache = self._restore_guarded(cache, pages, blobs)
        del self._parked[sid]
        for h in handles:
            self._pop(h)
        self.pages_restored += len(pages)
        self.tier_restores += 1
        self._charge(len(pages))
        return cache

    def drop_parked(self, sid: str) -> None:
        """Forget a parked entry without restoring (the session was
        re-admitted through a device prefix match or plain
        re-prefill)."""
        for h in self._parked.pop(sid, ()):
            self._pop(h)

    def corrupt_parked_blob(self) -> Optional[str]:
        """Fault-injection hook: flip one byte of the first parked blob
        of the lowest-sorted parked sid (deterministic victim choice).
        The restore-time checksum screen must catch the damage.
        Returns the victim sid, or None when nothing is parked."""
        for sid in sorted(self._parked):
            handles = [h for h in self._parked[sid] if h is not None]
            if not handles:
                continue
            h = handles[0]
            blob = self.host.get(h)
            comp = np.array(blob[0], copy=True)
            comp.view(np.uint8).reshape(-1)[0] ^= 0xFF
            self.host.replace(h, (comp,) + tuple(blob[1:]))
            return sid
        return None

    # ---------------------------------------------- shadow pre-spills
    def has_shadow(self, sid: str, blk: int) -> bool:
        return (sid, blk) in self._shadow

    def shadow_spill(self, sid: str, blks: Sequence[int],
                     pages: Sequence[int], cache) -> int:
        """LookAheadSpill: pre-copy a *resident* session's cold full
        pages host-side during idle ticks, so a later park copies only
        the un-shadowed remainder.  Cold full pages are immutable
        (decode writes only at ``pos``), so the copies stay valid."""
        if not self.host.reserve(len(blks)):
            return 0
        try:
            blobs = self._save_guarded(cache, pages)
        except TierCopyError:
            return 0                     # optional pre-spill: skip it
        for blk, blob in zip(blks, blobs):
            h = self._put(blob, pinned=True)
            assert h is not None
            self._shadow[(sid, blk)] = h
            self._shadow_sids.setdefault(sid, set()).add(blk)
        self.pages_spilled += len(blks)
        self._charge(len(blks))
        return len(blks)

    def drop_shadows(self, sid: str) -> None:
        for blk in self._shadow_sids.pop(sid, set()):
            self._pop(self._shadow.pop((sid, blk)))
