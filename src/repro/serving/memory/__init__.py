"""Serving memory subsystem: page accounting, prefix index, tiers.

Split out of the scheduler monolith so admission/dispatch (scheduler)
and page placement (here) evolve independently: the scheduler programs
against the narrow ``PageStore`` seam, and the host-DRAM tier plus its
placement/migration policies slot in behind it without touching
dispatch."""
from repro.serving.memory.allocator import GARBAGE_PAGE, BlockAllocator
from repro.serving.memory.policy import (LookAheadSpill, PreferDevice,
                                         SpillOnEvict, TierPolicy,
                                         get_policy)
from repro.serving.memory.prefix import PrefixCache
from repro.serving.memory.tiers import (HostPagePool, PageStore,
                                        TierCopyError, TieredPageStore,
                                        blob_checksum, restore_kv_blobs,
                                        save_kv_blobs)

__all__ = [
    "GARBAGE_PAGE", "BlockAllocator", "PrefixCache",
    "PageStore", "TieredPageStore", "HostPagePool",
    "save_kv_blobs", "restore_kv_blobs",
    "TierCopyError", "blob_checksum",
    "TierPolicy", "PreferDevice", "SpillOnEvict", "LookAheadSpill",
    "get_policy",
]
