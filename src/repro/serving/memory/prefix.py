"""Hash-chain prefix index over page-aligned token runs → pool pages.

Physical-AI fleets replay the same system prompt / scene preamble
across sessions; with a block table already indirecting every page,
"the same prefix" can simply BE the same pages.  ``PrefixCache``
indexes every fully-prefilled page by ``(parent page, its token run)``
so admission can alias the longest cached page-aligned prefix into a
new slot's block table and prefill only the tail (the scheduler's CoW
fault keeps shared pages unwritten — see serving/scheduler.py).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.memory.allocator import GARBAGE_PAGE, BlockAllocator


@dataclasses.dataclass
class _PrefixNode:
    """One cached page: ``key = (parent page, the page's token run)``."""
    key: Tuple[int, Tuple[int, ...]]
    page: int
    parent: int                      # parent page id; GARBAGE_PAGE = root
    children: set = dataclasses.field(default_factory=set)  # child pages
    last_used: int = 0               # LRU clock stamp


class PrefixCache:
    """Prefix index over the paged pool, one node per cached full page.

    A node's key is ``(parent page id, tuple of the page's tokens)`` —
    exact (dict equality, never a hash collision) and chain-unique: a
    page's KV content is a pure function of the token path from the
    root, so any two sessions whose prompts share a page-aligned prefix
    resolve to the SAME physical pages, whichever session prefilled
    them first.  Only *full* pages are indexed (a partial page is still
    being written and its content is not final).

    The cache holds one allocator reference per registered page, which
    is what keeps a finished session's prefix resident after its slot
    is reclaimed.  A cached page whose only remaining holder is the
    cache is *reclaimable*; under allocation pressure ``reclaim``
    releases such pages leaf-first in LRU order (a parent is never
    evicted while a child chain still hangs off it — the child's
    content is only reachable through the parent's chain).

    ``on_evict`` (optional) is called with ``(token_path, page)`` right
    before an eviction releases the page — while its device content is
    still valid and its parent chain still indexed — which is how the
    host-DRAM tier (memory/tiers.py) spills LRU-evicted prefix pages
    instead of losing them."""

    def __init__(self, allocator: BlockAllocator,
                 on_evict: Optional[Callable[[Tuple[int, ...], int],
                                             None]] = None):
        self._allocator = allocator
        self._nodes: Dict[Tuple[int, Tuple[int, ...]], _PrefixNode] = {}
        self._by_page: Dict[int, _PrefixNode] = {}
        self._clock = 0
        self.on_evict = on_evict

    def __len__(self) -> int:
        return len(self._nodes)

    def pages(self) -> List[int]:
        """Physical page ids currently registered (sorted)."""
        return sorted(self._by_page)

    def _now(self) -> int:
        self._clock += 1
        return self._clock

    @staticmethod
    def _run(tokens: np.ndarray, blk: int, page_size: int
             ) -> Tuple[int, ...]:
        return tuple(int(t)
                     for t in tokens[blk * page_size:(blk + 1) * page_size])

    def match(self, tokens: np.ndarray, page_size: int) -> List[int]:
        """Pages backing the longest cached page-aligned prefix of
        ``tokens``, root-first (empty when the first page misses).
        Walked nodes get their LRU stamp refreshed."""
        now = self._now()
        pages: List[int] = []
        parent = GARBAGE_PAGE
        for blk in range(len(tokens) // page_size):
            node = self._nodes.get((parent, self._run(tokens, blk,
                                                      page_size)))
            if node is None:
                break
            node.last_used = now
            pages.append(node.page)
            parent = node.page
        return pages

    def register(self, tokens: np.ndarray, page_size: int,
                 pages: Sequence[int], n_blocks: int) -> None:
        """Index the first ``n_blocks`` (full) pages of a session's
        prefilled run.  Each newly registered page gains a cache
        reference; blocks whose content is already cached (the session
        matched them, or another session prefilled identical content
        concurrently) keep the incumbent page — the walk continues down
        the INDEX's chain, so a mixed-ownership chain stays coherent."""
        now = self._now()
        parent = GARBAGE_PAGE
        for blk in range(n_blocks):
            key = (parent, self._run(tokens, blk, page_size))
            node = self._nodes.get(key)
            if node is None:
                page = pages[blk]
                if page in self._by_page:     # already indexed elsewhere
                    break
                node = _PrefixNode(key, page, parent, last_used=now)
                self._nodes[key] = node
                self._by_page[page] = node
                if parent != GARBAGE_PAGE:
                    self._by_page[parent].children.add(page)
                self._allocator.retain([page])
            node.last_used = now
            parent = node.page

    def reclaimable(self, exclude: Sequence[int] = ()) -> int:
        """Pages a full cascade of leaf-first evictions could free right
        now — cached pages held only by the cache whose entire subtree
        is likewise unreferenced.  ``exclude`` pages (about to be
        retained by an admission in flight) count as pinned.  Iterative
        post-order with memoisation: O(nodes) per call, no recursion
        depth to hit on deep chains."""
        ex = set(exclude)
        memo: Dict[int, bool] = {}
        for root in self._by_page:
            if root in memo:
                continue
            stack = [(root, False)]
            while stack:
                page, visited = stack.pop()
                if page in memo:
                    continue
                node = self._by_page[page]
                if visited:
                    memo[page] = (page not in ex
                                  and self._allocator.refcount(page) == 1
                                  and all(memo[c] for c in node.children))
                else:
                    stack.append((page, True))
                    stack.extend((c, False) for c in node.children
                                 if c not in memo)
        return sum(memo.values())

    def _token_path(self, node: _PrefixNode) -> Tuple[int, ...]:
        """Full token path root→``node`` (the exact content key of the
        page's KV).  Evictions are leaf-first, so every parent on the
        chain is still indexed while its leaf is being evicted."""
        runs = []
        while True:
            runs.append(node.key[1])
            if node.parent == GARBAGE_PAGE:
                break
            node = self._by_page[node.parent]
        return tuple(t for run in reversed(runs) for t in run)

    def _evict(self, node: _PrefixNode) -> None:
        if self.on_evict is not None:
            self.on_evict(self._token_path(node), node.page)
        del self._nodes[node.key]
        del self._by_page[node.page]
        if node.parent != GARBAGE_PAGE and node.parent in self._by_page:
            self._by_page[node.parent].children.discard(node.page)
        self._allocator.release([node.page])

    def reclaim(self, n: int) -> int:
        """Release up to ``n`` unreferenced cached pages back to the
        free list, LRU leaves first (evicting a leaf may expose its
        parent as the next candidate).  A heap of candidate leaves keeps
        this O((cache + n) log cache) — this runs inside the mandatory
        allocation path, so a per-eviction rescan (quadratic on deep
        chains, the same class of bug the allocator's free-set fixed)
        is not acceptable.  Returns the pages actually freed."""
        freed = 0
        heap = [(nd.last_used, nd.page) for nd in self._by_page.values()
                if not nd.children
                and self._allocator.refcount(nd.page) == 1]
        heapq.heapify(heap)
        while freed < n and heap:
            stamp, page = heapq.heappop(heap)
            nd = self._by_page.get(page)
            if nd is None or nd.children or nd.last_used != stamp \
                    or self._allocator.refcount(page) != 1:
                continue        # stale candidate
            parent = nd.parent
            self._evict(nd)
            freed += 1
            if parent != GARBAGE_PAGE:
                pn = self._by_page.get(parent)
                if pn is not None and not pn.children \
                        and self._allocator.refcount(parent) == 1:
                    heapq.heappush(heap, (pn.last_used, parent))
        return freed

    def flush(self) -> int:
        """Drop every unreferenced cached page (end-of-run accounting;
        pages still shared by live sessions stay)."""
        return self.reclaim(len(self._by_page))

    def check(self) -> List[str]:
        """Self-audit: every cached page must hold an allocator
        reference and the node/parent/child linkage must be coherent.
        Returns issue strings (empty = clean); pure reads."""
        issues = []
        if len(self._nodes) != len(self._by_page):
            issues.append("prefix node / by-page index size mismatch")
        for page, node in self._by_page.items():
            if self._allocator.refcount(page) < 1:
                issues.append(f"cached page {page} holds no allocator ref")
            if node.page != page:
                issues.append(f"cached page {page}: node page desync")
            if self._nodes.get(node.key) is not node:
                issues.append(f"cached page {page}: key index desync")
            if node.parent != GARBAGE_PAGE:
                pn = self._by_page.get(node.parent)
                if pn is None:
                    issues.append(f"cached page {page}: parent "
                                  f"{node.parent} not indexed")
                elif page not in pn.children:
                    issues.append(f"cached page {page}: missing from "
                                  f"parent {node.parent}'s children")
            for c in node.children:
                if c not in self._by_page:
                    issues.append(f"cached page {page}: dangling child {c}")
        return issues
