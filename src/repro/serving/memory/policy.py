"""Pluggable placement/migration policies for the host-DRAM KV tier.

A ``TierPolicy`` decides *what spills and when*; the mechanism (batched
device↔host page copies, the host pool, restore-on-resume) lives in
``TieredPageStore`` and is policy-independent.  Policies steer three
hooks:

  * ``spill_parked`` — park a preempted session's full KV pages
    host-side (vs the single-tier behaviour: destroy and re-prefill).
  * ``spill_prefix`` — give LRU-evicted prefix-cache pages a second
    life in the host prefix index.
  * ``idle_tick(sched)`` — optional background migration run by the
    scheduler on ticks with no admission pressure; ``LookAheadSpill``
    uses it to pre-copy the predicted next preemption victim's cold
    pages so the eventual park is (near) copy-free on the critical
    path.

Policies only change *schedules and copies*, never streams: greedy
token identity versus the single-tier baseline holds under every
policy (asserted in tests/test_kv_tiering.py and table14).
"""
from __future__ import annotations


class TierPolicy:
    """Base policy: what the host tier accepts and when it pre-copies."""

    name = "base"
    spill_parked = True
    spill_prefix = True

    def idle_tick(self, sched) -> None:
        """Background-migration hook; called by the scheduler on ticks
        with no waiting arrivals.  Default: nothing."""

    def __repr__(self) -> str:
        return f"{type(self).__name__}()"


class PreferDevice(TierPolicy):
    """Control arm: the host pool exists but nothing is ever placed in
    it — preemption destroys KV and resume re-prefills, byte-for-byte
    the single-tier scheduler.  A/B against this to isolate the tier's
    contribution."""

    name = "prefer-device"
    spill_parked = False
    spill_prefix = False


class SpillOnEvict(TierPolicy):
    """Default reactive policy: migrate exactly when the device tier
    gives a page up — park full pages at preemption, index prefix pages
    at LRU eviction.  No background copies, so every spill is on the
    preemption path and charged there."""

    name = "spill"


class LookAheadSpill(SpillOnEvict):
    """Reactive spilling plus look-ahead pre-copies (the LookAhead
    placement idiom from the data-placement simulators this tier
    mirrors): on idle ticks, shadow-copy up to ``budget`` cold full
    pages of the session the preemption rule would pick next — lowest
    priority, youngest admission, the exact ordering ``_preempt``
    uses — so when that preemption lands, park only copies the
    un-shadowed remainder.  Cold full pages are immutable (decode
    writes only at ``pos``), so shadows never go stale; if the victim
    finishes instead, its shadows are dropped."""

    name = "lookahead"

    def __init__(self, budget: int = 2):
        self.budget = budget

    def idle_tick(self, sched) -> None:
        store = sched.store
        live = [s for s in sched.slots if s is not None and s.pages]
        if not live:
            return
        victim = max(live, key=lambda s: (-s.priority, s.admit_seq))
        n_full = victim.pos // store.page_size
        blks = [b for b in range(n_full)
                if not store.has_shadow(victim.sid, b)][:self.budget]
        if blks:
            store.shadow_spill(victim.sid, blks,
                               [victim.pages[b] for b in blks],
                               sched.cache)

    def __repr__(self) -> str:
        return f"LookAheadSpill(budget={self.budget})"


_POLICIES = {
    "prefer-device": PreferDevice,
    "spill": SpillOnEvict,
    "lookahead": LookAheadSpill,
}


def get_policy(name) -> TierPolicy:
    """Resolve a policy by CLI name (an already-built policy instance
    passes through, so tests can inject configured ones)."""
    if isinstance(name, TierPolicy):
        return name
    try:
        return _POLICIES[name]()
    except KeyError:
        raise ValueError(
            f"unknown tier policy {name!r}; "
            f"choose from {sorted(_POLICIES)}") from None
