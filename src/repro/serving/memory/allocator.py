"""Refcounted page allocation over the paged KV pool.

The device pool (``Model.init_cache(paged=True)``) is a flat array of
fixed-size KV pages; which physical page backs a slot's block is pure
data (the block table).  This module owns the host-side accounting of
that pool: a LIFO free list with per-page reference counts, so pages
can be *shared* (prefix sharing aliases one physical page into many
block tables) and only return to the free list when the last holder is
gone.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

GARBAGE_PAGE = 0   # reserved pool page free/mid-prefill lanes point at


class BlockAllocator:
    """Refcounted LIFO free-list over a fixed pool of KV pages.

    Page ``GARBAGE_PAGE`` (0) is reserved as the write sink for lanes
    that have no real page under their current position (free slots,
    blocks beyond a session's allocation) and is never handed out.

    ``alloc`` hands pages out with refcount 1; prefix sharing adds
    holders (``retain``) when another slot's block table — or the prefix
    cache — points at the same physical page, and ``release`` drops one
    holder, returning the page to the free list only when the last
    holder is gone.  The free list is mirrored by a set, so double-free
    detection is O(1) per page instead of an O(free-list) membership
    scan (a long session releasing hundreds of pages used to make
    reclaim quadratic on big pools)."""

    def __init__(self, n_pages: int):
        assert n_pages >= 2, "need the garbage page plus >= 1 real page"
        self.n_pages = n_pages
        self._free: List[int] = list(range(n_pages - 1, 0, -1))
        self._free_set = set(self._free)
        self._refs = [0] * n_pages

    @property
    def n_free(self) -> int:
        return len(self._free)

    def refcount(self, page: int) -> int:
        return self._refs[page]

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` pages (refcount 1 each), or None (and no change) if
        under-supplied."""
        if n > len(self._free):
            return None
        got = [self._free.pop() for _ in range(n)]
        for p in got:
            self._free_set.discard(p)
            self._refs[p] = 1
        return got

    def retain(self, pages: Sequence[int]) -> None:
        """Add one holder to each (already allocated) page."""
        for p in pages:
            assert 0 < p < self.n_pages, f"bad page id {p}"
            assert self._refs[p] > 0, f"retain of unallocated page {p}"
            self._refs[p] += 1

    def release(self, pages: Sequence[int]) -> None:
        """Drop one holder per page; the last release frees the page."""
        for p in pages:
            assert 0 < p < self.n_pages, f"bad page id {p}"
            assert p not in self._free_set and self._refs[p] > 0, \
                f"double free of page {p}"
            self._refs[p] -= 1
            if self._refs[p] == 0:
                self._free.append(p)
                self._free_set.add(p)

    def check(self) -> List[str]:
        """Self-audit: free list ↔ free set ↔ refcount consistency.
        Returns human-readable issue strings (empty = clean).  Pure
        reads — never mutates, safe to run mid-serving."""
        issues = []
        if len(self._free) != len(set(self._free)):
            issues.append("free list holds duplicate pages")
        if set(self._free) != self._free_set:
            issues.append("free list and free set disagree")
        if GARBAGE_PAGE in self._free_set:
            issues.append("garbage page on the free list")
        for p in range(1, self.n_pages):
            r = self._refs[p]
            if r < 0:
                issues.append(f"page {p}: negative refcount {r}")
            elif p in self._free_set and r != 0:
                issues.append(f"page {p}: free with refcount {r}")
            elif p not in self._free_set and r == 0:
                issues.append(f"page {p}: refcount 0 but not free")
        return issues
