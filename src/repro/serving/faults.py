"""Seeded fault plans + the injector the scheduler consults.

The serving stack asserts every feature token-exactly against a
baseline; this module extends that discipline to *failures*.  A
``FaultPlan`` is a deterministic schedule of fault events on the same
virtual clock trace replay runs on — generated from one
``random.Random`` stream and serialised byte-stably exactly like
serving/trace.py traces — so a chaos run is as reproducible as a clean
one: the same ``(config, seed)`` pair regenerates the identical plan,
and replaying it reproduces the identical fault schedule, recovery
actions, and counters.

Fault kinds (``KINDS``) and where they bite:

  * ``save_fail`` / ``restore_fail`` — the next N host-tier page-copy
    calls (``save_kv_blobs`` / ``restore_kv_blobs``) raise
    ``InjectedFault``; the tier's bounded retry-with-backoff absorbs
    them or degrades to re-prefill (memory/tiers.py).
  * ``blob_corrupt`` — flip a byte of a parked host blob; the
    restore-time checksum screen must catch it and degrade.
  * ``pool_pressure`` — withhold pages from the device free list for a
    bounded virtual duration (a transient capacity spike).
  * ``nan_logits`` — poison one lane's sampled logits/tokens for one
    macro-tick; the scheduler's screen quarantines the session.
  * ``abort`` — a mid-stream client disconnect: the session is torn
    down wherever it lives and its slot/pages/blobs are freed.

The ``FaultInjector`` walks the plan against ``now_s``: copy-failure
specs arm consumable failure budgets (drawn by the tier's save/restore
wrappers), every other kind is returned from ``poll`` for the scheduler
to apply.  ``fired`` counts faults that actually landed — a spec whose
window finds nothing to break (nothing parked, nobody live) stays
unfired rather than corrupting an unrelated victim.
"""
from __future__ import annotations

import collections
import dataclasses
import random
from typing import Dict, List, Sequence, Tuple

KINDS = ("save_fail", "restore_fail", "blob_corrupt", "pool_pressure",
         "nan_logits", "abort")

_FMT = "%.6f"                    # fixed-width times: byte-stable text


class InjectedFault(RuntimeError):
    """Raised by the tier's copy wrappers when an armed copy failure is
    consumed — indistinguishable from a real transport error to the
    retry machinery, which is the point."""


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One scheduled fault event on the virtual clock."""
    kind: str
    at_s: float                  # virtual due time
    target: str = ""             # session id ("" = any live session)
    count: int = 1               # copy fails to arm / blobs / pages
    duration_s: float = 0.0      # pool_pressure: hold time


@dataclasses.dataclass(frozen=True)
class FaultPlanConfig:
    """Everything that determines a plan, and nothing else."""
    seed: int = 7
    n_faults: int = 8
    horizon_s: float = 1.0       # events land uniformly in [0, horizon)
    kinds: Tuple[str, ...] = KINDS
    max_count: int = 3           # per-event count drawn from [1, max]
    max_duration_s: float = 0.05  # pool_pressure hold ceiling

    def __post_init__(self):
        assert self.n_faults >= 0 and self.horizon_s > 0
        assert self.max_count >= 1 and self.max_duration_s > 0
        assert self.kinds and all(k in KINDS for k in self.kinds)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    config: FaultPlanConfig
    specs: Tuple[FaultSpec, ...]


def generate_fault_plan(cfg: FaultPlanConfig,
                        session_ids: Sequence[str] = ()) -> FaultPlan:
    """Deterministically expand a config into a fault schedule.
    ``session_ids`` (e.g. the trace's) lets targeted kinds pick real
    victims; without them targets stay "" (= whoever is live)."""
    r = random.Random(cfg.seed)
    sids = tuple(session_ids)
    specs = []
    for _ in range(cfg.n_faults):
        kind = cfg.kinds[r.randrange(len(cfg.kinds))]
        at = round(r.random() * cfg.horizon_s, 6)
        count = 1 + r.randrange(cfg.max_count)
        dur = 0.0
        target = ""
        if kind == "pool_pressure":
            dur = round((0.2 + 0.8 * r.random()) * cfg.max_duration_s, 6)
        if kind in ("nan_logits", "abort"):
            if sids:
                target = sids[r.randrange(len(sids))]
            count = 1            # one victim per spec, always
        specs.append(FaultSpec(kind, at, target, count, dur))
    specs.sort(key=lambda s: (s.at_s, s.kind, s.target))
    plan = FaultPlan(cfg, tuple(specs))
    validate_plan(plan)
    return plan


def validate_plan(plan: FaultPlan) -> None:
    """Schema validity with explicit raises (a hand-edited plan file
    must fail loudly even under ``python -O``)."""
    def bad(msg: str) -> None:
        raise ValueError(f"invalid fault plan: {msg}")

    last = 0.0
    for spec in plan.specs:
        if spec.kind not in KINDS:
            bad(f"unknown kind {spec.kind!r}")
        if spec.at_s < 0:
            bad(f"{spec.kind}: negative due time {spec.at_s!r}")
        if spec.at_s < last:
            bad(f"{spec.kind}: specs must be time-sorted "
                f"({spec.at_s!r} after {last!r})")
        last = spec.at_s
        if spec.count < 1:
            bad(f"{spec.kind}: count {spec.count!r} must be >= 1")
        if spec.duration_s < 0:
            bad(f"{spec.kind}: negative duration {spec.duration_s!r}")
        if spec.kind == "pool_pressure" and spec.duration_s <= 0:
            bad("pool_pressure needs a positive hold duration")
        if " " in spec.target:
            bad(f"target {spec.target!r} must be a token")


# --------------------------------------------------------------- text I/O
def plan_to_text(plan: FaultPlan) -> str:
    """Serialise byte-stably: a header pinning the config, one line per
    scheduled fault ('-' encodes the empty any-session target)."""
    cfg = plan.config
    lines = [
        "# faultplan v1 seed=%d n=%d horizon=%s max_count=%d "
        "max_duration=%s kinds=%s"
        % (cfg.seed, cfg.n_faults, _FMT % cfg.horizon_s, cfg.max_count,
           _FMT % cfg.max_duration_s, ",".join(cfg.kinds))]
    for s in plan.specs:
        lines.append("%s t=%s target=%s count=%d dur=%s"
                     % (s.kind, _FMT % s.at_s, s.target or "-", s.count,
                        _FMT % s.duration_s))
    return "\n".join(lines) + "\n"


def plan_from_text(text: str) -> FaultPlan:
    """Parse ``plan_to_text`` output back into a plan (validated)."""
    header = None
    specs: List[FaultSpec] = []
    for line in text.splitlines():
        line = line.strip()
        if not line:
            continue
        parts = line.split()
        if parts[0] == "#" and parts[1] == "faultplan":
            assert parts[2] == "v1", f"unknown plan version {parts[2]}"
            header = dict(p.split("=", 1) for p in parts[3:])
        else:
            kv = dict(p.split("=", 1) for p in parts[1:])
            target = kv["target"]
            specs.append(FaultSpec(
                parts[0], at_s=float(kv["t"]),
                target="" if target == "-" else target,
                count=int(kv["count"]), duration_s=float(kv["dur"])))
    assert header is not None, "missing fault plan header"
    cfg = FaultPlanConfig(
        seed=int(header["seed"]), n_faults=int(header["n"]),
        horizon_s=float(header["horizon"]),
        kinds=tuple(header["kinds"].split(",")),
        max_count=int(header["max_count"]),
        max_duration_s=float(header["max_duration"]))
    plan = FaultPlan(cfg, tuple(specs))
    validate_plan(plan)
    return plan


# ---------------------------------------------------------------- injector
class FaultInjector:
    """Walks a plan against the scheduler's virtual clock.

    ``poll(now_s)`` activates every spec now due: copy-failure specs
    arm the consumable ``save_fails`` / ``restore_fails`` budgets that
    the tier's guarded copy wrappers draw from (``take_copy_fail``);
    all other kinds are returned for the scheduler to apply in place.
    ``fired`` counts faults that actually landed — compare two runs'
    ``counters()`` for byte-exact chaos reproducibility."""

    def __init__(self, plan: FaultPlan):
        validate_plan(plan)
        self.plan = plan
        self._idx = 0
        self.save_fails = 0      # armed, not yet consumed
        self.restore_fails = 0
        self.fired: collections.Counter = collections.Counter()

    @property
    def scheduled(self) -> int:
        return len(self.plan.specs)

    def poll(self, now_s: float) -> List[FaultSpec]:
        """Activate specs due by ``now_s``; returns the ones the
        scheduler itself must apply (everything but copy failures)."""
        out = []
        specs = self.plan.specs
        while self._idx < len(specs) and specs[self._idx].at_s <= now_s:
            spec = specs[self._idx]
            self._idx += 1
            if spec.kind == "save_fail":
                self.save_fails += spec.count
            elif spec.kind == "restore_fail":
                self.restore_fails += spec.count
            else:
                out.append(spec)
        return out

    def take_copy_fail(self, which: str) -> bool:
        """Consume one armed copy failure ('save' | 'restore')."""
        if which == "save" and self.save_fails > 0:
            self.save_fails -= 1
            self.fired["save_fail"] += 1
            return True
        if which == "restore" and self.restore_fails > 0:
            self.restore_fails -= 1
            self.fired["restore_fail"] += 1
            return True
        return False

    def mark(self, kind: str) -> None:
        """Record a scheduler-applied fault as landed."""
        assert kind in KINDS, kind
        self.fired[kind] += 1

    def counters(self) -> Dict[str, int]:
        """Stable-keyed fired counts (zero-kinds omitted)."""
        return {k: self.fired[k] for k in KINDS if self.fired[k]}
