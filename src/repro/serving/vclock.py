"""Virtual-clock pacing for trace-driven serving.

``VirtualClockMixin`` carries the deterministic cost model the
scheduler charges against — a launch tax per dispatched program, a
service quantum per device decode step, a host-copy quantum per
migrated KV page — plus trace-arrival release and the adaptive-K
horizon pick (which is clock-driven: macro-ticks end at the next
scheduling event).  Pure host arithmetic; nothing here touches the
device.  Split from scheduler.py so admission/dispatch logic reads
separately from pacing policy.
"""
from __future__ import annotations

import heapq
import time
from typing import Optional, Tuple


def build_k_ladder(ceiling: int, floor: int) -> Tuple[int, ...]:
    """Halvings of the horizon ceiling down to the floor — one
    (backend, K) executable per rung, ever."""
    ladder = set()
    k = ceiling
    while k > floor:
        ladder.add(k)
        k //= 2
    ladder.add(floor)
    return tuple(sorted(ladder))


class VirtualClockMixin:
    """Clock/pacing methods mixed into ``SlotScheduler``.

    Uses scheduler state: ``now_s``, ``virtual_*_s``, ``timed``,
    ``_pending``/``_arrivals``, ``waiting``, ``slots``, ``paged``,
    ``adaptive_k``, ``steps_per_tick``, ``k_ladder``."""

    def _release_arrivals(self) -> None:
        """Release due trace requests; fast-forward the clock to the
        next arrival when the whole system is idle."""
        if self._pending:
            base = self.now_s
            for rel, seq, sess in self._pending:
                sess.arrival_s = base + rel
                heapq.heappush(self._arrivals, (base + rel, seq, sess))
            self._pending.clear()
        if self._arrivals and not self.waiting \
                and all(s is None for s in self.slots):
            self.now_s = max(self.now_s, self._arrivals[0][0])
        while self._arrivals and self._arrivals[0][0] <= self.now_s:
            _, _, sess = heapq.heappop(self._arrivals)
            sess.release_wall = time.perf_counter() if self.timed else None
            self.waiting.append(sess)
            self.arrivals_released += 1

    def _charge(self, steps: int, dispatches: int = 1) -> None:
        """Advance the clock: launch taxes + device service quanta."""
        self.now_s += (dispatches * self.virtual_dispatch_s
                       + steps * self.virtual_step_s)

    def _charge_migration(self, n_pages: int) -> None:
        """One batched KV-page migration: a launch tax plus a host-copy
        quantum per page (the tier's A/B currency — see table14)."""
        self.now_s += (self.virtual_dispatch_s
                       + n_pages * self.virtual_host_copy_s)

    def _stamp(self, sess, vt: Optional[float] = None) -> None:
        """Record the emission time of the token just appended."""
        sess.token_times_s.append(self.now_s if vt is None else vt)
        if self.timed and sess.first_token_wall is None \
                and len(sess.tokens) == 1:
            sess.first_token_wall = time.perf_counter()

    def _tick_horizon(self) -> int:
        """Horizon K for this macro-tick.  Fixed-K uses the ceiling;
        adaptive-K ends macro-ticks at the next *scheduling event*:
        shortest remaining budget when someone waits against full
        slots, never past an arrival that could fill a free slot, else
        the ladder top.  Only ladder rungs dispatch."""
        if not self.adaptive_k:
            return self.steps_per_tick
        k = self.steps_per_tick
        remaining = [s.request.max_new_tokens - len(s.tokens)
                     for s in self.slots
                     if s is not None and (not self.paged or s.decoding)]
        slots_full = all(s is not None for s in self.slots)
        if remaining:
            demand = bool(self.waiting) or bool(self._arrivals)
            k = min(k, min(remaining) if demand and slots_full
                    else max(remaining))
        if self._arrivals and not slots_full:
            # steps until the next arrival is due; +1 so an arrival
            # inside the next quantum still lets one step run
            until = self._arrivals[0][0] - self.now_s
            k = min(k, 1 + int(max(until, 0.0) / self.virtual_step_s))
        k = max(k, self.min_steps_per_tick)
        for rung in reversed(self.k_ladder):
            if rung <= k:
                return rung
        return self.min_steps_per_tick
