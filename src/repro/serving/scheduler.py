"""Continuous-batching session scheduler over a slotted KV cache.

The paper's conclusion (batch-1 decode is launch-bound, fixed by keeping
the whole step inside ONE compiled program) scales to multi-user serving
only if session churn never forces a recompile.  The scheduler therefore
serves K concurrent sessions out of a **fixed-capacity slotted cache**:

  * the decode batch dimension is the (constant) slot count — the step
    program, its shapes, and its compiled executable never change;
  * each slot carries its own write position (``cache["pos"]`` is a
    (n_slots,) vector) and a per-slot length mask, so sequences of
    different ages decode together (models/attention.py);
  * admission prefills a session's prompt **into** its slot
    (``Model.prefill_into_slot`` — one compile per distinct prompt
    length, amortised across all future admissions);
  * completed sessions are evicted and their slot is backfilled from a
    FIFO waiting queue; free slots ride along in the batch as masked
    lanes (their outputs are discarded, their stale K/V stays masked).

**Paged mode** (``paged=True``) removes the last capacity cliff: slots
no longer each reserve a full ``max_len`` K/V row.  The cache becomes a
pool of fixed-size pages plus a per-slot block table
(``Model.init_cache(paged=True)``); a host-side ``BlockAllocator``
free-list hands pages out on demand.  Admission is gated on free pages,
eviction reclaims them, and the pool may be *oversubscribed*
(``n_pages`` smaller than full backing) — capacity follows live tokens,
which is exactly the memory term the paper says dominates once the
launch tax is gone.  If decode outgrows the pool mid-flight, the
youngest session is preempted (pages reclaimed, session requeued and
later re-prefilled from its prompt + generated prefix) so the oldest
always progresses.  Long prompts can be admitted in fixed-size
**chunks** (``prefill_chunk``) interleaved with decode ticks, so one big
admission never stalls live sessions.  Shapes stay constant throughout:
the paged decode step is still ONE compiled program; page residency is
pure data (the block table).

The paged step's attention route follows the Model's ``decode_backend``:
``"pallas"`` runs the fused block-table kernel
(kernels/paged_decode_attention — pages read in place, per-step KV
traffic tracked in ``step_kv_blocks``), any other backend takes the
gather+SDPA reference through the materialised ``paged_view``.

**Prefix sharing** (``prefix_cache=True``, paged mode only) stops
moving — or even re-computing — shared prompt bytes at all: physical-AI
fleets replay the same system prompt / scene preamble across sessions,
and with a block table already indirecting every page, "the same
prefix" can simply BE the same pages.  A ``PrefixCache`` hash-chain
indexes every fully-prefilled page by (parent page, its token run); on
admission the longest cached page-aligned prefix is matched, the new
slot's block table points at the shared pages (``BlockAllocator``
refcounts track the holders), and only the unmatched tail is prefilled
(``prefill_chunk_into_slot`` from the matched boundary — tail chunks
write fresh private pages, so shared pages are never written).  A fully
cached prompt skips prefill entirely: the last prompt token is replayed
through the decode step for its logits, and since that step's KV write
lands inside the last shared page, the page is first **CoW-faulted**
into a private copy (one host-side page copy, before dispatch).
Eviction and preemption *release* (decrement) instead of freeing;
cached pages whose only holder is the cache are reclaimed LRU-leaf-
first, and only under allocation pressure.  The decode read path —
fused Pallas kernel and gather route alike — is untouched by
construction: which physical page backs a block was always pure data.
The identity contract is GREEDY: temperature-0 streams are token-
identical to the no-sharing baseline.  With ``temperature > 0`` a
fully-cached admission draws its first token under a decode-tick salt
instead of the admission salt (and shifts later admission salts), so
stochastic streams sample the same distributions under different keys
— same family, different draws.

**Trace replay** (requests with ``arrival_s > 0``) turns the scheduler
from a lockstep-wave harness into a load harness: sessions are released
into the FIFO queue by *virtual arrival time* instead of all at once,
against a deterministic virtual clock that charges every dispatched
program a launch tax (``virtual_dispatch_s``) plus ``virtual_step_s``
per device decode step — the paper's two latency terms, made explicit
so queueing, admission, and horizon policy trade off in a
machine-independent currency.  Every generated token is stamped with
its virtual emission time (and, when ``timed``, a wall timestamp), so
``SessionResult`` carries what the *session* feels: TTFT and the
per-token latency stream, including queueing and preemption stalls —
not just aggregate tok/s (serving/trace.py generates traces and turns
these stamps into SLO metrics).

**Adaptive horizon-K** (``adaptive_k=True``) makes the macro-tick react
to load instead of being a fixed throughput/latency trade: each tick
picks a horizon from a halving ladder (``steps_per_tick`` down to
``min_steps_per_tick``) — shrinking while the admission queue is deep
or the next arrival lands mid-horizon (a long fused tick would hold
admission hostage and blow TTFT), growing toward the ladder top while
resident sessions are long-running and nobody waits (amortising the
launch tax when latency is not under pressure).  Every ladder horizon
compiles once and is reused; greedy streams are token-identical to any
fixed K.  **Priority-aware preemption** (on by default; the
``priority_preemption=False`` baseline keeps youngest-first) picks
page-pressure victims lowest-priority-first, youngest within a
priority, and never evicts a higher-priority session for a lower one —
sessions of equal priority behave exactly like the old youngest-first
rule.

Scheduling is host-side Python; the per-token hot path is exactly the
paper's ``full_jit`` arm — one dispatch per decode step for the whole
slot batch — and the eager / stage_jit executors (core.dispatch) remain
available for the dispatch-tax A/B on the live continuous workload
(contiguous layout only; paged serving is full_jit-only).

**Horizon-K fused ticks** (``steps_per_tick=K > 1``) take the paper's
CUDA-Graphs finding one level further: even the full_jit arm pays one
Python round-trip + dispatch + sync *per token*, and on fast hardware
that launch tax — not bandwidth — caps batch-1 decode.  A macro-tick
runs ONE compiled program (``Model.decode_steps``: ``lax.scan`` over
``decode_step`` with on-device sampling) that advances every live slot
up to K tokens; lanes that hit EOS or their token budget mid-horizon
are masked no-ops on device (write-clamped like the ring path, frozen
pos), the (n_slots, K) token matrix returns in a single transfer, and
the host reconciles afterwards — trimming over-generated tokens,
evicting finished sessions, reclaiming their pages.  In paged mode the
``BlockAllocator`` pre-reserves lookahead pages covering each slot's
granted horizon BEFORE dispatch (shrinking the grant, preempting
younger sessions, or preempting the needy slot itself exactly like the
K=1 page-fault path), so the device never outruns its block table.
Admission and chunked prefill interleave between macro-ticks.  Greedy
output is token-identical to K=1 on every route (contiguous,
paged-gather, paged-pallas); there is exactly ONE compiled multi-step
program per (backend, K) reused through session churn.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import time
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dispatch import MODES, launch_count
from repro.models.model import Model
from repro.serving.sampling import sample

Event = Tuple  # ("admit"|"token"|"finish"|"preempt", session_id, slot[, token])

GARBAGE_PAGE = 0   # reserved pool page free/mid-prefill lanes point at


def jit_cache_size(fn) -> Optional[int]:
    """Compiled-executable count of a ``jax.jit`` callable.

    ``_cache_size()`` is a private jax internal (the only hook that
    exposes the per-callable executable cache today); wrap it so a jax
    upgrade that renames it degrades the recompile guard to ``None``
    (= "unknown") instead of crashing the scheduler.
    """
    try:
        return fn._cache_size()
    except Exception:
        return None


class BlockAllocator:
    """Refcounted LIFO free-list over a fixed pool of KV pages.

    Page ``GARBAGE_PAGE`` (0) is reserved as the write sink for lanes
    that have no real page under their current position (free slots,
    blocks beyond a session's allocation) and is never handed out.

    ``alloc`` hands pages out with refcount 1; prefix sharing adds
    holders (``retain``) when another slot's block table — or the prefix
    cache — points at the same physical page, and ``release`` drops one
    holder, returning the page to the free list only when the last
    holder is gone.  The free list is mirrored by a set, so double-free
    detection is O(1) per page instead of an O(free-list) membership
    scan (a long session releasing hundreds of pages used to make
    reclaim quadratic on big pools)."""

    def __init__(self, n_pages: int):
        assert n_pages >= 2, "need the garbage page plus >= 1 real page"
        self.n_pages = n_pages
        self._free: List[int] = list(range(n_pages - 1, 0, -1))
        self._free_set = set(self._free)
        self._refs = [0] * n_pages

    @property
    def n_free(self) -> int:
        return len(self._free)

    def refcount(self, page: int) -> int:
        return self._refs[page]

    def alloc(self, n: int) -> Optional[List[int]]:
        """Pop ``n`` pages (refcount 1 each), or None (and no change) if
        under-supplied."""
        if n > len(self._free):
            return None
        got = [self._free.pop() for _ in range(n)]
        for p in got:
            self._free_set.discard(p)
            self._refs[p] = 1
        return got

    def retain(self, pages: Sequence[int]) -> None:
        """Add one holder to each (already allocated) page."""
        for p in pages:
            assert 0 < p < self.n_pages, f"bad page id {p}"
            assert self._refs[p] > 0, f"retain of unallocated page {p}"
            self._refs[p] += 1

    def release(self, pages: Sequence[int]) -> None:
        """Drop one holder per page; the last release frees the page."""
        for p in pages:
            assert 0 < p < self.n_pages, f"bad page id {p}"
            assert p not in self._free_set and self._refs[p] > 0, \
                f"double free of page {p}"
            self._refs[p] -= 1
            if self._refs[p] == 0:
                self._free.append(p)
                self._free_set.add(p)


@dataclasses.dataclass
class _PrefixNode:
    """One cached page: ``key = (parent page, the page's token run)``."""
    key: Tuple[int, Tuple[int, ...]]
    page: int
    parent: int                      # parent page id; GARBAGE_PAGE = root
    children: set = dataclasses.field(default_factory=set)  # child pages
    last_used: int = 0               # LRU clock stamp


class PrefixCache:
    """Hash-chain prefix index over page-aligned token runs → pool pages.

    A node's key is ``(parent page id, tuple of the page's tokens)`` —
    exact (dict equality, never a hash collision) and chain-unique: a
    page's KV content is a pure function of the token path from the
    root, so any two sessions whose prompts share a page-aligned prefix
    resolve to the SAME physical pages, whichever session prefilled
    them first.  Only *full* pages are indexed (a partial page is still
    being written and its content is not final).

    The cache holds one allocator reference per registered page, which
    is what keeps a finished session's prefix resident after its slot
    is reclaimed.  A cached page whose only remaining holder is the
    cache is *reclaimable*; under allocation pressure ``reclaim``
    releases such pages leaf-first in LRU order (a parent is never
    evicted while a child chain still hangs off it — the child's
    content is only reachable through the parent's chain)."""

    def __init__(self, allocator: BlockAllocator):
        self._allocator = allocator
        self._nodes: Dict[Tuple[int, Tuple[int, ...]], _PrefixNode] = {}
        self._by_page: Dict[int, _PrefixNode] = {}
        self._clock = 0

    def __len__(self) -> int:
        return len(self._nodes)

    def pages(self) -> List[int]:
        """Physical page ids currently registered (sorted)."""
        return sorted(self._by_page)

    def _now(self) -> int:
        self._clock += 1
        return self._clock

    @staticmethod
    def _run(tokens: np.ndarray, blk: int, page_size: int
             ) -> Tuple[int, ...]:
        return tuple(int(t)
                     for t in tokens[blk * page_size:(blk + 1) * page_size])

    def match(self, tokens: np.ndarray, page_size: int) -> List[int]:
        """Pages backing the longest cached page-aligned prefix of
        ``tokens``, root-first (empty when the first page misses).
        Walked nodes get their LRU stamp refreshed."""
        now = self._now()
        pages: List[int] = []
        parent = GARBAGE_PAGE
        for blk in range(len(tokens) // page_size):
            node = self._nodes.get((parent, self._run(tokens, blk,
                                                      page_size)))
            if node is None:
                break
            node.last_used = now
            pages.append(node.page)
            parent = node.page
        return pages

    def register(self, tokens: np.ndarray, page_size: int,
                 pages: Sequence[int], n_blocks: int) -> None:
        """Index the first ``n_blocks`` (full) pages of a session's
        prefilled run.  Each newly registered page gains a cache
        reference; blocks whose content is already cached (the session
        matched them, or another session prefilled identical content
        concurrently) keep the incumbent page — the walk continues down
        the INDEX's chain, so a mixed-ownership chain stays coherent."""
        now = self._now()
        parent = GARBAGE_PAGE
        for blk in range(n_blocks):
            key = (parent, self._run(tokens, blk, page_size))
            node = self._nodes.get(key)
            if node is None:
                page = pages[blk]
                if page in self._by_page:     # already indexed elsewhere
                    break
                node = _PrefixNode(key, page, parent, last_used=now)
                self._nodes[key] = node
                self._by_page[page] = node
                if parent != GARBAGE_PAGE:
                    self._by_page[parent].children.add(page)
                self._allocator.retain([page])
            node.last_used = now
            parent = node.page

    def reclaimable(self, exclude: Sequence[int] = ()) -> int:
        """Pages a full cascade of leaf-first evictions could free right
        now — cached pages held only by the cache whose entire subtree
        is likewise unreferenced.  ``exclude`` pages (about to be
        retained by an admission in flight) count as pinned.  Iterative
        post-order with memoisation: O(nodes) per call, no recursion
        depth to hit on deep chains."""
        ex = set(exclude)
        memo: Dict[int, bool] = {}
        for root in self._by_page:
            if root in memo:
                continue
            stack = [(root, False)]
            while stack:
                page, visited = stack.pop()
                if page in memo:
                    continue
                node = self._by_page[page]
                if visited:
                    memo[page] = (page not in ex
                                  and self._allocator.refcount(page) == 1
                                  and all(memo[c] for c in node.children))
                else:
                    stack.append((page, True))
                    stack.extend((c, False) for c in node.children
                                 if c not in memo)
        return sum(memo.values())

    def _evict(self, node: _PrefixNode) -> None:
        del self._nodes[node.key]
        del self._by_page[node.page]
        if node.parent != GARBAGE_PAGE and node.parent in self._by_page:
            self._by_page[node.parent].children.discard(node.page)
        self._allocator.release([node.page])

    def reclaim(self, n: int) -> int:
        """Release up to ``n`` unreferenced cached pages back to the
        free list, LRU leaves first (evicting a leaf may expose its
        parent as the next candidate).  A heap of candidate leaves keeps
        this O((cache + n) log cache) — this runs inside the mandatory
        allocation path, so a per-eviction rescan (quadratic on deep
        chains, the same class of bug the allocator's free-set fixed)
        is not acceptable.  Returns the pages actually freed."""
        freed = 0
        heap = [(nd.last_used, nd.page) for nd in self._by_page.values()
                if not nd.children
                and self._allocator.refcount(nd.page) == 1]
        heapq.heapify(heap)
        while freed < n and heap:
            stamp, page = heapq.heappop(heap)
            nd = self._by_page.get(page)
            if nd is None or nd.children or nd.last_used != stamp \
                    or self._allocator.refcount(page) != 1:
                continue        # stale candidate
            parent = nd.parent
            self._evict(nd)
            freed += 1
            if parent != GARBAGE_PAGE:
                pn = self._by_page.get(parent)
                if pn is not None and not pn.children \
                        and self._allocator.refcount(parent) == 1:
                    heapq.heappush(heap, (pn.last_used, parent))
        return freed

    def flush(self) -> int:
        """Drop every unreferenced cached page (end-of-run accounting;
        pages still shared by live sessions stay)."""
        return self.reclaim(len(self._by_page))


@dataclasses.dataclass(frozen=True)
class SessionRequest:
    """One user session: a prompt, a token budget, and (for trace
    replay) an arrival time plus class/priority metadata.

    ``arrival_s`` is in *virtual seconds relative to the ``run()`` that
    serves the request*: 0.0 (the default) keeps the legacy behaviour —
    the request is queued the moment it is submitted.  ``priority``
    orders preemption victims (higher = more important; equal
    priorities degrade to the youngest-first rule).  ``klass`` is a
    free-form session-class label carried through to ``SessionResult``
    so per-class SLO metrics can be grouped (serving/trace.py)."""
    session_id: str
    prompt: Sequence[int]            # (S,) token ids
    max_new_tokens: int
    arrival_s: float = 0.0           # virtual arrival (0 = immediate)
    priority: int = 0                # preemption priority (higher wins)
    klass: str = ""                  # session-class label (SLO grouping)


@dataclasses.dataclass
class SessionResult:
    session_id: str
    tokens: np.ndarray               # (max_new_tokens,) generated ids
    slot: int                        # slot the session was served in
    admitted_tick: int
    finished_tick: int
    step_times_s: List[float]        # shared-batch decode-step walls
    klass: str = ""                  # session-class label (from request)
    priority: int = 0
    arrival_s: float = 0.0           # virtual arrival on the run clock
    token_times_s: np.ndarray = dataclasses.field(
        default_factory=lambda: np.zeros(0))
    # virtual emission timestamp per generated token (same clock as
    # ``arrival_s``) — queueing, prefill, preemption stalls and macro-
    # tick position all included, so diffs are the per-token latency
    # the session FELT, not the shared-batch service wall
    ttft_s: Optional[float] = None   # token_times_s[0] - arrival_s
    ttft_wall_s: Optional[float] = None
    # wall-clock TTFT (queue release -> first token); None when the
    # scheduler ran timed=False — never NaN, so JSON stays clean

    def token_latencies_s(self) -> np.ndarray:
        """Virtual inter-token latencies (the TPOT stream): gaps
        between consecutive emission stamps.  Empty for 1-token
        sessions."""
        return np.diff(self.token_times_s)


@dataclasses.dataclass
class ContinuousResult:
    """Outcome of one ``SlotScheduler.run()`` call.

    ``run()`` may be called repeatedly on one scheduler (submit → run →
    submit → run); every field belongs to exactly one of two groups,
    and which group is part of its contract:

    **Cumulative** over the scheduler's lifetime (all ``run()`` calls so
    far): ``sessions``, ``events``, ``decode_steps``.
    ``step_cache_size``, ``launches_per_step``, and ``steps_per_tick``
    describe the compiled program / configuration, not a count.

    **This ``run()`` call only** (delta since the call started):
    ``ticks``, ``wall_s``, ``tokens_per_s``, ``preemptions``,
    ``dispatches``, ``run_tokens``, ``step_kv_blocks``,
    ``host_dispatch_s``, ``host_sync_s``, ``prefill_tokens``,
    ``prefix_hits``, ``prefix_tokens_saved``, ``cow_copies``,
    ``arrivals``, ``horizon_hist``.
    (``dispatches`` is the per-run delta of the cumulative
    ``decode_steps``.)

    ``now_s`` is the scheduler's virtual clock at the end of the call —
    monotone across calls (a clock, not a counter); per-run virtual
    makespan is the difference of consecutive ``now_s`` readings."""
    sessions: Dict[str, SessionResult]  # cumulative: every finished session
    ticks: int                       # scheduler iterations this run()
    decode_steps: int                # batched decode dispatches (cumulative)
    wall_s: float
    tokens_per_s: float              # aggregate generated tokens / wall
    step_cache_size: Optional[int]   # compiled decode-step count (full_jit)
    launches_per_step: int           # host dispatches per decode step
    events: List[Event]              # cumulative event log
    preemptions: int = 0             # paged: sessions requeued for pages
    step_kv_blocks: Optional[List[int]] = None
    # paged: per decode step, summed ceil(live_len/page_size) over the
    # active lanes — the pages the fused kernel actually walks.  None
    # for contiguous runs.
    steps_per_tick: int = 1          # horizon K of the fused macro-tick
    dispatches: int = 0              # decode dispatches this run() call
    run_tokens: int = 0              # tokens generated this run() call
    host_dispatch_s: float = 0.0     # host wall building + dispatching
                                     # decode work (the launch term the
                                     # horizon amortises)
    host_sync_s: float = 0.0         # host wall blocked on the per-tick
                                     # token transfer
    prefill_tokens: int = 0          # tokens actually dispatched through
                                     # prefill programs this run()
    prefix_hits: int = 0             # admissions that matched a cached
                                     # prefix (prefix sharing; resumed
                                     # re-admissions count too, so this
                                     # may exceed the session count)
    prefix_tokens_saved: int = 0     # sequence tokens (prompt, plus the
                                     # generated prefix on resume) whose
                                     # prefill was skipped via shared
                                     # pages
    cow_copies: int = 0              # copy-on-write page faults served
    now_s: float = 0.0               # virtual clock at the end of the
                                     # call (monotone across calls)
    arrivals: int = 0                # trace requests released from the
                                     # arrival queue this run()
    adaptive_k: bool = False         # horizon chosen per tick (config)
    horizon_hist: Dict[int, int] = dataclasses.field(default_factory=dict)
    # macro-ticks dispatched per horizon K this run() — the adaptive
    # policy's visible footprint ({} for single-step runs)

    def tokens_for(self, session_id: str) -> np.ndarray:
        return self.sessions[session_id].tokens


@dataclasses.dataclass
class _Session:
    request: SessionRequest
    tokens: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    admitted_tick: int = -1
    finished_tick: int = -1
    step_times_s: List[float] = dataclasses.field(default_factory=list)
    # ---- paged bookkeeping ----
    pages: List[int] = dataclasses.field(default_factory=list)
    pos: int = 0                     # host mirror of cache["pos"][slot]
    prefilled: int = 0               # prefill_seq tokens written so far
    prefill_seq: Optional[np.ndarray] = None   # sequence being prefilled
    seq_cache: Optional[np.ndarray] = None     # memoised admission seq
                                     # (valid while waiting: tokens only
                                     # grow while resident in a slot)
    resume: bool = False             # re-admission after preemption
    admit_seq: int = -1              # monotone admission order (preempt prio)
    arrival_s: float = 0.0           # virtual arrival on the run clock
    release_wall: Optional[float] = None   # perf_counter at queue entry
    token_times_s: List[float] = dataclasses.field(default_factory=list)
    first_token_wall: Optional[float] = None

    @property
    def priority(self) -> int:
        return self.request.priority

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.request.max_new_tokens

    @property
    def decoding(self) -> bool:
        """Prefill complete: the session takes part in decode steps."""
        return (self.prefill_seq is not None
                and self.prefilled >= len(self.prefill_seq))

    @property
    def next_input_token(self) -> int:
        """Token the next decode step feeds this lane.  Normally the
        last generated token; a fully-prefix-matched fresh admission has
        generated nothing yet and replays the last prompt token (its KV
        row is rewritten in place — into the CoW private copy — and the
        step's logits stand in for the skipped prefill's)."""
        return (self.tokens[-1] if self.tokens
                else int(self.prefill_seq[-1]))


class SlotScheduler:
    """Admission / decode / eviction / backfill over a slotted cache."""

    def __init__(self, model: Model, params, *, n_slots: int, max_len: int,
                 dispatch_mode: str = "full_jit", temperature: float = 0.0,
                 top_k: int = 0, seed: int = 0, kv_dtype=None,
                 max_ticks: Optional[int] = None, paged: bool = False,
                 page_size: int = 16, n_pages: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 steps_per_tick: int = 1, eos_id: Optional[int] = None,
                 timed: bool = True, prefix_cache: bool = False,
                 adaptive_k: bool = False, min_steps_per_tick: int = 1,
                 priority_preemption: bool = True,
                 virtual_step_s: float = 1e-3,
                 virtual_dispatch_s: float = 4e-3,
                 shared_programs: bool = False):
        assert n_slots >= 1
        assert dispatch_mode in MODES, dispatch_mode
        assert steps_per_tick >= 1
        assert 1 <= min_steps_per_tick <= steps_per_tick
        if adaptive_k and steps_per_tick < 2:
            raise NotImplementedError(
                "adaptive_k picks horizons from a ladder below "
                "steps_per_tick; a ceiling of 1 leaves nothing to adapt")
        cfg = model.cfg
        if cfg.n_codebooks:
            raise NotImplementedError(
                "continuous batching serves single-codebook archs")
        if steps_per_tick > 1 and dispatch_mode != "full_jit":
            raise NotImplementedError(
                "horizon-K fused ticks ARE the one-program arm; the "
                "stage/eager dispatch A/B only decomposes single steps")
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.dispatch_mode = dispatch_mode
        self.temperature = temperature
        self.top_k = top_k
        self.key = jax.random.PRNGKey(seed)
        self.max_ticks = max_ticks
        self.steps_per_tick = steps_per_tick
        self.eos_id = eos_id
        self.timed = timed
        self.host_dispatch_s = 0.0
        self.host_sync_s = 0.0
        self.adaptive_k = adaptive_k
        self.min_steps_per_tick = min_steps_per_tick
        self.priority_preemption = priority_preemption
        # the horizon ladder: halvings of the ceiling down to the floor.
        # Each value compiles its own (backend, K) executable exactly
        # once, so the compiled-program count is bounded by the ladder
        # length (~log2), not by anything traffic-dependent.
        ladder = set()
        k = steps_per_tick
        while k > min_steps_per_tick:
            ladder.add(k)
            k //= 2
        ladder.add(min_steps_per_tick)
        self.k_ladder: Tuple[int, ...] = tuple(sorted(ladder))
        # virtual clock + cost model (trace replay / SLO metrics): every
        # dispatched program costs a launch tax, every device decode
        # step a service quantum.  Pure host arithmetic — zero overhead
        # on the hot path, fully deterministic.
        self.virtual_step_s = virtual_step_s
        self.virtual_dispatch_s = virtual_dispatch_s
        self.now_s = 0.0
        self._pending: List[Tuple[float, int, _Session]] = []
        self._arrivals: List[Tuple[float, int, _Session]] = []
        self._arrival_seq = 0
        self.arrivals_released = 0
        self.horizon_hist: collections.Counter = collections.Counter()

        self.paged = paged
        if prefix_cache and not paged:
            raise NotImplementedError(
                "prefix sharing rides the paged block table; contiguous "
                "slots have no page indirection to share through")
        if paged:
            if dispatch_mode != "full_jit":
                raise NotImplementedError(
                    "paged serving runs the full_jit arm only (the "
                    "stage/eager A/B targets the contiguous layout)")
            if prefill_chunk is not None:
                assert prefill_chunk >= page_size and \
                    prefill_chunk % page_size == 0, (
                        "prefill_chunk must be a positive multiple of "
                        "page_size so chunk boundaries stay page-aligned")
            self.page_size = page_size
            self.max_blocks = -(-max_len // page_size)
            if n_pages is None:
                n_pages = 1 + n_slots * self.max_blocks   # full backing
            self.n_pages = n_pages
            self.prefill_chunk = prefill_chunk
            self.allocator = BlockAllocator(n_pages)
            self.prefix = PrefixCache(self.allocator) if prefix_cache \
                else None
            self.preemptions = 0
            self.step_kv_blocks: List[int] = []
            self._bt = np.zeros((n_slots, self.max_blocks), np.int32)
            self._bt_dirty = True
            self._pos = np.zeros((n_slots,), np.int32)
            self._pos_dirty = True
            self.cache = model.init_cache(
                n_slots, max_len, kv_dtype=kv_dtype, paged=True,
                page_size=page_size, n_pages=n_pages)
        else:
            self.preemptions = 0
            self.prefix = None
            self.cache = model.init_cache(n_slots, max_len,
                                          kv_dtype=kv_dtype, slotted=True)
        self.slots: List[Optional[_Session]] = [None] * n_slots
        self.waiting: Deque[_Session] = collections.deque()
        self.finished: List[_Session] = []
        self.events: List[Event] = []
        self.tick_count = 0
        self.decode_steps = 0
        self.prefill_tokens = 0     # tokens dispatched through prefill
        self.prefix_hits = 0        # admissions matching a cached prefix
        self.prefix_tokens_saved = 0
        self.cow_copies = 0
        self._admit_count = 0       # sampling-salt counter (even salts)
        self._admission_order = 0   # monotone admission id (preempt prio)

        # shared_programs: A/B drivers that build many schedulers over
        # ONE model (e.g. table13's arm sweep) pay a full recompile per
        # instance, because each jax.jit wrapper carries its own trace
        # cache.  Opting in parks the wrappers on the model so every
        # scheduler over it reuses the same compiled executables —
        # donation is per call, so sharing the callable is safe.
        # step_cache_size() then reports the delta since construction,
        # keeping the "one executable per (backend, K)" accounting
        # per scheduler.
        if shared_programs:
            _shared = model.__dict__.setdefault("_shared_sched_jits", {})

            def _jit(name, make):
                if name not in _shared:
                    _shared[name] = make()
                return _shared[name]
        else:
            def _jit(name, make):
                return make()

        if paged:
            self._prefill_chunk_jit = _jit(
                "prefill_chunk",
                lambda: jax.jit(model.prefill_chunk_into_slot,
                                donate_argnums=(2,)))
            self._copy_page_jit = _jit(
                "copy_page",
                lambda: jax.jit(model.copy_kv_page, donate_argnums=(0,)))
        else:
            self._prefill_slot = _jit(
                "prefill_slot",
                lambda: jax.jit(model.prefill_into_slot,
                                donate_argnums=(2,)))
        if dispatch_mode == "full_jit":
            # the production hot path: the whole step is one program,
            # cache donated so steps run allocation-free.  With
            # steps_per_tick > 1 the program is the horizon-K multi-step
            # scan (decode_steps) — ONE executable per (backend, K),
            # dispatched once per macro-tick; lanes that finish
            # mid-horizon are masked off on device (steps_left/EOS), so
            # partial horizons never need a second program.
            self._step_jit = None
            self._steps_jit = None
            if steps_per_tick > 1:
                self._steps_jit = _jit(
                    "decode_steps",
                    lambda: jax.jit(
                        model.decode_steps,
                        static_argnames=("horizon", "temperature",
                                         "top_k", "eos_id"),
                        donate_argnums=(1,)))
            else:
                self._step_jit = _jit(
                    "decode_step",
                    lambda: jax.jit(model.decode_step,
                                    donate_argnums=(1,)))
            self._program = None
        else:
            # dispatch A/B hooks: same math through the eager/stage_jit
            # executors of the StepProgram decomposition
            self._step_jit = None
            self._steps_jit = None
            self._program = model.step_program(params, self.cache)
            self._executor = self._program.executor(dispatch_mode)
        # shared wrappers can arrive pre-warmed by an earlier scheduler
        # over the same model; compile counts are reported relative to
        # this instance's start so the recompile guard stays meaningful
        self._step_cache_base = self._raw_step_cache_size() or 0

    # ------------------------------------------------------------- intro
    @property
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    @property
    def active_sessions(self) -> List[str]:
        return [s.request.session_id for s in self.slots if s is not None]

    @property
    def free_pages(self) -> Optional[int]:
        return self.allocator.n_free if self.paged else None

    @property
    def cached_pages(self) -> Optional[int]:
        """Pages currently held by the prefix cache (None when prefix
        sharing is off)."""
        return len(self.prefix) if self.prefix is not None else None

    def flush_prefix_cache(self) -> int:
        """Drop every unreferenced cached prefix page back to the free
        list (end-of-run accounting; under allocation pressure the LRU
        reclaim does this incrementally on its own)."""
        return self.prefix.flush() if self.prefix is not None else 0

    def _raw_step_cache_size(self) -> Optional[int]:
        if self._steps_jit is not None:
            return jit_cache_size(self._steps_jit)
        if self._step_jit is not None:
            return jit_cache_size(self._step_jit)
        return None

    def step_cache_size(self) -> Optional[int]:
        """Number of decode-step executables compiled SINCE THIS
        SCHEDULER was built (the recompile guard: must be 1 after any
        amount of session churn — for ``steps_per_tick > 1`` that is
        the ONE horizon-K multi-step program, reused across
        macro-ticks).  With ``shared_programs`` the underlying cache is
        shared across schedulers, so the count is a delta against the
        size at construction.  ``None`` when unknown (staged/eager
        executors, or a jax version that dropped the private cache-size
        hook — see ``jit_cache_size``)."""
        raw = self._raw_step_cache_size()
        if raw is None:
            return None
        return raw - self._step_cache_base

    @property
    def launches_per_step(self) -> int:
        if self._program is not None:
            return launch_count(self._program, self.dispatch_mode)
        return 1  # full_jit

    # ------------------------------------------------------------- queue
    def submit(self, request: SessionRequest) -> None:
        prompt = np.asarray(request.prompt, np.int32).reshape(-1)
        assert prompt.size >= 1, "empty prompt"
        assert request.max_new_tokens >= 1
        # last decode write lands at S + max_new - 2; keep it in-cache
        assert prompt.size + request.max_new_tokens - 1 <= self.max_len, (
            f"session {request.session_id}: prompt {prompt.size} + "
            f"{request.max_new_tokens} new tokens exceeds max_len "
            f"{self.max_len}")
        if self.paged:
            need = self._pages_for(prompt.size + request.max_new_tokens - 1)
            assert need <= self.n_pages - 1, (
                f"session {request.session_id} needs {need} pages but the "
                f"pool only holds {self.n_pages - 1}")
        req = dataclasses.replace(request, prompt=prompt)
        sess = _Session(req)
        if req.arrival_s > 0.0:
            # trace replay: the request enters the FIFO queue only once
            # the virtual clock reaches its arrival.  Arrival times are
            # relative to the run() that serves them — they are rebased
            # onto the absolute clock at release time (_release_arrivals
            # anchors the batch to now_s when it first sees it), so a
            # scheduler that already served earlier waves replays a new
            # trace correctly.
            self._pending.append((float(req.arrival_s),
                                  self._arrival_seq, sess))
            self._arrival_seq += 1
        else:
            sess.arrival_s = self.now_s
            sess.release_wall = time.perf_counter() if self.timed else None
            self.waiting.append(sess)

    # ----------------------------------------------------------- serving
    def _sample(self, logits: jnp.ndarray, salt: int) -> jnp.ndarray:
        key = jax.random.fold_in(self.key, salt)
        return sample(logits, key, temperature=self.temperature,
                      top_k=self.top_k)

    def _hit_eos(self, tok: int) -> bool:
        return self.eos_id is not None and tok == self.eos_id

    # ------------------------------------------------- trace replay clock
    def _release_arrivals(self) -> None:
        """Move trace requests whose virtual arrival has come into the
        FIFO queue.  Newly submitted arrival batches are anchored to the
        clock as it stood when the batch is first seen; when the whole
        system is idle the clock fast-forwards to the next arrival (an
        empty server does not spin through dead air)."""
        if self._pending:
            base = self.now_s
            for rel, seq, sess in self._pending:
                sess.arrival_s = base + rel
                heapq.heappush(self._arrivals, (base + rel, seq, sess))
            self._pending.clear()
        if self._arrivals and not self.waiting \
                and all(s is None for s in self.slots):
            self.now_s = max(self.now_s, self._arrivals[0][0])
        while self._arrivals and self._arrivals[0][0] <= self.now_s:
            _, _, sess = heapq.heappop(self._arrivals)
            sess.release_wall = time.perf_counter() if self.timed else None
            self.waiting.append(sess)
            self.arrivals_released += 1

    def _charge(self, steps: int, dispatches: int = 1) -> None:
        """Advance the virtual clock: ``dispatches`` launch taxes plus
        ``steps`` device service quanta."""
        self.now_s += (dispatches * self.virtual_dispatch_s
                       + steps * self.virtual_step_s)

    def _stamp(self, sess: _Session, vt: Optional[float] = None) -> None:
        """Record the emission time of the token just appended to
        ``sess.tokens``: virtual always, wall only when timed."""
        sess.token_times_s.append(self.now_s if vt is None else vt)
        if self.timed and sess.first_token_wall is None \
                and len(sess.tokens) == 1:
            sess.first_token_wall = time.perf_counter()

    def _finish(self, slot: int, sess: _Session) -> None:
        sess.finished_tick = self.tick_count
        self.slots[slot] = None
        self.finished.append(sess)
        if self.paged:
            self._release_slot(slot, sess)
        self.events.append(("finish", sess.request.session_id, slot))

    # ------------------------------------------------------ paged plumbing
    def _pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def _alloc_pages(self, n: int) -> Optional[List[int]]:
        """``allocator.alloc`` with prefix-cache pressure relief: when
        the free list is short, unreferenced cached prefix pages are
        reclaimed LRU-first to cover the shortfall.  Cached pages are a
        soft reserve — they never deny a MANDATORY allocation the bare
        pool could have served.  (Optional horizon lookahead stays
        free-list-only by design: speculative pages are worth less than
        cached prefills, so a warm cache shrinks the lookahead grant
        rather than the other way round.)"""
        got = self.allocator.alloc(n)
        if got is None and self.prefix is not None:
            self.prefix.reclaim(n - self.allocator.n_free)
            got = self.allocator.alloc(n)
        return got

    def _can_cover(self, need: int, exclude: Sequence[int] = ()) -> bool:
        """Could ``need`` pages be obtained without preempting anyone —
        free list first, cache reclaim cascade as the fallback
        (``exclude``: matched pages an admission in flight is about to
        retain, which must count as pinned)?  The cache walk only runs
        when the free list alone is short."""
        if self.allocator.n_free >= need:
            return True
        if self.prefix is None:
            return False
        return (self.allocator.n_free
                + self.prefix.reclaimable(exclude)) >= need

    def _match_prefix(self, seq: np.ndarray) -> List[int]:
        """Pages backing the longest cached page-aligned prefix of the
        session's prefill sequence ([] when sharing is off)."""
        if self.prefix is None:
            return []
        return self.prefix.match(seq, self.page_size)

    def _register_prefix(self, sess: _Session) -> None:
        """Index the session's fully-prefilled pages so later admissions
        can share them.  Only full pages enter the index, and only after
        their prefill chunk completed — a page mid-prefill has no final
        content to share."""
        if self.prefix is None:
            return
        n_blocks = sess.prefilled // self.page_size
        if n_blocks:
            self.prefix.register(sess.prefill_seq, self.page_size,
                                 sess.pages, n_blocks)

    def _release_slot(self, slot: int, sess: _Session) -> None:
        """Reclaim a session's pages and park the lane on the sentinel."""
        self.allocator.release(sess.pages)
        sess.pages = []
        self._bt[slot, :] = GARBAGE_PAGE
        self._bt_dirty = True
        self._pos[slot] = 0
        self._pos_dirty = True

    def _sync_device(self, pos_always: bool = True) -> None:
        """Push the host-authoritative block table + positions into the
        cache pytree (pure data: never changes compiled shapes).  The
        block table only uploads when admission/eviction/allocation
        dirtied it, keeping steady-state decode free of the extra H2D
        transfer.

        ``pos_always=True`` (the single-step path) re-syncs positions
        every tick: the K=1 decode step advances every lane's device
        pos, including masked ones.  The horizon-K path passes False —
        its device steps clamp inactive lanes' positions, so device pos
        stays correct end-to-end and only host-side resets (slot
        release) need an upload."""
        if self._bt_dirty:
            self.cache["block_table"] = jnp.asarray(self._bt)
            self._bt_dirty = False
        if pos_always or self._pos_dirty:
            self.cache["pos"] = jnp.asarray(self._pos)
            self._pos_dirty = False

    def _preempt(self, slot: int, sess: _Session) -> None:
        """Requeue a session to reclaim its pages.  It keeps its
        generated tokens and is later re-prefilled from prompt +
        generated prefix, so its stream is unchanged — preemption costs
        recompute, never correctness."""
        self._release_slot(slot, sess)
        self.slots[slot] = None
        sess.slot = -1
        sess.prefilled = 0
        sess.prefill_seq = None
        sess.resume = True
        self.preemptions += 1
        self.events.append(("preempt", sess.request.session_id, slot))
        self.waiting.appendleft(sess)   # it was admitted before the waiters

    def _alloc_or_preempt(self, n: int, needy: _Session) -> Optional[List[int]]:
        """Allocate ``n`` pages, preempting one resident victim at a
        time until it fits.  Returns None if it still can't fit with
        only the needy session (and its non-victims) resident.

        Victim policy: with ``priority_preemption`` (the default) a
        session is eligible if it is STRICTLY lower priority than the
        needy one, or of equal priority but strictly younger (later
        ``admit_seq``) — a higher-priority session is never evicted for
        a lower-priority page fault.  Among eligibles the
        lowest-priority-youngest goes first.  With
        ``priority_preemption=False`` priorities are ignored and the
        rule degrades to the original youngest-first baseline — the
        FIFO arm of the SLO A/B (benchmarks/table13)."""
        while True:
            got = self._alloc_pages(n)
            if got is not None:
                return got
            if self.priority_preemption:
                victims = [((-s.priority, s.admit_seq), i, s)
                           for i, s in enumerate(self.slots)
                           if s is not None and s is not needy
                           and (s.priority < needy.priority
                                or (s.priority == needy.priority
                                    and s.admit_seq > needy.admit_seq))]
            else:
                victims = [((0, s.admit_seq), i, s)
                           for i, s in enumerate(self.slots)
                           if s is not None and s is not needy
                           and s.admit_seq > needy.admit_seq]
            if not victims:
                return None
            _, vslot, vsess = max(victims)
            self._preempt(vslot, vsess)

    def _next_chunk_len(self, sess: _Session) -> int:
        remaining = len(sess.prefill_seq) - sess.prefilled
        if self.prefill_chunk is None:
            return remaining
        return min(self.prefill_chunk, remaining)

    def _prefill_next_chunk(self, slot: int, sess: _Session) -> bool:
        """Run ONE prefill chunk for the session in ``slot`` (allocate
        its pages first).  Returns False if pages are short even after
        preempting younger sessions — the chunk retries next tick."""
        start = sess.prefilled
        C = self._next_chunk_len(sess)
        need = self._pages_for(start + C) - len(sess.pages)
        if need > 0:
            got = self._alloc_or_preempt(need, sess)
            if got is None:
                return False
            base = len(sess.pages)
            sess.pages.extend(got)
            self._bt[slot, base:base + need] = got
            self._bt_dirty = True
        self._sync_device()
        chunk = jnp.asarray(sess.prefill_seq[start:start + C])[None, :]
        logits, self.cache = self._prefill_chunk_jit(
            self.params, {"tokens": chunk}, self.cache, jnp.int32(slot),
            jnp.int32(start))
        sess.prefilled = start + C
        sess.pos = sess.prefilled
        self._pos[slot] = sess.prefilled
        self.prefill_tokens += C
        self._charge(1)          # one prefill program: launch + a quantum
        self._register_prefix(sess)
        if sess.decoding:
            # prefill complete: sample the first token — unless resuming
            # after preemption, where the last generated token is still
            # waiting to be fed through the next decode step
            if sess.resume and sess.tokens:
                sess.resume = False
            else:
                sess.resume = False
                salt = 2 * self._admit_count
                self._admit_count += 1
                tok = int(self._sample(logits[:, -1], salt)[0])
                sess.tokens.append(tok)
                self._stamp(sess)
                self.events.append(
                    ("token", sess.request.session_id, slot, tok))
                if sess.done or self._hit_eos(tok):
                    self._finish(slot, sess)
        return True

    @staticmethod
    def _prefill_seq_for(sess: _Session) -> np.ndarray:
        """The token sequence admission must make resident: the prompt,
        plus the generated prefix when resuming after preemption (all
        but the last generated token — that one is re-fed through the
        next decode step).  Memoised on the session: a gate-blocked
        queue head is re-examined every tick, and its sequence is
        frozen while it waits (tokens only grow while resident)."""
        if sess.seq_cache is None:
            sess.seq_cache = (
                np.concatenate([sess.request.prompt,
                                np.asarray(sess.tokens[:-1], np.int32)])
                if sess.resume and sess.tokens else
                np.asarray(sess.request.prompt, np.int32))
        return sess.seq_cache

    def _admit_paged(self, slot: int, sess: _Session, seq: np.ndarray,
                     shared: List[int]) -> None:
        """Install a session in ``slot``; with prefix sharing, point the
        block table at the ``shared`` pages (retaining them) so only the
        tail past the match is ever prefilled.

        When the match covers the WHOLE sequence there is nothing left
        to prefill.  A resumed session needs no logits either (its next
        input token is already known) and starts decoding at once; a
        fresh session still owes its first sample, so it *replays* the
        last prompt token through the decode path — and because that
        step's KV write lands at position ``len(seq) - 1``, inside the
        last shared page, that page is CoW-faulted into a private copy
        (host-side page copy, before any dispatch) so shared pages are
        never written."""
        sess.prefill_seq = seq
        sess.seq_cache = None        # tokens grow while resident
        sess.prefilled = 0
        sess.pages = []
        sess.slot = slot
        sess.admitted_tick = self.tick_count
        sess.admit_seq = self._admission_order
        self._admission_order += 1
        self.slots[slot] = sess
        self._bt[slot, :] = GARBAGE_PAGE
        self._bt_dirty = True
        self._pos[slot] = 0
        self.events.append(("admit", sess.request.session_id, slot))
        if not shared:
            return
        k = len(shared)
        matched = k * self.page_size
        self.prefix_hits += 1
        if matched < len(seq):
            # tail remains: share the matched run, prefill only the tail
            # (which writes fresh private pages — no CoW needed)
            self.allocator.retain(shared)
            sess.pages = list(shared)
            self._bt[slot, :k] = shared
            sess.prefilled = matched
            sess.pos = matched
            self._pos[slot] = matched
            self.prefix_tokens_saved += matched
        elif sess.resume and sess.tokens:
            # fully cached resume: nothing to prefill, nothing to sample
            self.allocator.retain(shared)
            sess.pages = list(shared)
            self._bt[slot, :k] = shared
            sess.prefilled = len(seq)
            sess.pos = len(seq)
            self._pos[slot] = len(seq)
            sess.resume = False
            self.prefix_tokens_saved += len(seq)
        else:
            # fully cached fresh prompt: CoW-fault the last shared page
            # (the replayed token's write target), then replay the last
            # prompt token through decode for the first sample.  Retain
            # BEFORE allocating: the copy's allocation may reclaim
            # cached pages, and the retained ones must be pinned.  (The
            # reclaim may legally steal the unretained source page
            # itself — the copy then degrades to an in-place no-op and
            # the page simply changes owner, content already correct.)
            self.allocator.retain(shared[:-1])
            got = self._alloc_pages(1)
            assert got is not None, "admission gate covered the CoW page"
            sess.pages = list(shared[:-1]) + got
            self._bt[slot, :k - 1] = shared[:-1]
            self._bt[slot, k - 1] = got[0]
            self.cache = self._copy_page_jit(
                self.cache, jnp.int32(shared[-1]), jnp.int32(got[0]))
            self.cow_copies += 1
            self._charge(0)      # the CoW copy is one dispatched program
            sess.prefilled = len(seq)
            sess.pos = len(seq) - 1
            self._pos[slot] = len(seq) - 1
            self.prefix_tokens_saved += len(seq)
        self._pos_dirty = True
        self._bt_dirty = True

    def _backfill_paged(self) -> None:
        """FIFO admission gated on free pages: the queue head is
        admitted only when its first chunk's pages are available
        (head-of-line blocking is deliberate — skipping ahead would
        starve long prompts).  With prefix sharing the gate charges only
        the UNMATCHED pages (shared pages are already resident) and may
        count reclaimable cached pages as free — excluding the matched
        run itself, which the admission is about to pin."""
        for slot in range(self.n_slots):
            while self.slots[slot] is None and self.waiting:
                head = self.waiting[0]
                seq = self._prefill_seq_for(head)
                shared = self._match_prefix(seq)
                while True:
                    matched = len(shared) * self.page_size
                    if shared and matched >= len(seq):
                        # fully cached: a fresh admission needs 1 page
                        # (the CoW copy) and pins only shared[:-1] — the
                        # last matched page is a legal reclaim target
                        # (it may even BE the copy, already holding the
                        # right content); a resume pins the whole match
                        # and needs 1 so its first decode write can't
                        # instantly wedge
                        resume = head.resume and head.tokens
                        pinned = shared if resume else shared[:-1]
                        need = 1
                    else:
                        pinned = shared
                        tail = len(seq) - matched
                        first = (tail if self.prefill_chunk is None
                                 else min(self.prefill_chunk, tail))
                        need = (self._pages_for(matched + first)
                                - len(shared))
                    if self._can_cover(need, pinned):
                        break
                    if not shared:
                        return      # gate: wait for reclaim
                    # pool can't cover the admission with the full match
                    # pinned: shrink the match — its dropped tail pages
                    # become reclaimable fuel for this very admission
                    # (degrades to the unshared gate, which keeps the
                    # no-cache liveness property)
                    shared = shared[:-1]
                self._admit_paged(slot, self.waiting.popleft(), seq,
                                  shared)
                sess = self.slots[slot]
                if not sess.decoding:
                    ok = self._prefill_next_chunk(slot, sess)
                    assert ok, "gated admission must have its first chunk"
                if self.slots[slot] is not None and \
                        not self.slots[slot].decoding:
                    break           # chunked prefill continues next ticks

    # -------------------------------------------------------- contiguous
    def _backfill(self) -> None:
        """FIFO admission into free slots; prefill-into-slot per session."""
        if self.paged:
            self._backfill_paged()
            return
        for slot in range(self.n_slots):
            while self.slots[slot] is None and self.waiting:
                sess = self.waiting.popleft()
                prompt = jnp.asarray(sess.request.prompt)[None, :]
                logits, self.cache = self._prefill_slot(
                    self.params, {"tokens": prompt}, self.cache,
                    jnp.int32(slot))
                sess.slot = slot
                sess.admitted_tick = self.tick_count
                self.slots[slot] = sess
                self.prefill_tokens += int(prompt.shape[1])
                self._charge(1)
                sid = sess.request.session_id
                self.events.append(("admit", sid, slot))
                # even salts for admissions (one per admission, counted
                # monotonically), odd for decode steps — never collide
                salt = 2 * self._admit_count
                self._admit_count += 1
                tok = int(self._sample(logits[:, -1], salt)[0])
                sess.tokens.append(tok)
                self._stamp(sess)
                self.events.append(("token", sid, slot, tok))
                if sess.done or self._hit_eos(tok):
                    # 1-token / instant-EOS session: retire immediately,
                    self._finish(slot, sess)   # loop backfills the slot
        occupied = [s for s in self.slots if s is not None]
        assert len(set(map(id, occupied))) == len(occupied), \
            "slot double-assignment"
        assert all(s is None or s.slot == i
                   for i, s in enumerate(self.slots)), "slot bookkeeping"

    def _run_step(self, tokens: jnp.ndarray):
        if self._step_jit is not None:
            return self._step_jit(self.params, self.cache, tokens)
        state = self._executor({"tokens": tokens, "cache": self.cache})
        return state["logits"], state["cache"]

    def _ensure_decode_page(self, slot: int, sess: _Session) -> bool:
        """Guarantee the page under ``sess.pos`` (this tick's KV write)
        exists, preempting younger sessions if the pool is dry.  If even
        that fails, the needy session itself is preempted (an older
        session holds the pool — it will finish and reclaim)."""
        blk = sess.pos // self.page_size
        if blk < len(sess.pages):
            return True
        assert blk == len(sess.pages), "page allocation skipped a block"
        got = self._alloc_or_preempt(1, sess)
        if got is None:
            self._preempt(slot, sess)
            return False
        self._bt[slot, blk] = got[0]
        self._bt_dirty = True
        sess.pages.extend(got)
        return True

    def _reserve_horizon(self, slot: int, sess: _Session, want: int) -> int:
        """Pre-reserve lookahead pages so the session can take ``want``
        decode steps inside one fused macro-tick (its last KV write
        lands at ``pos + want - 1``).  Lookahead beyond the next step is
        *optional*: it is taken from the free list only, and when the
        pool is short the grant shrinks to what the session's held pages
        cover — never evicting anyone for speculative pages.  Only the
        MANDATORY next page (the K=1 requirement) preempts
        strictly-younger sessions, exactly like ``_ensure_decode_page``.
        Returns the steps granted; 0 means the session itself was
        preempted (the same failure path as K=1)."""
        def take(n_pages: int) -> bool:
            """Free-list-only allocation of ``n_pages`` pages: optional
            lookahead never evicts a session AND never drains the
            prefix cache — speculative pages are not allocation
            pressure (the mandatory-page path below does apply it)."""
            got = self.allocator.alloc(n_pages)
            if got is None:
                return False
            base = len(sess.pages)
            sess.pages.extend(got)
            self._bt[slot, base:base + n_pages] = got
            self._bt_dirty = True
            return True

        def top_up(n_steps: int) -> bool:
            need = self._pages_for(sess.pos + n_steps) - len(sess.pages)
            return need <= 0 or take(need)

        if top_up(want):
            return want
        # pool short of the full horizon: take the partial lookahead the
        # free list can spare — but leave one page per OTHER live
        # decoding slot, so optional lookahead never forces a later
        # slot's mandatory-page allocation into preempting someone
        others = sum(1 for i, s in enumerate(self.slots)
                     if s is not None and s is not sess and s.decoding)
        spare = self.allocator.n_free - others
        need = self._pages_for(sess.pos + want) - len(sess.pages)
        if 0 < spare < need:
            take(spare)
        have = len(sess.pages) * self.page_size - sess.pos
        if have >= 1:
            return min(want, have)       # shrink: lookahead is optional
        # pool dry at a page boundary: the next page is mandatory —
        # preempt younger sessions (or the needy itself) like K=1 does
        got = self._alloc_or_preempt(1, sess)
        if got is None:
            self._preempt(slot, sess)
            return 0
        blk = len(sess.pages)
        self._bt[slot, blk] = got[0]
        self._bt_dirty = True
        sess.pages.extend(got)
        if top_up(want):                 # eviction may have freed plenty
            return want
        return min(want, len(sess.pages) * self.page_size - sess.pos)

    def tick(self) -> List[Event]:
        """One scheduler iteration: continue chunked prefills, backfill,
        one batched decode dispatch for every decoding slot (a single
        step, or a horizon-K fused macro-tick advancing every live slot
        up to ``steps_per_tick`` tokens in ONE program), evict completed
        sessions."""
        n_before = len(self.events)
        self._release_arrivals()
        if self.paged:
            for slot, sess in enumerate(self.slots):
                if sess is not None and not sess.decoding:
                    self._prefill_next_chunk(slot, sess)
        self._backfill()
        if self.steps_per_tick == 1:
            self._decode_tick_single()
        else:
            self._decode_tick_horizon(self._tick_horizon())
        self.tick_count += 1
        return self.events[n_before:]

    def _tick_horizon(self) -> int:
        """Horizon K for this macro-tick.  Fixed-K schedulers always use
        the configured ceiling; the adaptive policy ends macro-ticks at
        the next *scheduling event* instead of a fixed stride:

          * **demand against full slots** — someone is waiting (or due
            to arrive) and every slot is busy: cap at the shortest
            remaining budget among residents, so the tick ends exactly
            when the first slot frees and the backfill happens
            immediately (a longer tick would burn that slot on masked
            no-op lanes while the waiter keeps paying TTFT);
          * **arrival against a free slot** — never run a macro-tick so
            long that an arrival which could be admitted on the spot
            would sit out most of it (with full slots the arrival can
            only join the queue, so ending the tick for it buys nothing
            and costs a launch tax);
          * **otherwise grow** — nobody waiting and no arrival due: take
            the largest rung no bigger than the longest remaining
            budget (the launch tax amortises across the whole horizon).

        Only ladder rungs are ever dispatched, so the compiled-program
        count stays bounded by the ladder length."""
        if not self.adaptive_k:
            return self.steps_per_tick
        k = self.steps_per_tick
        remaining = [s.request.max_new_tokens - len(s.tokens)
                     for s in self.slots
                     if s is not None and (not self.paged or s.decoding)]
        slots_full = all(s is not None for s in self.slots)
        if remaining:
            demand = bool(self.waiting) or bool(self._arrivals)
            k = min(k, min(remaining) if demand and slots_full
                    else max(remaining))
        if self._arrivals and not slots_full:
            # steps the clock can take before the next arrival is due;
            # +1 so an arrival inside the very next quantum still lets
            # one step run
            until = self._arrivals[0][0] - self.now_s
            k = min(k, 1 + int(max(until, 0.0) / self.virtual_step_s))
        k = max(k, self.min_steps_per_tick)
        for rung in reversed(self.k_ladder):
            if rung <= k:
                return rung
        return self.min_steps_per_tick

    def _decode_tick_single(self) -> None:
        """K=1 decode: one dispatch, one host round-trip per token.
        The only hard sync is the token transfer itself (the data
        dependency of host-side sampling feedback); per-step walls are
        recorded only when ``timed`` — there is no unconditional
        ``block_until_ready`` barrier anymore."""
        if self.paged:
            for slot, sess in list(enumerate(self.slots)):
                if sess is not None and sess.decoding and \
                        self.slots[slot] is sess:
                    self._ensure_decode_page(slot, sess)
            self._sync_device()
        active = [(i, s) for i, s in enumerate(self.slots)
                  if s is not None and (not self.paged or s.decoding)]
        if not active:
            return
        toks = np.zeros((self.n_slots, 1), np.int32)
        for slot, sess in active:
            toks[slot, 0] = sess.next_input_token
        if self.paged:
            # this step reads blocks 0..ceil((pos+1)/page)-1 per live
            # lane (pos+1 counts the row the step writes) — the KV
            # traffic of the fused in-place kernel
            self.step_kv_blocks.append(sum(
                -(-(sess.pos + 1) // self.page_size)
                for _, sess in active))
        t0 = time.perf_counter()
        logits, self.cache = self._run_step(jnp.asarray(toks))
        nxt = self._sample(logits[:, -1], 2 * self.tick_count + 1)
        t1 = time.perf_counter()
        nxt = np.asarray(nxt)            # the one sync: sampled tokens
        t2 = time.perf_counter()
        self.host_dispatch_s += t1 - t0
        self.host_sync_s += t2 - t1
        dt = t2 - t0
        self.decode_steps += 1
        self._charge(1)
        for slot, sess in active:
            sess.pos += 1
            if self.paged:
                self._pos[slot] = sess.pos
            tok = int(nxt[slot])
            sess.tokens.append(tok)
            self._stamp(sess)
            if self.timed:
                sess.step_times_s.append(dt)
            self.events.append(
                ("token", sess.request.session_id, slot, tok))
            if sess.done or self._hit_eos(tok):
                self._finish(slot, sess)

    def _decode_tick_horizon(self, K: int) -> None:
        """Horizon-K fused decode: ONE compiled program advances every
        live slot up to ``K`` tokens (lax.scan over ``decode_step`` with
        on-device sampling), the (n_slots, K) token matrix comes back in
        a single transfer, and the host reconciles after the fact —
        trimming lanes that hit EOS or their budget mid-horizon (their
        device steps were masked no-ops) and evicting finished sessions.
        Pages covering each slot's full granted horizon are reserved
        BEFORE dispatch, so the device never outruns its block table.
        ``K`` is the configured ceiling for fixed-K schedulers or the
        ladder rung ``_tick_horizon`` chose for this tick."""
        plan: Dict[int, int] = {}
        for slot, sess in list(enumerate(self.slots)):
            # skip free lanes, mid-chunked-prefill lanes, and lanes whose
            # session an earlier reservation's preemption already evicted
            if sess is None or (self.paged and not sess.decoding) or \
                    self.slots[slot] is not sess:
                continue
            want = min(K, sess.request.max_new_tokens - len(sess.tokens))
            assert want >= 1, "finished session left in a slot"
            plan[slot] = (self._reserve_horizon(slot, sess, want)
                          if self.paged else want)
        if self.paged:
            self._sync_device(pos_always=False)
        active = [(i, s) for i, s in enumerate(self.slots)
                  if plan.get(i, 0) >= 1 and s is not None]
        if not active:
            return
        toks = np.zeros((self.n_slots, 1), np.int32)
        steps_left = np.zeros((self.n_slots,), np.int32)
        for slot, sess in active:
            toks[slot, 0] = sess.next_input_token
            steps_left[slot] = plan[slot]
        key = jax.random.fold_in(self.key, 2 * self.tick_count + 1)
        t0 = time.perf_counter()
        tok_mat, self.cache = self._steps_jit(
            self.params, self.cache, jnp.asarray(toks), key,
            jnp.asarray(steps_left), horizon=K,
            temperature=self.temperature, top_k=self.top_k,
            eos_id=self.eos_id)
        t1 = time.perf_counter()
        tok_mat = np.asarray(tok_mat)    # ONE sync for up to K*slots tokens
        t2 = time.perf_counter()
        self.host_dispatch_s += t1 - t0
        self.host_sync_s += t2 - t1
        dt = t2 - t0
        self.decode_steps += 1
        self.horizon_hist[K] += 1
        # ---- reconciliation: step-major walk mirrors the device scan ----
        per_tok_dt = dt / K
        max_steps = max(plan[slot] for slot, _ in active)
        vt0 = self.now_s + self.virtual_dispatch_s
        self._charge(max_steps)
        kv_blocks = [0] * max_steps
        emitted = [0] * max_steps
        done: set = set()
        for j in range(max_steps):
            for slot, sess in active:
                if slot in done or j >= plan[slot]:
                    continue
                sess.pos += 1
                if self.paged:
                    self._pos[slot] = sess.pos
                    # blocks this device step walked for the lane: its
                    # live length after the write (same accounting as K=1)
                    kv_blocks[j] += -(-sess.pos // self.page_size)
                emitted[j] += 1
                tok = int(tok_mat[slot, j])
                sess.tokens.append(tok)
                # device step j's token leaves at the j+1'th quantum of
                # the macro-tick — a session's stamp stream sees its own
                # position inside the fused horizon, not just tick ends
                self._stamp(sess, vt0 + (j + 1) * self.virtual_step_s)
                if self.timed:
                    sess.step_times_s.append(per_tok_dt)
                self.events.append(
                    ("token", sess.request.session_id, slot, tok))
                if sess.done or self._hit_eos(tok):
                    # budget exhausted or EOS sampled mid-horizon: the
                    # lane's remaining device steps were no-ops (the
                    # device cleared its alive bit on the same token);
                    # trim here and reclaim the slot + its pages
                    done.add(slot)
                    self._finish(slot, sess)
        if self.paged:
            # count only device steps that had >= 1 live lane (trailing
            # all-masked steps move no live pages)
            self.step_kv_blocks.extend(
                b for b, n in zip(kv_blocks, emitted) if n)

    def run(self) -> ContinuousResult:
        """Drive until the waiting queue and all slots drain.

        May be called repeatedly (submit → run → submit → run) on one
        scheduler — compiled programs are reused across waves.  See
        ``ContinuousResult`` for which fields are cumulative across
        calls (``sessions``, ``events``, ``decode_steps``) and which
        cover this call only (everything else)."""
        fin0 = len(self.finished)
        tick0 = self.tick_count
        pre0 = self.preemptions
        disp0 = self.decode_steps
        arr0 = self.arrivals_released
        hist0 = collections.Counter(self.horizon_hist)
        hd0, hs0 = self.host_dispatch_s, self.host_sync_s
        blk0 = len(self.step_kv_blocks) if self.paged else 0
        pf0, ph0 = self.prefill_tokens, self.prefix_hits
        ps0, cw0 = self.prefix_tokens_saved, self.cow_copies
        limit = self.max_ticks
        if limit is None:
            def ticks_for(s: _Session) -> int:
                # a macro-tick advances up to steps_per_tick tokens, but
                # the conservative per-token budget stays valid for K>1
                t = s.request.max_new_tokens
                if self.paged and self.prefill_chunk:
                    # chunked admission spends one tick per chunk, and a
                    # preempted session re-prefills prompt + generated
                    seq = len(s.request.prompt) + s.request.max_new_tokens
                    t += -(-seq // self.prefill_chunk)
                return t
            backlog = list(self.waiting) \
                + [s for _, _, s in self._pending] \
                + [s for _, _, s in self._arrivals]
            budget = sum(ticks_for(s) for s in backlog)
            budget += sum(ticks_for(s)
                          for s in self.slots if s is not None)
            # + one release tick per trace arrival (an idle tick may do
            # nothing but fast-forward the clock and release a request)
            limit = 4 * budget + len(self._pending) \
                + len(self._arrivals) + 16
        t0 = time.perf_counter()
        while self.waiting or self._pending or self._arrivals \
                or any(s is not None for s in self.slots):
            self.tick()
            if self.tick_count - tick0 > limit:
                raise RuntimeError(
                    f"scheduler made no progress within {limit} ticks")
        wall = time.perf_counter() - t0
        n_tokens = sum(len(s.tokens) for s in self.finished[fin0:])
        sessions = {
            s.request.session_id: SessionResult(
                session_id=s.request.session_id,
                tokens=np.asarray(s.tokens, np.int32),
                slot=s.slot,
                admitted_tick=s.admitted_tick,
                finished_tick=s.finished_tick,
                step_times_s=s.step_times_s,
                klass=s.request.klass,
                priority=s.request.priority,
                arrival_s=s.arrival_s,
                token_times_s=np.asarray(s.token_times_s),
                ttft_s=(s.token_times_s[0] - s.arrival_s
                        if s.token_times_s else None),
                ttft_wall_s=(s.first_token_wall - s.release_wall
                             if s.first_token_wall is not None
                             and s.release_wall is not None else None))
            for s in self.finished}
        return ContinuousResult(
            sessions=sessions, ticks=self.tick_count - tick0,
            decode_steps=self.decode_steps, wall_s=wall,
            tokens_per_s=n_tokens / wall if wall > 0 else float("nan"),
            step_cache_size=self.step_cache_size(),
            launches_per_step=self.launches_per_step,
            # snapshot: a returned result must not mutate when the
            # scheduler keeps running (events stays cumulative — the
            # full log up to the end of THIS call)
            events=list(self.events),
            preemptions=self.preemptions - pre0,
            step_kv_blocks=(self.step_kv_blocks[blk0:] if self.paged
                            else None),
            steps_per_tick=self.steps_per_tick,
            dispatches=self.decode_steps - disp0,
            run_tokens=n_tokens,
            host_dispatch_s=self.host_dispatch_s - hd0,
            host_sync_s=self.host_sync_s - hs0,
            prefill_tokens=self.prefill_tokens - pf0,
            prefix_hits=self.prefix_hits - ph0,
            prefix_tokens_saved=self.prefix_tokens_saved - ps0,
            cow_copies=self.cow_copies - cw0,
            now_s=self.now_s,
            arrivals=self.arrivals_released - arr0,
            adaptive_k=self.adaptive_k,
            horizon_hist=dict(self.horizon_hist - hist0))
