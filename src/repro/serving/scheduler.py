"""Continuous-batching session scheduler over a slotted KV cache.

Owns admission, dispatch, and reconciliation only: the decode batch
dimension is the constant slot count (session churn never recompiles —
the paper's launch-bound finding scaled to serving), page accounting
sits behind the ``PageStore`` seam in serving/memory/ (allocator,
prefix cache, host-DRAM tier, policies), and the compiled programs live
in serving/programs.py.  Feature axes — paged KV, prefix sharing + CoW,
the host KV tier (preemption parks full pages, resume restores them),
trace replay on a deterministic virtual clock, horizon-K fused
macro-ticks, adaptive-K, priority preemption — are each greedy
token-identity-tested against their baselines.  Design notes: README.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import time
from typing import Deque, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dispatch import MODES, launch_count
from repro.models.model import Model
from repro.serving.faults import FaultInjector, InjectedFault
from repro.serving.memory import (BlockAllocator, PageStore, PrefixCache,
                                  TierCopyError, TieredPageStore,
                                  get_policy, restore_kv_blobs,
                                  save_kv_blobs)
from repro.serving.programs import SchedulerPrograms
from repro.serving.sampling import sample
from repro.serving.session import (ContinuousResult, Event,
                                   SessionRequest, _Session)
from repro.serving.vclock import VirtualClockMixin, build_k_ladder

__all__ = [
    "SlotScheduler", "jit_cache_size", "GARBAGE_PAGE", "Event",
    "BlockAllocator", "PrefixCache", "SessionRequest", "SessionResult",
    "ContinuousResult",
]


class SlotScheduler(VirtualClockMixin):
    """Admission / decode / eviction / backfill over a slotted cache."""

    def __init__(self, model: Model, params, *, n_slots: int, max_len: int,
                 dispatch_mode: str = "full_jit", temperature: float = 0.0,
                 top_k: int = 0, seed: int = 0, kv_dtype=None,
                 max_ticks: Optional[int] = None, paged: bool = False,
                 page_size: int = 16, n_pages: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 steps_per_tick: int = 1, eos_id: Optional[int] = None,
                 timed: bool = True, prefix_cache: bool = False,
                 adaptive_k: bool = False, min_steps_per_tick: int = 1,
                 priority_preemption: bool = True,
                 virtual_step_s: float = 1e-3,
                 virtual_dispatch_s: float = 4e-3,
                 shared_programs: bool = False,
                 kv_tier: str = "none",
                 tier_policy="spill",
                 host_pages: Optional[int] = None,
                 virtual_host_copy_s: float = 5e-4,
                 fault_injector: Optional[FaultInjector] = None,
                 retry_budget: int = 2,
                 session_ttl_s: Optional[float] = None,
                 restore_patience: int = 0,
                 quarantine_budget: int = 2,
                 self_audit: bool = False,
                 logit_screen: Optional[bool] = None):
        assert n_slots >= 1
        assert dispatch_mode in MODES, dispatch_mode
        assert steps_per_tick >= 1
        assert 1 <= min_steps_per_tick <= steps_per_tick
        assert kv_tier in ("none", "host"), kv_tier
        assert retry_budget >= 0 and restore_patience >= 0
        assert quarantine_budget >= 0
        assert session_ttl_s is None or session_ttl_s > 0
        if adaptive_k and steps_per_tick < 2:
            raise NotImplementedError(
                "adaptive_k needs a horizon ceiling >= 2 to adapt below")
        cfg = model.cfg
        if cfg.n_codebooks:
            raise NotImplementedError(
                "continuous batching serves single-codebook archs")
        if steps_per_tick > 1 and dispatch_mode != "full_jit":
            raise NotImplementedError(
                "horizon-K fused ticks ARE the one-program (full_jit) arm")
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.dispatch_mode = dispatch_mode
        self.temperature = temperature
        self.top_k = top_k
        self.key = jax.random.PRNGKey(seed)
        self.max_ticks = max_ticks
        self.steps_per_tick = steps_per_tick
        self.eos_id = eos_id
        self.timed = timed
        self.host_dispatch_s = 0.0
        self.host_sync_s = 0.0
        self.adaptive_k = adaptive_k
        self.min_steps_per_tick = min_steps_per_tick
        self.priority_preemption = priority_preemption
        self.k_ladder: Tuple[int, ...] = build_k_ladder(
            steps_per_tick, min_steps_per_tick)
        # virtual clock (trace replay / SLO metrics): launch tax per
        # dispatch + service quantum per decode step + host-copy
        # quantum per migrated page; deterministic host arithmetic
        self.virtual_step_s = virtual_step_s
        self.virtual_dispatch_s = virtual_dispatch_s
        self.virtual_host_copy_s = virtual_host_copy_s
        self.now_s = 0.0
        self._pending: List[Tuple[float, int, _Session]] = []
        self._arrivals: List[Tuple[float, int, _Session]] = []
        self._arrival_seq = 0
        self.arrivals_released = 0
        self.horizon_hist: collections.Counter = collections.Counter()

        self.paged = paged
        if prefix_cache and not paged:
            raise NotImplementedError(
                "prefix sharing rides the paged block table")
        if kv_tier != "none" and not paged:
            raise NotImplementedError(
                "the host KV tier spills pool pages; contiguous slots "
                "have none to migrate")
        if paged:
            if dispatch_mode != "full_jit":
                raise NotImplementedError(
                    "paged serving runs the full_jit arm only")
            if prefill_chunk is not None:
                assert prefill_chunk >= page_size and \
                    prefill_chunk % page_size == 0, \
                    "prefill_chunk must be a multiple of page_size"
            self.page_size = page_size
            self.max_blocks = -(-max_len // page_size)
            if n_pages is None:
                n_pages = 1 + n_slots * self.max_blocks   # full backing
            self.n_pages = n_pages
            self.prefill_chunk = prefill_chunk
            self.cache = model.init_cache(
                n_slots, max_len, kv_dtype=kv_dtype, paged=True,
                page_size=page_size, n_pages=n_pages)
        else:
            self.cache = model.init_cache(n_slots, max_len,
                                          kv_dtype=kv_dtype, slotted=True)
        # ---- fault tolerance (serving/faults.py; all default-off) ----
        self.fault_injector = fault_injector
        self.retry_budget = retry_budget
        self.session_ttl_s = session_ttl_s
        self.restore_patience = restore_patience
        self.quarantine_budget = quarantine_budget
        self.self_audit = self_audit
        self.logit_screen = logit_screen
        self._vocab = cfg.vocab_size
        self._pressure_holds: List[Tuple[float, List[int]]] = []
        self._pending_corrupts = 0
        self._pending_aborts: List[str] = []
        self._poison: List[str] = []
        self.quarantines = 0
        self.degraded_restores = 0
        self.aborted_sessions = 0
        self.failed_sessions = 0
        self.expired_sessions = 0
        self.audit_failures = 0
        self.retry_backoff_s = 0.0

        self.preemptions = 0
        self.step_kv_blocks: List[int] = []
        self.slots: List[Optional[_Session]] = [None] * n_slots
        self.waiting: Deque[_Session] = collections.deque()
        self.finished: List[_Session] = []
        self.events: List[Event] = []
        self.tick_count = 0
        self.decode_steps = 0
        self.prefill_tokens = 0     # tokens dispatched through prefill
        self.prefix_hits = 0        # admissions matching a cached prefix
        self.prefix_tokens_saved = 0
        self.cow_copies = 0
        self._admit_count = 0       # sampling-salt counter (even salts)
        self._admission_order = 0   # monotone admission id (preempt prio)

        self._progs = SchedulerPrograms(
            model, paged=paged, kv_tier=kv_tier,
            dispatch_mode=dispatch_mode, steps_per_tick=steps_per_tick,
            shared_programs=shared_programs)
        if paged:
            store_kw = dict(n_slots=n_slots, max_blocks=self.max_blocks,
                            page_size=page_size, n_pages=n_pages,
                            prefix_cache=prefix_cache)
            if kv_tier == "host":
                def _save_fn(cache, pages):
                    self._injected("save")
                    return save_kv_blobs(self._progs.save_pages, cache,
                                         pages)

                def _restore_fn(cache, pages, blobs):
                    self._injected("restore")
                    return restore_kv_blobs(self._progs.restore_pages,
                                            cache, pages, blobs)

                self.store: PageStore = TieredPageStore(
                    host_pages=(host_pages if host_pages is not None
                                else n_pages - 1),
                    policy=get_policy(tier_policy),
                    save_fn=_save_fn, restore_fn=_restore_fn,
                    get_cache=lambda: self.cache,
                    charge_cb=self._charge_migration,
                    retry_budget=retry_budget,
                    retry_cb=self._charge_retry, **store_kw)
            else:
                self.store = PageStore(**store_kw)
        else:
            self.store = None
        self.tiered = paged and self.store.kv_tier == "host"
        if dispatch_mode == "full_jit":
            self._program = None
        else:
            # dispatch A/B: the StepProgram decomposition's executors
            self._program = model.step_program(params, self.cache)
            self._executor = self._program.executor(dispatch_mode)
        # shared wrappers may arrive pre-warmed: report compile counts
        # relative to this instance's start
        self._step_cache_base = self._progs.raw_step_cache_size() or 0

    # ------------------------------------------------------------- intro
    @property
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    @property
    def active_sessions(self) -> List[str]:
        return [s.request.session_id for s in self.slots if s is not None]

    @property
    def allocator(self) -> Optional[BlockAllocator]:
        return self.store.allocator if self.paged else None

    @property
    def prefix(self) -> Optional[PrefixCache]:
        return self.store.prefix if self.paged else None

    @property
    def free_pages(self) -> Optional[int]:
        return self.store.free_pages if self.paged else None

    @property
    def cached_pages(self) -> Optional[int]:
        """Pages held by the prefix cache (None when sharing is off)."""
        return self.store.cached_pages if self.paged else None

    def flush_prefix_cache(self) -> int:
        """Drop every unreferenced cached prefix page to the free list."""
        return self.store.flush_prefix() if self.paged else 0

    def step_cache_size(self) -> Optional[int]:
        """Decode-step executables compiled since this scheduler was
        built (recompile guard; None when unknown)."""
        raw = self._progs.raw_step_cache_size()
        if raw is None:
            return None
        return raw - self._step_cache_base

    @property
    def launches_per_step(self) -> int:
        if self._program is not None:
            return launch_count(self._program, self.dispatch_mode)
        return 1  # full_jit

    # ------------------------------------------------------------- queue
    def submit(self, request: SessionRequest) -> None:
        prompt = np.asarray(request.prompt, np.int32).reshape(-1)
        assert prompt.size >= 1, "empty prompt"
        assert request.max_new_tokens >= 1
        # last decode write lands at S + max_new - 2; keep it in-cache
        assert prompt.size + request.max_new_tokens - 1 <= self.max_len, (
            f"session {request.session_id}: prompt {prompt.size} + "
            f"{request.max_new_tokens} new exceeds max_len {self.max_len}")
        if self.paged:
            need = self._pages_for(prompt.size + request.max_new_tokens - 1)
            assert need <= self.n_pages - 1, (
                f"session {request.session_id} needs {need} pages; the "
                f"pool holds {self.n_pages - 1}")
        req = dataclasses.replace(request, prompt=prompt)
        sess = _Session(req)
        if req.arrival_s > 0.0:
            # trace replay: queued once the virtual clock reaches the
            # arrival; times are rebased to the serving run()
            self._pending.append((float(req.arrival_s),
                                  self._arrival_seq, sess))
            self._arrival_seq += 1
        else:
            sess.arrival_s = self.now_s
            sess.release_wall = time.perf_counter() if self.timed else None
            self.waiting.append(sess)

    # ----------------------------------------------------------- serving
    def _sample(self, logits: jnp.ndarray, salt: int) -> jnp.ndarray:
        key = jax.random.fold_in(self.key, salt)
        return sample(logits, key, temperature=self.temperature,
                      top_k=self.top_k)

    def _hit_eos(self, tok: int) -> bool:
        return self.eos_id is not None and tok == self.eos_id

    def _finish(self, slot: int, sess: _Session) -> None:
        sess.finished_tick = self.tick_count
        self.slots[slot] = None
        self.finished.append(sess)
        if self.paged:
            self.store.drop_shadows(sess.sid)   # stale pre-spills die
            self._release_slot(slot, sess)
        self.events.append(("finish", sess.sid, slot))

    # ------------------------------------------------- fault tolerance
    @property
    def _screen_logits(self) -> bool:
        """The NaN/Inf (K=1) / token-range (horizon) screen on sampled
        output: explicit ``logit_screen`` wins, else on exactly when an
        injector is attached (resolved per call, so a soak can swap
        injectors on a cached scheduler)."""
        return (self.logit_screen if self.logit_screen is not None
                else self.fault_injector is not None)

    def _injected(self, which: str) -> None:
        """Raise ``InjectedFault`` when the plan armed a copy failure
        for this save/restore call (consulted per call — see above)."""
        inj = self.fault_injector
        if inj is not None and inj.take_copy_fail(which):
            raise InjectedFault(f"injected {which} copy failure")

    def _charge_retry(self, attempt: int) -> None:
        """Virtual cost of one copy retry: exponential backoff in
        launch-tax units, doubling per attempt — charged to the same
        clock everything else pays, so chaos SLO numbers include it."""
        dt = self.virtual_dispatch_s * (2 ** (attempt - 1))
        self.now_s += dt
        self.retry_backoff_s += dt

    def _take_poison(self, sid: str) -> bool:
        """Consume a pending logit poisoning aimed at ``sid`` (or at
        anyone, target "")."""
        for i, t in enumerate(self._poison):
            if t == sid or t == "":
                del self._poison[i]
                if self.fault_injector is not None:
                    self.fault_injector.mark("nan_logits")
                return True
        return False

    def _bump_status(self, status: str) -> None:
        self.aborted_sessions += status == "aborted"
        self.failed_sessions += status == "failed"
        self.expired_sessions += status == "expired"

    def _abort_session(self, sid: str, status: str) -> bool:
        """Terminally remove a session wherever it lives — resident,
        waiting, or still queued in the arrival stream — freeing its
        slot, pages, and host blobs.  Committed tokens are kept (the
        result carries the prefix plus a non-ok ``status``).  False
        when the session is unknown or already finished (a disconnect
        racing completion is not an error)."""
        for slot, sess in enumerate(self.slots):
            if sess is not None and sess.sid == sid:
                sess.status = status
                self._bump_status(status)
                self.events.append((status, sid, slot))
                self._finish(slot, sess)
                return True
        for sess in self.waiting:
            if sess.sid == sid:
                self.waiting.remove(sess)
                sess.status = status
                sess.finished_tick = self.tick_count
                self.finished.append(sess)
                if self.paged:
                    self.store.drop_shadows(sid)
                    self.store.drop_parked(sid)
                self._bump_status(status)
                self.events.append((status, sid, -1))
                return True
        for queue in (self._arrivals, self._pending):
            for entry in queue:
                if entry[2].sid == sid:
                    queue.remove(entry)
                    if queue is self._arrivals:
                        heapq.heapify(self._arrivals)
                    sess = entry[2]
                    sess.status = status
                    sess.finished_tick = self.tick_count
                    self.finished.append(sess)
                    self._bump_status(status)
                    self.events.append((status, sid, -1))
                    return True
        return False

    def _poll_faults(self) -> None:
        """Apply due fault-plan events and enforce the per-session TTL.
        Runs right after arrival release each tick; a no-op without an
        injector, TTL, or live pressure hold."""
        inj = self.fault_injector
        if inj is None and self.session_ttl_s is None \
                and not self._pressure_holds:
            return
        # expire pressure holds: withheld pages return to the free list
        # the moment the virtual clock passes the spike
        if self._pressure_holds:
            live = []
            for expiry, pages in self._pressure_holds:
                if self.now_s >= expiry:
                    self.store.release(pages)
                else:
                    live.append((expiry, pages))
            self._pressure_holds = live
        if inj is not None:
            for spec in inj.poll(self.now_s):
                if spec.kind == "pool_pressure":
                    if not self.paged:
                        continue         # no pool to pressure
                    got = self.store.alloc_free(
                        min(spec.count, self.store.free_pages))
                    if got:
                        self._pressure_holds.append(
                            (self.now_s + spec.duration_s, got))
                        inj.mark("pool_pressure")
                        self.events.append(("pressure", "", -1,
                                            len(got)))
                elif spec.kind == "blob_corrupt":
                    self._pending_corrupts += spec.count
                elif spec.kind == "nan_logits":
                    self._poison.append(spec.target)
                elif spec.kind == "abort":
                    self._pending_aborts.append(spec.target)
            # corruption bites whatever is parked NOW; pending damage
            # waits for the next parked blob instead of going unfired
            while self._pending_corrupts and self.tiered:
                sid = self.store.corrupt_parked_blob()
                if sid is None:
                    break
                self._pending_corrupts -= 1
                inj.mark("blob_corrupt")
                self.events.append(("corrupt", sid, -1))
            if not self.tiered:
                self._pending_corrupts = 0
            if self._pending_aborts:
                rest = []
                for target in self._pending_aborts:
                    if target:
                        # a disconnect racing completion just drops
                        if self._abort_session(target, "aborted"):
                            inj.mark("abort")
                        continue
                    sid = next(
                        (s.sid for s in self.slots if s is not None),
                        None) or (self.waiting[0].sid if self.waiting
                                  else None)
                    if sid is None:
                        rest.append(target)   # nobody live yet: retry
                    else:
                        self._abort_session(sid, "aborted")
                        inj.mark("abort")
                self._pending_aborts = rest
        if self.session_ttl_s is not None:
            overdue = [s for s in list(self.slots) + list(self.waiting)
                       if s is not None
                       and self.now_s - s.arrival_s > self.session_ttl_s]
            for s in overdue:
                self._abort_session(s.sid, "expired")

    def _run_audit(self) -> None:
        """Idle-tick self-audit of the page accounting; first damage
        warns (an "audit" event), repeated damage fails the run closed
        — continuing to serve on a corrupt allocator turns one broken
        session into silently wrong streams for everyone."""
        live = [p for s in self.slots if s is not None for p in s.pages]
        issues = self.store.check(live)
        if not issues:
            return
        self.audit_failures += 1
        self.events.append(("audit", "; ".join(issues)[:200], -1))
        if self.audit_failures > 1:
            raise RuntimeError(
                "page-accounting self-audit failed twice: "
                + "; ".join(issues))

    def _quarantine(self, slot: int, sess: _Session) -> None:
        """Pull a lane whose sampled output failed the logit screen.
        Paged sessions requeue and re-prefill from their committed
        prefix (the poisoned step never committed, so recovery is
        token-identical); past the quarantine budget — or on the
        contiguous layout, which has no resume machinery — the session
        fails closed with a terminal event."""
        self.quarantines += 1
        sess.quarantines += 1
        self.events.append(("quarantine", sess.sid, slot))
        if not self.paged or sess.quarantines > self.quarantine_budget:
            sess.status = "failed"
            self._bump_status("failed")
            self.events.append(("failed", sess.sid, slot))
            self._finish(slot, sess)
            return
        self._requeue(slot, sess)

    # ------------------------------------------------------ paged plumbing
    def _pages_for(self, n_tokens: int) -> int:
        return -(-n_tokens // self.page_size)

    def _release_slot(self, slot: int, sess: _Session) -> None:
        """Reclaim a session's pages and park the lane on the sentinel."""
        self.store.release(sess.pages)
        sess.pages = []
        self.store.clear_slot(slot)

    def _sync_device(self, pos_always: bool = True) -> None:
        self.store.sync(self.cache, pos_always)

    def _requeue(self, slot: int, sess: _Session) -> None:
        """Pull a resident session back to the head of the queue,
        parking its full pages (tiered) or dropping them — the shared
        prologue of preemption and quarantine.  Costs recompute (or
        copies), never correctness."""
        if self.tiered and self.store.policy.spill_parked \
                and sess.pos >= self.page_size:
            self.store.park(sess.sid, sess.pos // self.page_size,
                            sess.pages, self.cache)
        elif self.paged:
            self.store.drop_shadows(sess.sid)
        self._release_slot(slot, sess)
        self.slots[slot] = None
        sess.slot = -1
        sess.prefilled = 0
        sess.prefill_seq = None
        sess.resume = True
        sess.tier_waits = 0
        self.waiting.appendleft(sess)   # it was admitted before the waiters

    def _preempt(self, slot: int, sess: _Session) -> None:
        """Requeue a session to reclaim its pages; the host tier parks
        full pages; the partial tail always re-prefills."""
        self._requeue(slot, sess)
        self.preemptions += 1
        self.events.append(("preempt", sess.sid, slot))

    def _alloc_or_preempt(self, n: int, needy: _Session) -> Optional[List[int]]:
        """Allocate ``n`` pages, preempting one victim at a time until
        it fits (None if it still can't).  Victims: lowest-priority-
        youngest first (youngest-first when priority preemption is off)."""
        while True:
            got = self.store.alloc(n)
            if got is not None:
                return got
            if self.priority_preemption:
                victims = [((-s.priority, s.admit_seq), i, s)
                           for i, s in enumerate(self.slots)
                           if s is not None and s is not needy
                           and (s.priority < needy.priority
                                or (s.priority == needy.priority
                                    and s.admit_seq > needy.admit_seq))]
            else:
                victims = [((0, s.admit_seq), i, s)
                           for i, s in enumerate(self.slots)
                           if s is not None and s is not needy
                           and s.admit_seq > needy.admit_seq]
            if not victims:
                return None
            _, vslot, vsess = max(victims)
            self._preempt(vslot, vsess)

    def _next_chunk_len(self, sess: _Session) -> int:
        remaining = len(sess.prefill_seq) - sess.prefilled
        if self.prefill_chunk is None:
            return remaining
        return min(self.prefill_chunk, remaining)

    def _prefill_next_chunk(self, slot: int, sess: _Session) -> bool:
        """Run ONE prefill chunk (allocating its pages first); False
        when pages stay short after preemption — retried next tick."""
        start = sess.prefilled
        C = self._next_chunk_len(sess)
        need = self._pages_for(start + C) - len(sess.pages)
        if need > 0:
            got = self._alloc_or_preempt(need, sess)
            if got is None:
                return False
            base = len(sess.pages)
            sess.pages.extend(got)
            self.store.map_pages(slot, base, got)
        self._sync_device()
        chunk = jnp.asarray(sess.prefill_seq[start:start + C])[None, :]
        logits, self.cache = self._progs.prefill_chunk(
            self.params, {"tokens": chunk}, self.cache, jnp.int32(slot),
            jnp.int32(start))
        sess.prefilled = start + C
        sess.pos = sess.prefilled
        self.store.set_pos(slot, sess.prefilled)
        self.prefill_tokens += C
        self._charge(1)          # one prefill program: launch + a quantum
        self._register_prefix(sess)
        if sess.decoding:
            # prefill complete: sample the first token — unless resuming,
            # where the last generated token re-feeds through decode
            if sess.resume and sess.tokens:
                sess.resume = False
            else:
                sess.resume = False
                salt = 2 * self._admit_count
                self._admit_count += 1
                tok = int(self._sample(logits[:, -1], salt)[0])
                sess.tokens.append(tok)
                self._stamp(sess)
                self.events.append(("token", sess.sid, slot, tok))
                if sess.done or self._hit_eos(tok):
                    self._finish(slot, sess)
        return True

    def _register_prefix(self, sess: _Session) -> None:
        """Index the session's fully-prefilled pages for sharing."""
        self.store.register(sess.prefill_seq, sess.pages,
                            sess.prefilled // self.page_size)

    @staticmethod
    def _prefill_seq_for(sess: _Session) -> np.ndarray:
        """The sequence admission must make resident: prompt, plus on
        resume all but the last generated token (that one re-feeds
        through decode).  Memoised while the head waits at the gate."""
        if sess.seq_cache is None:
            sess.seq_cache = (
                np.concatenate([sess.request.prompt,
                                np.asarray(sess.tokens[:-1], np.int32)])
                if sess.resume and sess.tokens else
                np.asarray(sess.request.prompt, np.int32))
        return sess.seq_cache

    def _admit_paged(self, slot: int, sess: _Session, seq: np.ndarray,
                     shared: List[int]) -> None:
        """Install a session in ``slot``, aliasing the ``shared`` prefix
        pages so only the tail past the match prefills.  A whole-
        sequence match leaves nothing to prefill: resumes decode at
        once; a fresh prompt replays its last token through decode,
        CoW-faulting the last shared page first (shared pages are never
        written)."""
        sess.prefill_seq = seq
        sess.seq_cache = None        # tokens grow while resident
        sess.prefilled = 0
        sess.pages = []
        sess.slot = slot
        sess.admitted_tick = self.tick_count
        sess.admit_seq = self._admission_order
        self._admission_order += 1
        self.slots[slot] = sess
        self.store.clear_slot(slot)
        self.events.append(("admit", sess.sid, slot))
        if not shared:
            return
        k = len(shared)
        matched = k * self.page_size
        self.prefix_hits += 1
        if matched < len(seq):
            # tail remains: share the matched run, prefill only the tail
            # (fresh private pages — no CoW needed)
            self.store.retain(shared)
            sess.pages = list(shared)
            self.store.map_pages(slot, 0, shared)
            sess.prefilled = matched
            sess.pos = matched
            self.store.set_pos(slot, matched)
            self.prefix_tokens_saved += matched
        elif sess.resume and sess.tokens:
            # fully cached resume: nothing to prefill, nothing to sample
            self.store.retain(shared)
            sess.pages = list(shared)
            self.store.map_pages(slot, 0, shared)
            sess.prefilled = len(seq)
            sess.pos = len(seq)
            self.store.set_pos(slot, len(seq))
            sess.resume = False
            self.prefix_tokens_saved += len(seq)
        else:
            # fully cached fresh prompt: CoW-fault the last shared page.
            # Retain BEFORE allocating — the allocation may reclaim
            # cached pages (legally even the unretained source page
            # itself, degrading the copy to an in-place no-op).
            self.store.retain(shared[:-1])
            got = self.store.alloc(1)
            assert got is not None, "admission gate covered the CoW page"
            sess.pages = list(shared[:-1]) + got
            self.store.map_pages(slot, 0, sess.pages)
            self.cache = self._progs.copy_page(
                self.cache, jnp.int32(shared[-1]), jnp.int32(got[0]))
            self.cow_copies += 1
            self._charge(0)      # the CoW copy is one dispatched program
            sess.prefilled = len(seq)
            sess.pos = len(seq) - 1
            self.store.set_pos(slot, len(seq) - 1)
            self.prefix_tokens_saved += len(seq)

    def _try_admit_tiered(self, slot: int) -> bool:
        """Tier-aware admission of the queue head: restore parked (or
        host-prefix-indexed) KV pages into fresh device pages instead
        of re-prefilling.  The device prefix cache is consulted first —
        blocks it covers alias and their parked blobs drop.  False when
        the host tier has nothing or the page gate can't cover the
        restore; the re-prefill admission then runs and stays the
        liveness anchor.  Restored bytes are the originally written
        bytes: the resumed stream is token-identical by construction."""
        store, head = self.store, self.waiting[0]
        seq = self._prefill_seq_for(head)
        shared = store.match(seq)
        k = len(shared)
        n_parked = store.parked_blocks(head.sid)
        if n_parked > k:
            paths = None
            n_restore = n_parked - k
            covered = n_parked * self.page_size
        else:
            # host prefix index: extend the device match, capped one
            # block short of the sequence so a fresh session keeps >= 1
            # tail token to prefill (first sample needs its logits)
            paths = store.host_match(seq, k,
                                     (len(seq) - 1) // self.page_size)
            if not paths:
                return False
            n_restore = len(paths)
            covered = (k + n_restore) * self.page_size
        if covered < len(seq):
            tail = len(seq) - covered
            first = (tail if self.prefill_chunk is None
                     else min(self.prefill_chunk, tail))
            need = self._pages_for(covered + first) - k
        else:
            need = n_restore + 1    # +1: first decode write headroom
        if not store.can_cover(need, shared):
            return False
        store.retain(shared)        # pin BEFORE the restore allocation
        got = store.alloc(n_restore)
        assert got is not None, "tier gate covered the restore pages"
        try:
            if paths is None:
                self.cache = store.take_parked(head.sid, k, got,
                                               self.cache)
            else:
                self.cache = store.restore_host_prefix(paths, got,
                                                       self.cache)
        except TierCopyError:
            # degraded admission: the copy (or its checksum) failed past
            # the retry budget.  Give every reservation back — device
            # pages AND the prefix pin — drop the (possibly corrupt)
            # parked copy, and fall through to the re-prefill admission
            # THIS tick: token-identical by construction, no livelock.
            store.release(got)
            store.release(shared)
            store.drop_parked(head.sid)
            self.degraded_restores += 1
            self.events.append(("degraded", head.sid, slot))
            return False
        self.waiting.popleft()
        self._admit_paged(slot, head, seq, [])
        if shared:
            self.prefix_hits += 1
        head.pages = list(shared) + got
        self.store.map_pages(slot, 0, head.pages)
        head.prefilled = covered
        head.pos = covered
        store.set_pos(slot, covered)
        self.prefix_tokens_saved += covered
        self._register_prefix(head)   # restored blocks become shareable
        if covered == len(seq):
            head.resume = False       # fully covered: decode directly
        return True

    def _backfill_paged(self) -> None:
        """FIFO admission gated on free pages (head-of-line blocking is
        deliberate — skip-ahead would starve long prompts).  The host
        tier gets first refusal; the ordinary gate charges only the
        UNMATCHED pages, counting reclaimable cached pages as free —
        excluding the match itself, which is about to be pinned."""
        for slot in range(self.n_slots):
            while self.slots[slot] is None and self.waiting:
                if self.tiered and self._try_admit_tiered(slot):
                    sess = self.slots[slot]
                else:
                    head = self.waiting[0]
                    if self.tiered and self.restore_patience > 0 \
                            and head.tier_waits < self.restore_patience \
                            and self.store.parked_blocks(head.sid) > 0:
                        # restore-gate patience: the parked copy exists
                        # but the page gate can't cover it yet — hold a
                        # bounded number of ticks before the re-prefill
                        # admission supersedes (and discards) the copy
                        head.tier_waits += 1
                        return
                    seq = self._prefill_seq_for(head)
                    shared = self.store.match(seq)
                    while True:
                        matched = len(shared) * self.page_size
                        if shared and matched >= len(seq):
                            # fully cached: fresh needs 1 page (the CoW
                            # copy) pinning shared[:-1]; resume pins the
                            # whole match, +1 decode-write headroom
                            resume = head.resume and head.tokens
                            pinned = shared if resume else shared[:-1]
                            need = 1
                        else:
                            pinned = shared
                            tail = len(seq) - matched
                            first = (tail if self.prefill_chunk is None
                                     else min(self.prefill_chunk, tail))
                            need = (self._pages_for(matched + first)
                                    - len(shared))
                        if self.store.can_cover(need, pinned):
                            break
                        if not shared:
                            return      # gate: wait for reclaim
                        # shrink the match: its dropped tail pages
                        # become reclaimable fuel for this admission
                        # (degrades to the unshared gate = liveness)
                        shared = shared[:-1]
                    self._admit_paged(slot, self.waiting.popleft(), seq,
                                      shared)
                    # re-prefill admission supersedes any parked copy
                    self.store.drop_parked(head.sid)
                    sess = self.slots[slot]
                if not sess.decoding:
                    ok = self._prefill_next_chunk(slot, sess)
                    assert ok, "gated admission must have its first chunk"
                if self.slots[slot] is not None and \
                        not self.slots[slot].decoding:
                    break           # chunked prefill continues next ticks

    # -------------------------------------------------------- contiguous
    def _backfill(self) -> None:
        """FIFO admission into free slots; prefill-into-slot per session."""
        if self.paged:
            self._backfill_paged()
            return
        for slot in range(self.n_slots):
            while self.slots[slot] is None and self.waiting:
                sess = self.waiting.popleft()
                prompt = jnp.asarray(sess.request.prompt)[None, :]
                logits, self.cache = self._progs.prefill_slot(
                    self.params, {"tokens": prompt}, self.cache,
                    jnp.int32(slot))
                sess.slot = slot
                sess.admitted_tick = self.tick_count
                self.slots[slot] = sess
                self.prefill_tokens += int(prompt.shape[1])
                self._charge(1)
                self.events.append(("admit", sess.sid, slot))
                # even salts for admissions (counted monotonically), odd
                # for decode steps — never collide
                salt = 2 * self._admit_count
                self._admit_count += 1
                tok = int(self._sample(logits[:, -1], salt)[0])
                sess.tokens.append(tok)
                self._stamp(sess)
                self.events.append(("token", sess.sid, slot, tok))
                if sess.done or self._hit_eos(tok):
                    # 1-token / instant-EOS session: retire immediately,
                    self._finish(slot, sess)   # loop backfills the slot
        occupied = [s for s in self.slots if s is not None]
        assert len(set(map(id, occupied))) == len(occupied), \
            "slot double-assignment"
        assert all(s is None or s.slot == i
                   for i, s in enumerate(self.slots)), "slot bookkeeping"

    def _run_step(self, tokens: jnp.ndarray):  # staticcheck: hotpath
        if self._progs.step is not None:
            return self._progs.step(self.params, self.cache, tokens)
        state = self._executor({"tokens": tokens, "cache": self.cache})
        return state["logits"], state["cache"]

    def _ensure_decode_page(self, slot: int, sess: _Session) -> bool:
        """Guarantee the page under ``sess.pos`` exists, preempting
        younger sessions if the pool is dry; failing that, the needy
        session itself is preempted (an older one holds the pool)."""
        blk = sess.pos // self.page_size
        if blk < len(sess.pages):
            return True
        assert blk == len(sess.pages), "page allocation skipped a block"
        got = self._alloc_or_preempt(1, sess)
        if got is None:
            self._preempt(slot, sess)
            return False
        self.store.map_pages(slot, blk, got)
        sess.pages.extend(got)
        return True

    def _reserve_horizon(self, slot: int, sess: _Session, want: int) -> int:
        """Pre-reserve pages for ``want`` decode steps of one fused
        macro-tick.  Lookahead past the next step is *optional*
        (free-list-only); only the MANDATORY next page preempts, like
        ``_ensure_decode_page``.  Returns steps granted; 0 = the
        session itself was preempted."""
        def take(n_pages: int) -> bool:
            got = self.store.alloc_free(n_pages)
            if got is None:
                return False
            base = len(sess.pages)
            sess.pages.extend(got)
            self.store.map_pages(slot, base, got)
            return True

        def top_up(n_steps: int) -> bool:
            need = self._pages_for(sess.pos + n_steps) - len(sess.pages)
            return need <= 0 or take(need)

        if top_up(want):
            return want
        # partial lookahead: take what the free list can spare, leaving
        # one page per OTHER live decoding slot so optional lookahead
        # never forces a later mandatory allocation into preempting
        others = sum(1 for i, s in enumerate(self.slots)
                     if s is not None and s is not sess and s.decoding)
        spare = self.store.free_pages - others
        need = self._pages_for(sess.pos + want) - len(sess.pages)
        if 0 < spare < need:
            take(spare)
        have = len(sess.pages) * self.page_size - sess.pos
        if have >= 1:
            return min(want, have)       # shrink: lookahead is optional
        # pool dry at a page boundary: the next page is mandatory —
        # preempt younger sessions (or the needy itself) like K=1 does
        got = self._alloc_or_preempt(1, sess)
        if got is None:
            self._preempt(slot, sess)
            return 0
        self.store.map_pages(slot, len(sess.pages), got)
        sess.pages.extend(got)
        if top_up(want):                 # eviction may have freed plenty
            return want
        return min(want, len(sess.pages) * self.page_size - sess.pos)

    def tick(self) -> List[Event]:
        """One iteration: continue chunked prefills, backfill, tier idle
        work, one batched decode dispatch, evict completed sessions."""
        n_before = len(self.events)
        steps0, pf0 = self.decode_steps, self.prefill_tokens
        self._release_arrivals()
        self._poll_faults()
        if self.paged:
            for slot, sess in enumerate(self.slots):
                if sess is not None and not sess.decoding:
                    self._prefill_next_chunk(slot, sess)
        self._backfill()
        if self.tiered and not self.waiting and not self._arrivals:
            # no admission pressure: let the policy pre-migrate
            # (LookAheadSpill shadow-copies the predicted victim)
            self.store.policy.idle_tick(self)
        if self.paged and self.self_audit and not self.waiting \
                and all(s is None for s in self.slots):
            self._run_audit()     # idle tick: audit the page accounting
        if self.steps_per_tick == 1:
            self._decode_tick_single()
        else:
            self._decode_tick_horizon(self._tick_horizon())
        if self._pressure_holds and self.decode_steps == steps0 \
                and self.prefill_tokens == pf0 \
                and len(self.events) == n_before:
            # a pressure spike can gate every admission with nothing
            # resident and no arrival to fast-forward to: jump the clock
            # to the next hold expiry so the spike passes
            self.now_s = max(self.now_s,
                             min(e for e, _ in self._pressure_holds))
        self.tick_count += 1
        return self.events[n_before:]

    def _decode_tick_single(self) -> None:  # staticcheck: hotpath
        """K=1 decode: one dispatch + one host round-trip per token."""
        if self.paged:
            for slot, sess in list(enumerate(self.slots)):
                if sess is not None and sess.decoding and \
                        self.slots[slot] is sess:
                    self._ensure_decode_page(slot, sess)
            self._sync_device()
        active = [(i, s) for i, s in enumerate(self.slots)
                  if s is not None and (not self.paged or s.decoding)]
        if not active:
            return
        toks = np.zeros((self.n_slots, 1), np.int32)
        for slot, sess in active:
            toks[slot, 0] = sess.next_input_token
        if self.paged:
            # blocks this step reads per live lane (pos+1 counts the
            # written row) — the KV traffic of the fused kernel
            self.step_kv_blocks.append(sum(
                -(-(sess.pos + 1) // self.page_size)
                for _, sess in active))
        t0 = time.perf_counter()
        logits, self.cache = self._run_step(jnp.asarray(toks))
        nxt = self._sample(logits[:, -1], 2 * self.tick_count + 1)
        t1 = time.perf_counter()
        # staticcheck: disable=hot-sync -- the ONE deliberate per-tick sync: sampled tokens must reach the host to be emitted
        nxt = np.asarray(nxt)
        t2 = time.perf_counter()
        self.host_dispatch_s += t1 - t0
        self.host_sync_s += t2 - t1
        dt = t2 - t0
        self.decode_steps += 1
        self._charge(1)
        screened: set = set()
        if self._screen_logits:
            # NaN/Inf screen on this step's logits — a writable HOST
            # copy: injected poison lands here, device state stays clean
            # staticcheck: disable=hot-sync -- NaN screen needs a writable host copy; only taken when --screen-logits is on (chaos runs)
            last = np.array(logits[:, -1], np.float32)
            for slot, sess in active:
                if self._poison and self._take_poison(sess.sid):
                    last[slot] = np.nan
                if not np.isfinite(last[slot]).all():
                    screened.add(slot)
        for slot, sess in active:
            if slot in screened:
                # poisoned step never commits: quarantine the lane,
                # other lanes proceed untouched
                self._quarantine(slot, sess)
                continue
            sess.pos += 1
            if self.paged:
                self.store.mirror_pos(slot, sess.pos)
            tok = int(nxt[slot])
            sess.tokens.append(tok)
            self._stamp(sess)
            if self.timed:
                sess.step_times_s.append(dt)
            self.events.append(("token", sess.sid, slot, tok))
            if sess.done or self._hit_eos(tok):
                self._finish(slot, sess)

    def _decode_tick_horizon(self, K: int) -> None:  # staticcheck: hotpath
        """Horizon-K fused decode: ONE program advances every live slot
        up to ``K`` tokens (lax.scan, on-device sampling), the
        (n_slots, K) token matrix returns in one transfer, and the host
        reconciles afterwards — trimming lanes that hit EOS or budget
        mid-horizon (masked no-ops on device).  Pages covering each
        granted horizon are reserved BEFORE dispatch."""
        plan: Dict[int, int] = {}
        for slot, sess in list(enumerate(self.slots)):
            # skip free lanes, mid-prefill lanes, and lanes an earlier
            # reservation's preemption already evicted
            if sess is None or (self.paged and not sess.decoding) or \
                    self.slots[slot] is not sess:
                continue
            want = min(K, sess.request.max_new_tokens - len(sess.tokens))
            assert want >= 1, "finished session left in a slot"
            plan[slot] = (self._reserve_horizon(slot, sess, want)
                          if self.paged else want)
        if self.paged:
            self._sync_device(pos_always=False)
        active = [(i, s) for i, s in enumerate(self.slots)
                  if plan.get(i, 0) >= 1 and s is not None]
        if not active:
            return
        toks = np.zeros((self.n_slots, 1), np.int32)
        steps_left = np.zeros((self.n_slots,), np.int32)
        for slot, sess in active:
            toks[slot, 0] = sess.next_input_token
            steps_left[slot] = plan[slot]
        key = jax.random.fold_in(self.key, 2 * self.tick_count + 1)
        t0 = time.perf_counter()
        tok_mat, self.cache = self._progs.steps(
            self.params, self.cache, jnp.asarray(toks), key,
            jnp.asarray(steps_left), horizon=K,
            temperature=self.temperature, top_k=self.top_k,
            eos_id=self.eos_id)
        t1 = time.perf_counter()
        # staticcheck: disable=hot-sync -- the ONE deliberate macro-tick sync: up to K*slots sampled tokens in one transfer
        tok_mat = np.asarray(tok_mat)
        t2 = time.perf_counter()
        screen = self._screen_logits
        if screen:
            tok_mat = np.array(tok_mat)     # writable host copy
            for slot, sess in active:
                if self._poison and self._take_poison(sess.sid):
                    # out-of-vocab sentinel on the lane's whole horizon:
                    # the range check below quarantines at step 0, so no
                    # poisoned token ever commits
                    tok_mat[slot, :] = self._vocab
        self.host_dispatch_s += t1 - t0
        self.host_sync_s += t2 - t1
        dt = t2 - t0
        self.decode_steps += 1
        self.horizon_hist[K] += 1
        # ---- reconciliation: step-major walk mirrors the device scan ----
        per_tok_dt = dt / K
        max_steps = max(plan[slot] for slot, _ in active)
        vt0 = self.now_s + self.virtual_dispatch_s
        self._charge(max_steps)
        kv_blocks = [0] * max_steps
        emitted = [0] * max_steps
        done: set = set()
        for j in range(max_steps):
            for slot, sess in active:
                if slot in done or j >= plan[slot]:
                    continue
                tok = int(tok_mat[slot, j])
                if screen and not 0 <= tok < self._vocab:
                    # screened lane: nothing from this horizon commits
                    done.add(slot)
                    self._quarantine(slot, sess)
                    continue
                sess.pos += 1
                if self.paged:
                    self.store.mirror_pos(slot, sess.pos)
                    # blocks this device step walked for the lane: its
                    # live length after the write (same accounting as K=1)
                    kv_blocks[j] += -(-sess.pos // self.page_size)
                emitted[j] += 1
                sess.tokens.append(tok)
                # step j's token leaves at the j+1'th quantum — stamps
                # see positions inside the fused horizon, not tick ends
                self._stamp(sess, vt0 + (j + 1) * self.virtual_step_s)
                if self.timed:
                    sess.step_times_s.append(per_tok_dt)
                self.events.append(("token", sess.sid, slot, tok))
                if sess.done or self._hit_eos(tok):
                    # remaining device steps were masked no-ops
                    done.add(slot)
                    self._finish(slot, sess)
        if self.paged:
            # count only device steps that had >= 1 live lane (trailing
            # all-masked steps move no live pages)
            self.step_kv_blocks.extend(
                b for b, n in zip(kv_blocks, emitted) if n)

    def run(self) -> ContinuousResult:
        """Drive until the queue and slots drain.  Callable repeatedly
        (submit → run → submit → run) with programs reused; see
        ``ContinuousResult`` for cumulative vs per-call fields."""
        fin0 = len(self.finished)
        tick0 = self.tick_count
        pre0 = self.preemptions
        disp0 = self.decode_steps
        arr0 = self.arrivals_released
        hist0 = collections.Counter(self.horizon_hist)
        hd0, hs0 = self.host_dispatch_s, self.host_sync_s
        blk0 = len(self.step_kv_blocks) if self.paged else 0
        pf0, ph0 = self.prefill_tokens, self.prefix_hits
        ps0, cw0 = self.prefix_tokens_saved, self.cow_copies
        st = self.store if self.paged else PageStore  # class-level zeros
        sp0, pr0 = st.pages_spilled, st.pages_restored
        tr0, hp0 = st.tier_restores, st.host_prefix_hits
        sr0, rr0 = st.save_retries, st.restore_retries
        cb0 = st.corrupt_blobs
        qa0, dg0 = self.quarantines, self.degraded_restores
        ab0, fl0 = self.aborted_sessions, self.failed_sessions
        ex0, au0 = self.expired_sessions, self.audit_failures
        rb0 = self.retry_backoff_s
        inj = self.fault_injector
        fired0 = collections.Counter(inj.fired) if inj else None
        limit = self.max_ticks
        if limit is None:
            def ticks_for(s: _Session) -> int:
                # conservative per-token budget (valid for K>1 too)
                t = s.request.max_new_tokens
                if self.paged and self.prefill_chunk:
                    # one tick per chunk; preemption re-prefills all
                    seq = len(s.request.prompt) + s.request.max_new_tokens
                    t += -(-seq // self.prefill_chunk)
                return t
            backlog = list(self.waiting) \
                + [s for _, _, s in self._pending] \
                + [s for _, _, s in self._arrivals]
            budget = sum(ticks_for(s) for s in backlog)
            budget += sum(ticks_for(s)
                          for s in self.slots if s is not None)
            # + one release tick per trace arrival
            limit = (4 + self.restore_patience) * budget \
                + len(self._pending) + len(self._arrivals) + 16
            if self.fault_injector is not None:
                # chaos re-prefills (quarantine, degraded restores) and
                # pressure-spike stall ticks eat extra headroom
                limit += 4 * budget + 64
        t0 = time.perf_counter()
        while self.waiting or self._pending or self._arrivals \
                or any(s is not None for s in self.slots):
            self.tick()
            if self.tick_count - tick0 > limit:
                raise RuntimeError(
                    f"scheduler made no progress within {limit} ticks")
        if self._pressure_holds:
            # a hold outliving the run would leak pool pages
            for _, pages in self._pressure_holds:
                self.store.release(pages)
            self._pressure_holds = []
        wall = time.perf_counter() - t0
        n_tokens = sum(len(s.tokens) for s in self.finished[fin0:])
        sessions = {s.sid: s.to_result() for s in self.finished}
        return ContinuousResult(
            sessions=sessions, ticks=self.tick_count - tick0,
            decode_steps=self.decode_steps, wall_s=wall,
            tokens_per_s=n_tokens / wall if wall > 0 else float("nan"),
            step_cache_size=self.step_cache_size(),
            launches_per_step=self.launches_per_step,
            # snapshot: a returned result must not mutate if the
            # scheduler keeps running (events stays cumulative)
            events=list(self.events),
            preemptions=self.preemptions - pre0,
            step_kv_blocks=(self.step_kv_blocks[blk0:] if self.paged
                            else None),
            steps_per_tick=self.steps_per_tick,
            dispatches=self.decode_steps - disp0,
            run_tokens=n_tokens,
            host_dispatch_s=self.host_dispatch_s - hd0,
            host_sync_s=self.host_sync_s - hs0,
            prefill_tokens=self.prefill_tokens - pf0,
            prefix_hits=self.prefix_hits - ph0,
            prefix_tokens_saved=self.prefix_tokens_saved - ps0,
            cow_copies=self.cow_copies - cw0,
            now_s=self.now_s,
            arrivals=self.arrivals_released - arr0,
            adaptive_k=self.adaptive_k,
            horizon_hist=dict(self.horizon_hist - hist0),
            kv_tier=(self.store.kv_tier if self.paged else "none"),
            tier_policy=(self.store.policy.name
                         if self.tiered else None),
            pages_spilled=st.pages_spilled - sp0,
            pages_restored=st.pages_restored - pr0,
            tier_restores=st.tier_restores - tr0,
            host_prefix_hits=st.host_prefix_hits - hp0,
            host_pages_used=(self.store.host_used if self.paged else 0),
            fault_counts=(
                {k: v for k, v in sorted(
                    (collections.Counter(inj.fired) - fired0).items())
                 if v} if inj else {}),
            faults_injected=(
                sum((collections.Counter(inj.fired) - fired0).values())
                if inj else 0),
            save_retries=st.save_retries - sr0,
            restore_retries=st.restore_retries - rr0,
            degraded_restores=self.degraded_restores - dg0,
            corrupt_blobs=st.corrupt_blobs - cb0,
            quarantines=self.quarantines - qa0,
            aborted_sessions=self.aborted_sessions - ab0,
            failed_sessions=self.failed_sessions - fl0,
            expired_sessions=self.expired_sessions - ex0,
            audit_failures=self.audit_failures - au0,
            retry_backoff_s=self.retry_backoff_s - rb0)
