"""Continuous-batching session scheduler over a slotted KV cache.

The paper's conclusion (batch-1 decode is launch-bound, fixed by keeping
the whole step inside ONE compiled program) scales to multi-user serving
only if session churn never forces a recompile.  The scheduler therefore
serves K concurrent sessions out of a **fixed-capacity slotted cache**:

  * the decode batch dimension is the (constant) slot count — the step
    program, its shapes, and its compiled executable never change;
  * each slot carries its own write position (``cache["pos"]`` is a
    (n_slots,) vector) and a per-slot length mask, so sequences of
    different ages decode together (models/attention.py);
  * admission prefills a session's prompt **into** its slot
    (``Model.prefill_into_slot`` — one compile per distinct prompt
    length, amortised across all future admissions);
  * completed sessions are evicted and their slot is backfilled from a
    FIFO waiting queue; free slots ride along in the batch as masked
    lanes (their outputs are discarded, their stale K/V stays masked).

Scheduling is host-side Python; the per-token hot path is exactly the
paper's ``full_jit`` arm — one dispatch per decode step for the whole
slot batch — and the eager / stage_jit executors (core.dispatch) remain
available for the dispatch-tax A/B on the live continuous workload.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dispatch import MODES, launch_count
from repro.models.model import Model
from repro.serving.sampling import sample

Event = Tuple  # ("admit"|"token"|"finish", session_id, slot[, token])


@dataclasses.dataclass(frozen=True)
class SessionRequest:
    """One user session: a prompt and a token budget."""
    session_id: str
    prompt: Sequence[int]            # (S,) token ids
    max_new_tokens: int


@dataclasses.dataclass
class SessionResult:
    session_id: str
    tokens: np.ndarray               # (max_new_tokens,) generated ids
    slot: int                        # slot the session was served in
    admitted_tick: int
    finished_tick: int
    step_times_s: List[float]        # shared-batch decode-step walls


@dataclasses.dataclass
class ContinuousResult:
    """Outcome of one continuous-batching run."""
    sessions: Dict[str, SessionResult]
    ticks: int                       # scheduler iterations
    decode_steps: int                # batched decode dispatches
    wall_s: float
    tokens_per_s: float              # aggregate generated tokens / wall
    step_cache_size: Optional[int]   # compiled decode-step count (full_jit)
    launches_per_step: int           # host dispatches per decode step
    events: List[Event]

    def tokens_for(self, session_id: str) -> np.ndarray:
        return self.sessions[session_id].tokens


@dataclasses.dataclass
class _Session:
    request: SessionRequest
    tokens: List[int] = dataclasses.field(default_factory=list)
    slot: int = -1
    admitted_tick: int = -1
    finished_tick: int = -1
    step_times_s: List[float] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.tokens) >= self.request.max_new_tokens


class SlotScheduler:
    """Admission / decode / eviction / backfill over a slotted cache."""

    def __init__(self, model: Model, params, *, n_slots: int, max_len: int,
                 dispatch_mode: str = "full_jit", temperature: float = 0.0,
                 top_k: int = 0, seed: int = 0, kv_dtype=None,
                 max_ticks: Optional[int] = None):
        assert n_slots >= 1
        assert dispatch_mode in MODES, dispatch_mode
        cfg = model.cfg
        if cfg.n_codebooks:
            raise NotImplementedError(
                "continuous batching serves single-codebook archs")
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.dispatch_mode = dispatch_mode
        self.temperature = temperature
        self.top_k = top_k
        self.key = jax.random.PRNGKey(seed)
        self.max_ticks = max_ticks

        self.cache = model.init_cache(n_slots, max_len, kv_dtype=kv_dtype,
                                      slotted=True)
        self.slots: List[Optional[_Session]] = [None] * n_slots
        self.waiting: Deque[_Session] = collections.deque()
        self.finished: List[_Session] = []
        self.events: List[Event] = []
        self.tick_count = 0
        self.decode_steps = 0
        self._admit_count = 0

        self._prefill_slot = jax.jit(model.prefill_into_slot,
                                     donate_argnums=(2,))
        if dispatch_mode == "full_jit":
            # the production hot path: the whole step is one program,
            # cache donated so steps run allocation-free
            self._step_jit = jax.jit(model.decode_step, donate_argnums=(1,))
            self._program = None
        else:
            # dispatch A/B hooks: same math through the eager/stage_jit
            # executors of the StepProgram decomposition
            self._step_jit = None
            self._program = model.step_program(params, self.cache)
            self._executor = self._program.executor(dispatch_mode)

    # ------------------------------------------------------------- intro
    @property
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s is None]

    @property
    def active_sessions(self) -> List[str]:
        return [s.request.session_id for s in self.slots if s is not None]

    def step_cache_size(self) -> Optional[int]:
        """Number of compiled decode-step executables (the recompile
        guard: must be 1 after any amount of session churn)."""
        if self._step_jit is not None:
            return self._step_jit._cache_size()
        return None

    @property
    def launches_per_step(self) -> int:
        if self._program is not None:
            return launch_count(self._program, self.dispatch_mode)
        return 1  # full_jit

    # ------------------------------------------------------------- queue
    def submit(self, request: SessionRequest) -> None:
        prompt = np.asarray(request.prompt, np.int32).reshape(-1)
        assert prompt.size >= 1, "empty prompt"
        assert request.max_new_tokens >= 1
        # last decode write lands at S + max_new - 2; keep it in-cache
        assert prompt.size + request.max_new_tokens - 1 <= self.max_len, (
            f"session {request.session_id}: prompt {prompt.size} + "
            f"{request.max_new_tokens} new tokens exceeds max_len "
            f"{self.max_len}")
        req = dataclasses.replace(request, prompt=prompt)
        self.waiting.append(_Session(req))

    # ----------------------------------------------------------- serving
    def _sample(self, logits: jnp.ndarray, salt: int) -> jnp.ndarray:
        key = jax.random.fold_in(self.key, salt)
        return sample(logits, key, temperature=self.temperature,
                      top_k=self.top_k)

    def _finish(self, slot: int, sess: _Session) -> None:
        sess.finished_tick = self.tick_count
        self.slots[slot] = None
        self.finished.append(sess)
        self.events.append(("finish", sess.request.session_id, slot))

    def _backfill(self) -> None:
        """FIFO admission into free slots; prefill-into-slot per session."""
        for slot in range(self.n_slots):
            while self.slots[slot] is None and self.waiting:
                sess = self.waiting.popleft()
                prompt = jnp.asarray(sess.request.prompt)[None, :]
                logits, self.cache = self._prefill_slot(
                    self.params, {"tokens": prompt}, self.cache,
                    jnp.int32(slot))
                sess.slot = slot
                sess.admitted_tick = self.tick_count
                self.slots[slot] = sess
                sid = sess.request.session_id
                self.events.append(("admit", sid, slot))
                # even salts for admissions (one per admission, counted
                # monotonically), odd for decode steps — never collide
                salt = 2 * self._admit_count
                self._admit_count += 1
                tok = int(self._sample(logits[:, -1], salt)[0])
                sess.tokens.append(tok)
                self.events.append(("token", sid, slot, tok))
                if sess.done:     # 1-token session: retire immediately,
                    self._finish(slot, sess)   # loop backfills the slot
        occupied = [s for s in self.slots if s is not None]
        assert len(set(map(id, occupied))) == len(occupied), \
            "slot double-assignment"
        assert all(s is None or s.slot == i
                   for i, s in enumerate(self.slots)), "slot bookkeeping"

    def _run_step(self, tokens: jnp.ndarray):
        if self._step_jit is not None:
            return self._step_jit(self.params, self.cache, tokens)
        state = self._executor({"tokens": tokens, "cache": self.cache})
        return state["logits"], state["cache"]

    def tick(self) -> List[Event]:
        """One scheduler iteration: backfill, one batched decode step
        for every occupied slot, evict completed sessions."""
        n_before = len(self.events)
        self._backfill()
        active = [(i, s) for i, s in enumerate(self.slots) if s is not None]
        if active:
            toks = np.zeros((self.n_slots, 1), np.int32)
            for slot, sess in active:
                toks[slot, 0] = sess.tokens[-1]
            t0 = time.perf_counter()
            logits, self.cache = self._run_step(jnp.asarray(toks))
            nxt = self._sample(logits[:, -1], 2 * self.tick_count + 1)
            nxt = np.asarray(jax.block_until_ready(nxt))
            dt = time.perf_counter() - t0
            self.decode_steps += 1
            for slot, sess in active:
                tok = int(nxt[slot])
                sess.tokens.append(tok)
                sess.step_times_s.append(dt)
                self.events.append(
                    ("token", sess.request.session_id, slot, tok))
                if sess.done:
                    self._finish(slot, sess)
        self.tick_count += 1
        return self.events[n_before:]

    def run(self) -> ContinuousResult:
        """Drive until the waiting queue and all slots drain.

        May be called repeatedly (submit → run → submit → run) on one
        scheduler — compiled programs are reused across waves.  The
        returned ``sessions`` map is cumulative; ``tokens_per_s`` and
        ``wall_s`` cover only the sessions this call finished."""
        fin0 = len(self.finished)
        tick0 = self.tick_count
        limit = self.max_ticks
        if limit is None:
            budget = sum(s.request.max_new_tokens
                         for s in list(self.waiting))
            budget += sum(s.request.max_new_tokens
                          for s in self.slots if s is not None)
            limit = 2 * budget + 16
        t0 = time.perf_counter()
        while self.waiting or any(s is not None for s in self.slots):
            self.tick()
            if self.tick_count - tick0 > limit:
                raise RuntimeError(
                    f"scheduler made no progress within {limit} ticks")
        wall = time.perf_counter() - t0
        n_tokens = sum(len(s.tokens) for s in self.finished[fin0:])
        sessions = {
            s.request.session_id: SessionResult(
                session_id=s.request.session_id,
                tokens=np.asarray(s.tokens, np.int32),
                slot=s.slot,
                admitted_tick=s.admitted_tick,
                finished_tick=s.finished_tick,
                step_times_s=s.step_times_s)
            for s in self.finished}
        return ContinuousResult(
            sessions=sessions, ticks=self.tick_count,
            decode_steps=self.decode_steps, wall_s=wall,
            tokens_per_s=n_tokens / wall if wall > 0 else float("nan"),
            step_cache_size=self.step_cache_size(),
            launches_per_step=self.launches_per_step,
            events=self.events)
