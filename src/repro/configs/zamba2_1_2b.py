"""Zamba2-1.2B [arXiv:2411.15242; hf] — Mamba2 backbone + shared
attention blocks.

38 Mamba2 layers, d_model=2048, ssm_state=64; one shared-weight
attention+MLP block (32H kv=32, head_dim 64, d_ff 8192) applied every 6
layers.  Simplifications vs HF reference noted in DESIGN.md §5 (single
shared block, no per-application LoRA).  Sliding window 4096 caps the
shared-attention KV at the long_500k shape.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    tie_embeddings=True,
    ssm_state=64,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    attn_every=6,
    sliding_window=4096,
    max_seq_len=1_048_576,
)
