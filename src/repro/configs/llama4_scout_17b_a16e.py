"""Llama-4-Scout-17B-16E [hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, 16 routed experts
top-1 + 1 shared expert.  ~17B active / ~108B total parameters.

Simplifications (DESIGN.md §5): RoPE on all layers (no iRoPE/NoPE split),
full attention (no chunked local attention), early-fusion frontend out of
scope for the LM shapes.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=0,
    vocab_size=202048,
    n_experts=16,
    top_k=1,
    moe_d_ff=8192,
    n_shared_experts=1,
    shared_d_ff=8192,
    router_type="sigmoid_top1",
    rope_theta=5e5,
)
