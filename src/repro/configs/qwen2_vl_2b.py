"""Qwen2-VL-2B [arXiv:2409.12191; hf] — transformer BACKBONE only.

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936, M-RoPE
(3 sections over t/h/w position ids), dynamic-resolution vision tower is
a STUB per the assignment: ``input_specs()`` supplies precomputed patch
embeddings at d_model.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    mrope_sections=(16, 24, 24),   # head_dim/2 = 64 = 16+24+24
    rope_theta=1e6,
)
