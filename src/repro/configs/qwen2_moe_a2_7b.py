"""Qwen1.5/2-MoE-A2.7B [hf:Qwen/Qwen1.5-MoE-A2.7B].

24L d_model=2048 16H (GQA kv=16) routed-expert d_ff=1408 vocab=151936,
60 routed experts top-4 + 4 shared experts (shared intermediate 5632).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=0,                 # every FFN is MoE
    vocab_size=151936,
    qkv_bias=True,
    n_experts=60,
    top_k=4,
    moe_d_ff=1408,
    n_shared_experts=4,
    shared_d_ff=5632,       # 4 x 1408
    router_type="softmax_topk",
    rope_theta=1e6,
)
