"""MusicGen-large decoder [arXiv:2306.05284; hf] — decoder-only over
EnCodec tokens.

48L d_model=2048 32H (GQA kv=32 = MHA) d_ff=8192 vocab=2048 per codebook,
4 EnCodec codebooks (sum-of-embeddings in, 4 LM heads out, delay-pattern
interleaving handled by the data stub).  Plain (non-gated) FFN.
head_dim = 2048/32 = 64.  Text-conditioning cross-attention is stubbed
(frontend provides frame embeddings), per the assignment.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    mlp_gated=False,
    n_codebooks=4,
    rope_theta=1e4,
)
