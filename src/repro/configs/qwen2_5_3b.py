"""Qwen2.5-3B [hf:Qwen/Qwen2.5-3B; family source hf:Qwen/Qwen2.5-0.5B].

36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936, QKV bias,
tied embeddings.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-3b",
    family="dense",
    n_layers=36,
    d_model=2048,
    n_heads=16,
    n_kv_heads=2,
    head_dim=128,
    d_ff=11008,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1e6,
)
