"""Config registry: 10 assigned architectures + the paper's 3 GQA models.

``get_config(name)`` accepts the assignment ids (e.g. "qwen2-moe-a2.7b").
"""
from __future__ import annotations

from typing import Dict, List

from repro.configs.base import SHAPES, ArchConfig, ShapeSpec, shape_applicable  # noqa: F401

from repro.configs.qwen2_moe_a2_7b import CONFIG as _qwen2_moe
from repro.configs.llama4_scout_17b_a16e import CONFIG as _llama4_scout
from repro.configs.mamba2_2_7b import CONFIG as _mamba2
from repro.configs.phi4_mini_3_8b import CONFIG as _phi4_mini
from repro.configs.olmo_1b import CONFIG as _olmo
from repro.configs.internlm2_1_8b import CONFIG as _internlm2
from repro.configs.qwen2_5_3b import CONFIG as _qwen25_3b
from repro.configs.qwen2_vl_2b import CONFIG as _qwen2_vl
from repro.configs.musicgen_large import CONFIG as _musicgen
from repro.configs.zamba2_1_2b import CONFIG as _zamba2
from repro.configs.paper_models import LLAMA31_8B, MISTRAL_7B, QWEN25_7B

ASSIGNED: List[ArchConfig] = [
    _qwen2_moe, _llama4_scout, _mamba2, _phi4_mini, _olmo,
    _internlm2, _qwen25_3b, _qwen2_vl, _musicgen, _zamba2,
]

PAPER_MODELS: List[ArchConfig] = [QWEN25_7B, MISTRAL_7B, LLAMA31_8B]

_REGISTRY: Dict[str, ArchConfig] = {c.name: c for c in ASSIGNED + PAPER_MODELS}


def get_config(name: str) -> ArchConfig:
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs(assigned_only: bool = False) -> List[str]:
    return [c.name for c in (ASSIGNED if assigned_only else ASSIGNED + PAPER_MODELS)]
