"""Mamba2-2.7B — SSD state-space duality [arXiv:2405.21060; unverified].

64L d_model=2560 (attention-free) vocab=50280, ssm_state=128.
d_inner = 2*2560 = 5120, head_dim 64 -> 80 SSD heads, 1 B/C group,
conv width 4.  Tied embeddings (mamba convention).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    d_ff=0,
    vocab_size=50280,
    tie_embeddings=True,
    ssm_state=128,
    ssm_conv=4,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    max_seq_len=1_048_576,   # sub-quadratic: long_500k applies
)
