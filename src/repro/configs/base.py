"""Architecture configuration dataclass + shape registry.

Every assigned architecture is an ``ArchConfig`` instance in its own
module under ``repro.configs``; ``repro.configs.get_config(name)``
resolves them.  ``reduced()`` returns a CPU-smoke-test-sized config of
the same family (same code paths, tiny dims).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

DTYPE_BYTES = {"bfloat16": 2, "float32": 4, "float16": 2, "int8": 1, "int4": 0.5}


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    # identity
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio

    # backbone
    n_layers: int
    d_model: int
    n_heads: int = 0          # 0 for attention-free archs
    n_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0             # dense-FFN intermediate (0 for mamba2-pure)
    vocab_size: int = 32000

    # flavour flags
    qkv_bias: bool = False
    mlp_gated: bool = True           # SwiGLU (3 mats) vs plain (2 mats)
    norm: str = "rmsnorm"            # rmsnorm | nonparametric
    rope_theta: float = 1e4
    tie_embeddings: bool = False
    sliding_window: Optional[int] = None   # attention window cap (hybrid long-ctx)

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # per routed expert
    n_shared_experts: int = 0
    shared_d_ff: int = 0             # total shared-expert intermediate
    capacity_factor: float = 1.25
    router_type: str = "softmax_topk"  # softmax_topk | sigmoid_top1

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1              # number of B/C groups (like GQA for SSM)

    # hybrid (zamba2-style)
    attn_every: int = 0              # apply the shared attention block every N layers

    # modality frontends (stubs)
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl M-RoPE
    n_codebooks: int = 0             # musicgen EnCodec codebooks

    # numerics / limits
    dtype: str = "bfloat16"
    max_seq_len: int = 32768

    # ---------------- derived ----------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_head_dim else 0

    @property
    def conv_channels(self) -> int:
        # mamba2 conv runs over x + B + C streams
        return self.d_inner + 2 * self.ssm_groups * self.ssm_state

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM and hybrid (windowed attention)."""
        return self.family in ("ssm", "hybrid")

    @property
    def n_attn_layers(self) -> int:
        if self.family == "ssm":
            return 0
        if self.family == "hybrid":
            # shared attention applied at layers 0, attn_every, 2*attn_every, ...
            return (self.n_layers + self.attn_every - 1) // self.attn_every
        return self.n_layers

    @property
    def n_ssm_layers(self) -> int:
        if self.family == "ssm":
            return self.n_layers
        if self.family == "hybrid":
            return self.n_layers
        return 0

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests (one forward/train step)."""
        kw = dict(
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 2 if self.family != "hybrid" else 4),
            d_model=128,
            vocab_size=256,
            max_seq_len=128,
        )
        if self.n_heads:
            kw.update(n_heads=4, n_kv_heads=min(self.n_kv_heads, 2) or 2, head_dim=32)
            if self.mrope_sections:
                kw.update(mrope_sections=(4, 6, 6))   # sums to head_dim/2 = 16
        if self.d_ff:
            kw.update(d_ff=256)
        if self.n_experts:
            kw.update(n_experts=min(self.n_experts, 8), top_k=min(self.top_k, 2),
                      moe_d_ff=64,
                      shared_d_ff=64 if self.shared_d_ff else 0)
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=16)
        if self.attn_every:
            kw.update(attn_every=2)
        if self.sliding_window:
            kw.update(sliding_window=64)
        return self.replace(**kw)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One assigned input-shape cell."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeSpec("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeSpec("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeSpec("long_500k",   524_288, 1,   "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> bool:
    """long_500k needs sub-quadratic attention (see DESIGN.md §5)."""
    if shape.name == "long_500k":
        return cfg.supports_long_context
    return True
