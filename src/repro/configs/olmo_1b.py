"""OLMo-1B [arXiv:2402.00838; hf].

16L d_model=2048 16H (GQA kv=16) d_ff=8192 vocab=50304.
Non-parametric LayerNorm (no learned scale), tied embeddings.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=50304,
    norm="nonparametric",
    tie_embeddings=True,
    rope_theta=1e4,
)
