"""The paper's own three 7-8B GQA models (paper §3.3).

These exist to validate the floor model against the paper's Table 9 and
to run the paper-faithful benchmark suite; they are full members of the
registry (``--arch qwen2.5-7b`` etc.).

Paper-quoted weight footprints (decimal GB, bf16):
  Qwen-2.5-7B  W=15.23   Mistral-7B-v0.3  W=14.50   Llama-3.1-8B  W=16.06
and per-token KV bytes for Qwen-2.5-7B: 2*28*4*128*2 = 56 KB.
Unit tests assert our exact param arithmetic reproduces these.
"""
from repro.configs.base import ArchConfig

QWEN25_7B = ArchConfig(
    name="qwen2.5-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    head_dim=128,
    d_ff=18944,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1e6,
)

MISTRAL_7B = ArchConfig(
    name="mistral-7b-v0.3",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32768,
    rope_theta=1e6,
)

LLAMA31_8B = ArchConfig(
    name="llama-3.1-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=5e5,
)
