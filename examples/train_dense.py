"""Train a small dense LM for a few hundred steps on the learnable
synthetic stream, with a mid-run simulated preemption + restart — the
fault-tolerance path exercised end to end.

    PYTHONPATH=src python examples/train_dense.py [--steps 200]
"""
import argparse
import sys
import tempfile

import jax

sys.path.insert(0, "src")

from repro.configs import get_config  # noqa: E402
from repro.models import Model  # noqa: E402
from repro.training import (AdamW, DataLoader, Preemption,  # noqa: E402
                            cosine_schedule, jit_train_step, make_train_step,
                            run_training)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    cfg = get_config("olmo-1b").reduced().replace(
        d_model=192, d_ff=384, n_layers=4, vocab_size=512)
    model = Model(cfg)
    opt = AdamW(lr=cosine_schedule(3e-3, 20, args.steps))
    step = jit_train_step(make_train_step(model, opt, remat="blocks"))

    def init_state():
        params = model.init(jax.random.PRNGKey(0))
        return (params, opt.init(params))

    loader = DataLoader(cfg, batch=16, seq_len=64, seed=3, mode="arith")

    armed = {"on": True}

    def preempt_once(s):
        if s == args.steps // 2 and armed["on"]:
            armed["on"] = False
            print(f"  !! simulated preemption at step {s} — restarting "
                  f"from latest checkpoint")
            raise Preemption(s)

    with tempfile.TemporaryDirectory() as ckpt:
        res = run_training(train_step=step, init_state=init_state,
                           loader=loader, ckpt_dir=ckpt,
                           total_steps=args.steps, ckpt_every=25,
                           failure_hook=preempt_once)
    losses = [h["loss"] for h in res.metrics_history]
    print(f"steps={res.step} restarts={res.restarts}")
    print(f"loss: start {losses[0]:.3f} -> end {losses[-1]:.3f}")
    assert losses[-1] < losses[0], "loss should fall on the arith stream"
    print("OK")


if __name__ == "__main__":
    main()
