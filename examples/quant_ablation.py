"""The paper's §7 experiment on our stack: quantisation paths compared
on (a) analytic HBM traffic — the claim that transfers to TPU — and
(b) live decode on a reduced model.

    PYTHONPATH=src python examples/quant_ablation.py
"""
import sys

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.configs import get_config  # noqa: E402
from repro.core import floor as fl, stats  # noqa: E402
from repro.core.hardware import TPU_V5E  # noqa: E402
from repro.models import Model  # noqa: E402
from repro.serving import DecodeEngine  # noqa: E402
from repro.quant import WEIGHT_PATHS, quantize_tree, tree_weight_traffic  # noqa: E402


def main():
    cfg = get_config("qwen2.5-3b").reduced().replace(vocab_size=1024)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (1, 16),
                                           0, cfg.vocab_size)}
    base_traffic = tree_weight_traffic(params)

    print(f"{'path':14s} {'traffic':>9s} {'vs bf16':>8s} {'cpu p50':>9s} "
          f"{'v5e floor (full arch)':>22s}")
    full = get_config("qwen2.5-3b")
    for path in WEIGHT_PATHS:
        traffic = tree_weight_traffic(quantize_tree(params, path, group=32))
        eng = DecodeEngine(model, params, quant_path=path)
        res = eng.generate_streamed(prompt, max_len=64, n_new=16, timed=True)
        p50 = stats.p50(res.step_times_s) * 1e3
        wb = {"bf16": 2, "int8_dequant": 3, "int8_fused": 1,
              "int4_dequant": 2.5, "int4_fused": 0.5}[path]
        cell = fl.floor_cell(full, TPU_V5E, 2048, weight_dtype_bytes=wb)
        print(f"{path:14s} {traffic/1e6:7.2f}MB {traffic/base_traffic:7.2f}x "
              f"{p50:7.2f}ms {cell.t_floor_ms:18.2f}ms")
    print("\nthe paper's lesson: *_dequant streams MORE than bf16 — only "
          "the fused kernel paths realise the bandwidth saving.")


if __name__ == "__main__":
    main()
