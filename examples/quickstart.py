"""Quickstart: build any assigned architecture, run a forward pass, a
train step, and a few decode steps — all on CPU with reduced configs.

    PYTHONPATH=src python examples/quickstart.py [--arch qwen2-moe-a2.7b]
"""
import argparse
import sys

import jax

sys.path.insert(0, "src")

from repro.configs import get_config, list_configs  # noqa: E402
from repro.core import floor as fl  # noqa: E402
from repro.core.hardware import TPU_V5E  # noqa: E402
from repro.models import Model  # noqa: E402
from repro.serving import DecodeEngine  # noqa: E402
from repro.training import (AdamW, DataLoader, jit_train_step,  # noqa: E402
                            make_train_step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-moe-a2.7b",
                    choices=list_configs())
    args = ap.parse_args()

    full = get_config(args.arch)
    cfg = full.reduced()
    print(f"arch={full.name} family={full.family} "
          f"params={fl.param_count(full)/1e9:.2f}B "
          f"active={fl.active_param_count(full)/1e9:.2f}B")
    cell = fl.floor_cell(full, TPU_V5E, 2048)
    print(f"v5e batch-1 decode floor @ctx=2048: {cell.t_floor_ms:.2f} ms "
          f"(the paper's t_floor=(W+K)/B_peak)")

    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # one train step
    opt = AdamW(lr=1e-3)
    loader = DataLoader(cfg, batch=4, seq_len=32, mode="arith")
    step = jit_train_step(make_train_step(model, opt))
    state = (params, opt.init(params))
    state, metrics = step(state, next(loader))
    print(f"train step: loss={float(metrics['loss']):.3f} "
          f"grad_norm={float(metrics['grad_norm']):.2f}")

    # a few decode steps (reduced config, CPU)
    if cfg.family != "vlm":
        engine = DecodeEngine(model, state[0])
        prompt = next(loader)
        prompt.pop("labels")
        res = engine.generate_streamed(prompt, max_len=96, n_new=8, timed=True)
        print(f"decode: generated {res.tokens.shape[1]} tokens/seq, "
              f"{res.tokens_per_s:.1f} tok/s (reduced model, CPU)")
        print("tokens:", res.tokens[0].tolist())
    print("OK")


if __name__ == "__main__":
    main()
