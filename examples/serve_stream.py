"""END-TO-END SERVING DRIVER (the paper's workload): serve a small model
with batched requests through the DecodeEngine — prefill + streaming
decode with per-step timing, quantised weight paths, and the
dispatch-mode A/B on the live engine.

    PYTHONPATH=src python examples/serve_stream.py
"""
import sys
import time

import jax
import jax.numpy as jnp

sys.path.insert(0, "src")

from repro.configs import get_config  # noqa: E402
from repro.core import stats  # noqa: E402
from repro.models import Model  # noqa: E402
from repro.serving import DecodeEngine  # noqa: E402


def main():
    cfg = get_config("qwen2.5-3b").reduced().replace(
        d_model=256, d_ff=512, n_layers=8, vocab_size=2048)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)

    # --- batched request serving -------------------------------------
    print("== batched sessions (8 concurrent streams) ==")
    engine = DecodeEngine(model, params)
    prompts = {"tokens": jax.random.randint(key, (8, 24), 0, cfg.vocab_size)}
    t0 = time.perf_counter()
    res = engine.generate_streamed(prompts, max_len=128, n_new=32, timed=True)
    dt = time.perf_counter() - t0
    print(f"  8 streams x 32 tokens in {dt:.2f}s "
          f"({8 * 32 / dt:.0f} tok/s aggregate)")
    print(f"  per-step p50 {stats.p50(res.step_times_s)*1e3:.2f} ms")

    # --- batch-1 latency: the paper's metric --------------------------
    print("== batch-1 streaming (per-token latency) ==")
    one = {"tokens": prompts["tokens"][:1]}
    for quant in ("bf16", "int8_fused", "int4_fused"):
        eng = DecodeEngine(model, params, quant_path=quant)
        r = eng.generate_streamed(one, max_len=128, n_new=24, timed=True)
        print(f"  {quant:11s} p50 step {stats.p50(r.step_times_s)*1e3:.2f} ms")

    # --- fused-loop generation (beyond CUDA Graphs) --------------------
    print("== whole-generation fused loop (one XLA program) ==")
    r_stream = engine.generate_streamed(one, max_len=128, n_new=32)
    t0 = time.perf_counter()
    r_fused = engine.generate_fused(one, max_len=128, n_new=32)
    print(f"  fused: {r_fused.tokens_per_s:.0f} tok/s; greedy tokens equal: "
          f"{bool(jnp.array_equal(r_fused.tokens, r_stream.tokens))}")
    print("OK")


if __name__ == "__main__":
    main()
