#!/usr/bin/env bash
# CI gate: lint + tier-1 test suite + serving smokes + quick table
# sweeps, named and timed, grouped into three parallelisable stage
# groups (the GitHub Actions matrix runs one group per job).
#
# Usage:
#   bash scripts/ci.sh                  # full pipeline (all groups)
#   bash scripts/ci.sh --fast           # lint + tier-1 only (pre-push)
#   bash scripts/ci.sh --stage tests    # one group (what a matrix job runs)
#
# Groups:
#   tests   lint           ruff check (skipped with a notice when ruff
#                          isn't installed — CI always installs it via
#                          requirements.txt)
#           staticcheck    the repo's own AST invariant linter
#                          (python -m repro.analysis.staticcheck) over
#                          src/ — hot-path syncs, recompile hazards,
#                          donation misuse, PRNG reuse, page-refcount
#                          pairing; unused suppressions and
#                          non-baselined findings fail; writes
#                          staticcheck.json (uploaded as an artifact)
#           tier1          pytest suite minus slow-marked soaks
#                          (ROADMAP "tier-1 verify")
#           soak           the slow-marked property soaks (hypothesis
#                          runs them at full example counts when
#                          installed)
#   smokes  smoke-continuous  continuous-batching serve (slotted cache)
#           smoke-paged       paged serve: oversubscribed pool +
#                             chunked prefill
#           smoke-paged-fused paged serve through the fused Pallas
#                             block-table kernel (--decode-backend
#                             pallas; interpret on CPU)
#           smoke-horizon     horizon-K fused macro-ticks
#                             (--steps-per-tick 4): continuous + paged
#           smoke-prefix      paged serve with --prefix-cache on
#                             sessions sharing a page-aligned preamble
#           smoke-trace       trace-driven load replay (--trace bursty)
#                             with adaptive horizon-K + SLO report
#           smoke-tier        paged serve with the host-DRAM KV tier
#                             under a preemption-forcing pool
#           smoke-quant       the fully quantised serving stack: int8
#                             KV pages + int4 weights on both paged
#                             routes, incl. through the host tier
#           smoke-chaos       trace replay under a mixed seeded fault
#                             plan (--fault-plan mixed) through the
#                             host tier, both decode routes — retries,
#                             quarantines and aborts must serve to
#                             completion with clean recovery accounting
#   tables  table10-quick ... table16-quick
#                          quick benchmark sweeps; each --json run
#                          leaves a bench_table*.json that CI uploads
#                          as an artifact (exit 3 = a table's inline
#                          assertion tripped, 1 = crash)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

FAST=0
GROUP=all
while [ $# -gt 0 ]; do
    case "$1" in
        --fast) FAST=1 ;;
        --stage)
            shift
            GROUP="${1:?--stage requires a group (tests|smokes|tables)}" ;;
        --stage=*) GROUP="${1#--stage=}" ;;
        *) echo "unknown argument: $1" >&2; exit 2 ;;
    esac
    shift
done
case "$GROUP" in
    all|tests|smokes|tables) ;;
    *) echo "unknown stage group: $GROUP (tests|smokes|tables)" >&2
       exit 2 ;;
esac

stage() {
    local name="$1"; shift
    echo "== stage: $name =="
    local t0=$SECONDS
    "$@"
    echo "== stage: $name ok ($((SECONDS - t0))s) =="
}

run_tests() {
    if command -v ruff >/dev/null 2>&1; then
        stage lint ruff check .
    else
        echo "== stage: lint skipped (ruff not installed) =="
    fi

    stage staticcheck \
        python -m repro.analysis.staticcheck src --json staticcheck.json

    stage tier1 python -m pytest -x -q -m "not slow"

    if [ "$FAST" = 1 ]; then
        echo "== ci green (--fast: lint + tier-1 only) =="
        exit 0
    fi

    stage soak python -m pytest -x -q -m slow
}

run_smokes() {
    stage smoke-continuous \
        python -m repro.launch.serve --arch qwen2.5-3b --reduced \
            --continuous --slots 3 --sessions 6 --prompt-len 8 \
            --new-tokens 6 --timed

    stage smoke-paged \
        python -m repro.launch.serve --arch qwen2.5-3b --reduced --paged \
            --slots 3 --sessions 6 --prompt-len 8 --new-tokens 6 \
            --page-size 8 --pages 9 --prefill-chunk 8 --timed

    stage smoke-paged-fused \
        python -m repro.launch.serve --arch qwen2.5-3b --reduced --paged \
            --decode-backend pallas --slots 3 --sessions 6 --prompt-len 8 \
            --new-tokens 6 --page-size 8 --pages 9 --timed

    stage smoke-horizon bash -c "
        python -m repro.launch.serve --arch qwen2.5-3b --reduced --continuous \
            --slots 3 --sessions 6 --prompt-len 8 --new-tokens 6 \
            --steps-per-tick 4 --timed &&
        python -m repro.launch.serve --arch qwen2.5-3b --reduced --paged \
            --slots 3 --sessions 6 --prompt-len 8 --new-tokens 6 \
            --page-size 8 --pages 9 --steps-per-tick 4 --timed"

    stage smoke-prefix \
        python -m repro.launch.serve --arch qwen2.5-3b --reduced --paged \
            --prefix-cache --slots 3 --sessions 6 --prompt-len 6 \
            --shared-prefix 16 --new-tokens 6 --page-size 8 --timed

    stage smoke-trace \
        python -m repro.launch.serve --arch qwen2.5-3b --reduced --paged \
            --trace bursty --sessions 8 --slots 3 --page-size 8 \
            --steps-per-tick 8 --adaptive-k

    stage smoke-tier \
        python -m repro.launch.serve --arch qwen2.5-3b --reduced --paged \
            --kv-tier host --tier-policy spill --slots 2 --sessions 6 \
            --prompt-len 8 --new-tokens 8 --page-size 4 --pages 10 \
            --host-pages 8 --prefill-chunk 4 --timed

    stage smoke-quant bash -c "
        python -m repro.launch.serve --arch qwen2.5-3b --reduced --paged \
            --kv-quant int8 --weights int4 --slots 3 --sessions 6 \
            --prompt-len 8 --new-tokens 6 --page-size 8 --pages 9 \
            --timed &&
        python -m repro.launch.serve --arch qwen2.5-3b --reduced --paged \
            --decode-backend pallas --kv-quant int8 --weights int4 \
            --slots 3 --sessions 6 --prompt-len 8 --new-tokens 6 \
            --page-size 8 --pages 9 --timed &&
        python -m repro.launch.serve --arch qwen2.5-3b --reduced --paged \
            --kv-quant int8 --kv-tier host --tier-policy spill --slots 2 \
            --sessions 6 --prompt-len 8 --new-tokens 8 --page-size 4 \
            --pages 10 --host-pages 8 --prefill-chunk 4 --timed"

    stage smoke-chaos bash -c "
        python -m repro.launch.serve --arch qwen2.5-3b --reduced --paged \
            --trace bursty --sessions 8 --slots 2 --page-size 4 \
            --pages 14 --prefill-chunk 4 --kv-tier host \
            --tier-policy spill --host-pages 28 --fault-plan mixed \
            --chaos-seed 7 &&
        python -m repro.launch.serve --arch qwen2.5-3b --reduced --paged \
            --decode-backend pallas --trace bursty --sessions 8 --slots 2 \
            --page-size 4 --pages 14 --prefill-chunk 4 --kv-tier host \
            --tier-policy spill --host-pages 28 --fault-plan mixed \
            --chaos-seed 7"
}

run_tables() {
    stage table10-quick python -m benchmarks.run --quick --only=table10

    stage table11-quick \
        python -m benchmarks.run --quick --only=table11 \
            --json bench_table11.json

    stage table12-quick \
        python -m benchmarks.run --quick --only=table12 \
            --json bench_table12.json

    stage table13-quick \
        python -m benchmarks.run --quick --only=table13 \
            --json bench_table13.json

    stage table14-quick \
        python -m benchmarks.run --quick --only=table14 \
            --json bench_table14.json

    stage table15-quick \
        python -m benchmarks.run --quick --only=table15 \
            --json bench_table15.json

    stage table16-quick \
        python -m benchmarks.run --quick --only=table16 \
            --json bench_table16.json
}

case "$GROUP" in
    tests)  run_tests ;;
    smokes) run_smokes ;;
    tables) run_tables ;;
    all)    run_tests; run_smokes; run_tables ;;
esac

echo "== ci green ($GROUP) =="
