#!/usr/bin/env bash
# CI gate: tier-1 test suite + serving smoke stages, named and timed.
#
# Usage:
#   bash scripts/ci.sh           # full staged pipeline (what CI runs)
#   bash scripts/ci.sh --fast    # tier-1 only (pre-push gate)
#
# Stages (each individually timed; first failure aborts, nonzero exit):
#   tier1             pytest suite minus slow-marked soaks
#                     (ROADMAP "tier-1 verify")
#   soak              the slow-marked property soaks (hypothesis runs
#                     them at full example counts when installed)
#   smoke-continuous  continuous-batching serve (slotted cache)
#   smoke-paged       paged serve: oversubscribed pool + chunked prefill
#   smoke-paged-fused paged serve through the fused Pallas block-table
#                     kernel (--decode-backend pallas; interpret on CPU)
#   smoke-horizon     horizon-K fused macro-ticks (--steps-per-tick 4):
#                     continuous + paged serve, K decode steps per
#                     compiled dispatch
#   smoke-prefix      paged serve with --prefix-cache on sessions
#                     sharing a page-aligned prompt preamble (prefill
#                     skipped for matched pages, CoW before any shared
#                     write)
#   table10-quick     paged sweep incl. fused-vs-gather token identity
#                     (benchmarks/run.py exits nonzero on any failure)
#   table11-quick     launch-overhead A/B: horizon-K amortisation >= K
#                     across contiguous/paged-gather/paged-pallas, with
#                     the --json results file exercised
#   table12-quick     prefix-sharing A/B: prefill tokens reduced >= the
#                     shared-prefix fraction, token identity, free-list
#                     balance (gather + pallas routes)
#   smoke-trace       trace-driven load replay (--trace bursty) with
#                     adaptive horizon-K and the per-class SLO report
#   smoke-tier        paged serve with the host-DRAM KV tier
#                     (--kv-tier host) through a pool small enough to
#                     force preemption, so parks/restores actually run
#   table13-quick     SLO metrics under Poisson + bursty traces on both
#                     paged routes: TTFT/TPOT percentiles,
#                     goodput-under-SLO, adaptive-K >= best fixed-K on
#                     the bursty trace, token identity vs the
#                     fixed-K/FIFO baseline
#   table14-quick     host-tier A/B: per-policy token identity vs the
#                     single-tier baseline, spill arms migrate and cut
#                     re-prefill work, device + host pools balance
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

FAST=0
for arg in "$@"; do
    case "$arg" in
        --fast) FAST=1 ;;
        *) echo "unknown argument: $arg" >&2; exit 2 ;;
    esac
done

stage() {
    local name="$1"; shift
    echo "== stage: $name =="
    local t0=$SECONDS
    "$@"
    echo "== stage: $name ok ($((SECONDS - t0))s) =="
}

stage tier1 python -m pytest -x -q -m "not slow"

if [ "$FAST" = 1 ]; then
    echo "== ci green (--fast: tier-1 only) =="
    exit 0
fi

stage soak python -m pytest -x -q -m slow

stage smoke-continuous \
    python -m repro.launch.serve --arch qwen2.5-3b --reduced --continuous \
        --slots 3 --sessions 6 --prompt-len 8 --new-tokens 6 --timed

stage smoke-paged \
    python -m repro.launch.serve --arch qwen2.5-3b --reduced --paged \
        --slots 3 --sessions 6 --prompt-len 8 --new-tokens 6 \
        --page-size 8 --pages 9 --prefill-chunk 8 --timed

stage smoke-paged-fused \
    python -m repro.launch.serve --arch qwen2.5-3b --reduced --paged \
        --decode-backend pallas --slots 3 --sessions 6 --prompt-len 8 \
        --new-tokens 6 --page-size 8 --pages 9 --timed

stage smoke-horizon bash -c "
    python -m repro.launch.serve --arch qwen2.5-3b --reduced --continuous \
        --slots 3 --sessions 6 --prompt-len 8 --new-tokens 6 \
        --steps-per-tick 4 --timed &&
    python -m repro.launch.serve --arch qwen2.5-3b --reduced --paged \
        --slots 3 --sessions 6 --prompt-len 8 --new-tokens 6 \
        --page-size 8 --pages 9 --steps-per-tick 4 --timed"

stage smoke-prefix \
    python -m repro.launch.serve --arch qwen2.5-3b --reduced --paged \
        --prefix-cache --slots 3 --sessions 6 --prompt-len 6 \
        --shared-prefix 16 --new-tokens 6 --page-size 8 --timed

stage table10-quick python -m benchmarks.run --quick --only=table10

stage table11-quick \
    python -m benchmarks.run --quick --only=table11 --json bench_table11.json

stage table12-quick \
    python -m benchmarks.run --quick --only=table12 --json bench_table12.json

stage smoke-trace \
    python -m repro.launch.serve --arch qwen2.5-3b --reduced --paged \
        --trace bursty --sessions 8 --slots 3 --page-size 8 \
        --steps-per-tick 8 --adaptive-k

stage smoke-tier \
    python -m repro.launch.serve --arch qwen2.5-3b --reduced --paged \
        --kv-tier host --tier-policy spill --slots 2 --sessions 6 \
        --prompt-len 8 --new-tokens 8 --page-size 4 --pages 10 \
        --host-pages 8 --prefill-chunk 4 --timed

stage table13-quick \
    python -m benchmarks.run --quick --only=table13 --json bench_table13.json

stage table14-quick \
    python -m benchmarks.run --quick --only=table14 --json bench_table14.json

echo "== ci green =="
