#!/usr/bin/env bash
# CI gate: tier-1 test suite + a continuous-batching serve smoke run.
# Usage: bash scripts/ci.sh   (from the repo root; exits nonzero on failure)
set -euo pipefail
cd "$(dirname "$0")/.."

export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
export JAX_PLATFORMS="${JAX_PLATFORMS:-cpu}"

echo "== tier-1: pytest =="
python -m pytest -x -q

echo "== smoke: continuous-batching serve =="
python -m repro.launch.serve --arch qwen2.5-3b --reduced --continuous \
    --slots 3 --sessions 6 --prompt-len 8 --new-tokens 6 --timed

echo "== smoke: paged KV serve (oversubscribed, chunked prefill) =="
python -m repro.launch.serve --arch qwen2.5-3b --reduced --paged \
    --slots 3 --sessions 6 --prompt-len 8 --new-tokens 6 \
    --page-size 8 --pages 9 --prefill-chunk 8 --timed

echo "== smoke: paged KV sweep (table10 --quick) =="
python -m benchmarks.run --quick --only=table10

echo "== ci green =="
