import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Perf hillclimb driver (assignment §Perf).

For a chosen (arch x shape x mesh) cell: compile the baseline policy and
each candidate policy, derive the three roofline terms from the compiled
artifact, and append hypothesis -> change -> before -> after ->
confirmed/refuted records to results/perf/<cell>.json.

  PYTHONPATH=src python scripts/hillclimb.py olmo-1b train_4k pod \
      '{"strategy": "dp"}' "DP-only layout kills per-block ARs"
"""
import json  # noqa: E402
import sys  # noqa: E402

from repro.analysis.roofline import build_row  # noqa: E402
from repro.launch.dryrun import build_cell  # noqa: E402


def terms(cell):
    r = build_row(cell)
    return {"compute_ms": r.compute_t * 1e3, "memory_ms": r.memory_t * 1e3,
            "collective_ms": r.collective_t * 1e3, "dominant": r.dominant,
            "step_floor_ms": r.step_t * 1e3,
            "fits_v5e": cell["memory"]["fits_v5e"],
            "per_chip_GB": cell["memory"]["per_chip_bytes"] / 1e9}


def main():
    arch, shape, mesh = sys.argv[1:4]
    overrides = json.loads(sys.argv[4]) if len(sys.argv) > 4 else {}
    hypothesis = sys.argv[5] if len(sys.argv) > 5 else ""

    os.makedirs("results/perf", exist_ok=True)
    log_path = f"results/perf/{arch}__{shape}__{mesh}.json"
    log = []
    if os.path.exists(log_path):
        with open(log_path) as f:
            log = json.load(f)

    base = build_cell(arch, shape, mesh)
    before = terms(base)
    print("baseline:", json.dumps(before, indent=1))
    if overrides:
        treated = build_cell(arch, shape, mesh, overrides)
        after = terms(treated)
        print("treated :", json.dumps(after, indent=1))
        dom = before["dominant"]
        delta = before[f"{dom}_ms"] - after[f"{dom}_ms"]
        rel = delta / before[f"{dom}_ms"]
        if not after["fits_v5e"]:
            verdict = "refuted(oom)"
        else:
            verdict = "confirmed" if rel > 0.05 else \
                ("neutral" if rel > -0.05 else "refuted")
        rec = {"hypothesis": hypothesis, "change": overrides,
               "before": before, "after": after,
               "dominant_term_delta_ms": delta,
               "dominant_term_rel_improvement": rel,
               "verdict": verdict}
        log.append(rec)
        with open(log_path, "w") as f:
            json.dump(log, f, indent=1)
        print(f"\n{verdict.upper()}: {dom} term {before[f'{dom}_ms']:.2f} -> "
              f"{after[f'{dom}_ms']:.2f} ms ({rel*100:+.1f}%)")


if __name__ == "__main__":
    main()
