"""Merge per-table ``bench_table*.json`` harness reports into one
``BENCH_<date>.json`` perf-trajectory snapshot.

The CI matrix's table jobs each leave ``bench_table*.json`` files
(uploaded as artifacts); this script folds any number of them — or a
directory of downloaded artifacts — into a single dated snapshot whose
shape mirrors the harness report (one entry per table with status, wall
seconds, and the emitted rows), so successive snapshots diff cleanly
across PRs.

Usage:
    python scripts/bench_trajectory.py [paths...] [--date YYYY-MM-DD]
                                       [--out DIR]

With no paths, globs ``bench_table*.json`` in the repo root.  Paths may
be files or directories (searched recursively, the artifact-download
layout).  Exits 2 when nothing matches — an empty snapshot would read
as "no regressions" in a trajectory diff.
"""
from __future__ import annotations

import argparse
import datetime
import glob
import json
import os
import sys


def collect(paths):
    files = []
    for p in paths:
        if os.path.isdir(p):
            files.extend(sorted(glob.glob(
                os.path.join(p, "**", "bench_table*.json"),
                recursive=True)))
        else:
            files.append(p)
    return files


def merge(files):
    out = {"tables": {}, "sources": {}, "failed": []}
    for path in sorted(files):
        with open(path) as f:
            report = json.load(f)
        for name, entry in report.get("tables", {}).items():
            prev = out["sources"].get(name)
            if prev is not None:
                print(f"# note: {name} in both {prev} and {path}; "
                      f"keeping {path}", file=sys.stderr)
            out["tables"][name] = entry
            out["sources"][name] = path
        out["quick"] = report.get("quick", out.get("quick"))
        for name in report.get("failed", []):
            if name not in out["failed"]:
                out["failed"].append(name)
    return out


def main() -> int:
    ap = argparse.ArgumentParser(
        description="merge bench_table*.json into BENCH_<date>.json")
    ap.add_argument("paths", nargs="*",
                    help="report files or artifact directories "
                         "(default: bench_table*.json in the repo root)")
    ap.add_argument("--date", default=None,
                    help="snapshot date (default: today, UTC)")
    ap.add_argument("--out", default=".",
                    help="directory to write BENCH_<date>.json into")
    args = ap.parse_args()

    files = collect(args.paths or glob.glob("bench_table*.json"))
    if not files:
        print("# no bench_table*.json found — nothing to merge",
              file=sys.stderr)
        return 2
    snapshot = merge(files)
    date = args.date or datetime.datetime.now(
        datetime.timezone.utc).strftime("%Y-%m-%d")
    snapshot["date"] = date
    dest = os.path.join(args.out, f"BENCH_{date}.json")
    with open(dest, "w") as f:
        json.dump(snapshot, f, indent=2, allow_nan=False)
    n_rows = sum(len(t.get("rows", [])) for t in snapshot["tables"].values())
    print(f"# wrote {dest}: {len(snapshot['tables'])} table(s), "
          f"{n_rows} row(s) from {len(files)} report(s)")
    return 1 if snapshot["failed"] else 0


if __name__ == "__main__":
    sys.exit(main())
