"""Table 13 (extension): SLO metrics under trace-driven load —
TTFT / per-token latency percentiles / goodput-under-SLO, fixed-K FIFO
vs adaptive-K + priority preemption.

Every serving table so far feeds the scheduler a lockstep wave:
everything arrives at once, nothing queues, and aggregate tok/s is the
only number.  The paper's point is that the *session* feels per-token
latency — launch overhead and scheduling slack only surface under
realistic arrivals.  This table replays seeded traces (Poisson and
bursty on/off arrivals, two session classes: a high-priority
``interactive`` class with tight SLOs and a low-priority ``batch``
class with loose ones) through the paged scheduler on both decode
routes (gather+SDPA and fused Pallas) and reports, per arm:

  * TTFT p50/p95/p99 and per-token latency p50/p95/p99 on the
    scheduler's deterministic virtual clock (``virtual_dispatch_s``
    launch tax per dispatched program + ``virtual_step_s`` per device
    step), so rows are machine-independent and reproducible;
  * goodput-under-SLO: tokens of sessions that met BOTH their class's
    TTFT and per-token bounds, per virtual second of makespan — the
    number a capacity planner actually quotes;
  * the horizon histogram of the adaptive arm (which rungs the policy
    actually dispatched).

Arms: fixed K in {1, .., K_MAX} with the youngest-first preemption
baseline (FIFO arm), then adaptive-K (ladder K_MAX..1) with
priority-aware preemption.  Asserted per route:

  * greedy token identity of EVERY arm against the fixed-K=1/FIFO
    baseline, per session — policy changes schedules, never streams;
  * on the bursty trace, adaptive-K goodput >= the best fixed-K
    goodput (the acceptance bar: reacting to queue depth must not cost
    capacity against ANY static setting).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, header
from repro.configs import get_config
from repro.models import Model
from repro.serving import SessionClass, SlotScheduler, generate_trace, slo_report
from repro.serving.trace import bursty_config, poisson_config

SLOTS = 3
PAGE = 8
K_MAX = 8
FIXED_KS = (1, 4, 8)
FIXED_KS_QUICK = (1, 8)
# classes tuned to the virtual cost model (step 1 ms, dispatch 4 ms):
# interactive wants its first token within ~3 dispatch quanta — tight
# enough that a long fixed macro-tick blows it whenever a burst queues
# behind full slots — and tokens at a K>=2 cadence; batch tolerates
# an order of magnitude more on both.
CLASSES = (
    SessionClass("interactive", mix=0.6, priority=1,
                 prompt_lo=4, prompt_hi=12, new_lo=4, new_hi=10,
                 slo_ttft_s=0.015, slo_tpot_s=0.012),
    SessionClass("batch", mix=0.4, priority=0,
                 prompt_lo=12, prompt_hi=24, new_lo=8, new_hi=16,
                 slo_ttft_s=0.240, slo_tpot_s=0.048),
)


def _cfg():
    return get_config("qwen2.5-3b").reduced().replace(
        vocab_size=512, d_model=64, d_ff=128, n_layers=2,
        n_heads=4, n_kv_heads=2, head_dim=16, dtype="float32")


def _traces(cfg, quick):
    n = 10 if quick else 24
    kw = dict(n_requests=n, vocab_size=cfg.vocab_size, classes=CLASSES)
    return (("poisson", generate_trace(poisson_config(
                seed=13, rate_rps=25.0, **kw))),
            ("bursty", generate_trace(bursty_config(
                seed=13, rate_rps=25.0, burst_len=5, burst_factor=10.0,
                **kw))))


def _replay(model, params, trace, *, max_len, n_pages, **kw):
    # shared_programs: every arm reuses the model-level compiled
    # executables — without it each fresh scheduler recompiles the
    # whole prefill/decode set and the sweep is a compile benchmark
    sched = SlotScheduler(model, params, n_slots=SLOTS, max_len=max_len,
                          paged=True, page_size=PAGE, n_pages=n_pages,
                          timed=False, shared_programs=True, **kw)
    for r in trace.requests:
        sched.submit(r)
    res = sched.run()
    assert res.arrivals == len(trace.requests), "trace not fully replayed"
    return res


def _fields(rep, res):
    return (f"ttft_p50={rep['ttft']['p50']:.4f} "
            f"ttft_p95={rep['ttft']['p95']:.4f} "
            f"ttft_p99={rep['ttft']['p99']:.4f} "
            f"tpot_p50={rep['tpot']['p50']:.5f} "
            f"tpot_p95={rep['tpot']['p95']:.5f} "
            f"tpot_p99={rep['tpot']['p99']:.5f} "
            f"goodput={rep['goodput_tok_s']:.2f} "
            f"slo_frac={rep['slo_frac']:.3f} "
            f"makespan_s={rep['makespan_s']:.4f} "
            f"preemptions={res.preemptions} "
            f"dispatches={res.dispatches}")


def run(quick: bool = False) -> None:
    header("table13: SLO metrics under trace-driven load — fixed-K/FIFO "
           "vs adaptive-K + priority preemption (paged gather / pallas)")
    cfg = _cfg()
    params = Model(cfg).init(jax.random.PRNGKey(0))
    fixed_ks = FIXED_KS_QUICK if quick else FIXED_KS
    routes = (("gather", Model(cfg)),
              ("pallas", Model(cfg, decode_backend="pallas")))
    for route, model in routes:
        for tname, trace in _traces(cfg, quick):
            max_len = trace.max_len() + 1
            # a pool below full backing so bursts contend for pages and
            # the preemption policy actually decides something
            full = 1 + SLOTS * (-(-max_len // PAGE))
            n_pages = max(2 + (full - 1) * 2 // 3,
                          2 + -(-max_len // PAGE))
            base = None
            goodputs = {}
            for K in fixed_ks:
                res = _replay(model, params, trace, max_len=max_len,
                              n_pages=n_pages, steps_per_tick=K,
                              priority_preemption=False)
                rep = slo_report(res, trace.classes)
                if base is None:
                    base = res
                else:
                    for r in trace.requests:
                        np.testing.assert_array_equal(
                            base.tokens_for(r.session_id),
                            res.tokens_for(r.session_id),
                            err_msg=f"{r.session_id} diverged at K={K} "
                                    f"({route}/{tname})")
                goodputs[f"K{K}"] = rep["goodput_tok_s"]
                emit(f"slo/{route}/{tname}/fixedK{K}",
                     rep["ttft"]["p95"] * 1e6,
                     f"{_fields(rep, res)} adaptive=False "
                     f"token_identical=True")
            res = _replay(model, params, trace, max_len=max_len,
                          n_pages=n_pages, steps_per_tick=K_MAX,
                          adaptive_k=True)
            rep = slo_report(res, trace.classes)
            for r in trace.requests:
                np.testing.assert_array_equal(
                    base.tokens_for(r.session_id),
                    res.tokens_for(r.session_id),
                    err_msg=f"{r.session_id} diverged under adaptive-K "
                            f"({route}/{tname})")
            goodputs["adaptive"] = rep["goodput_tok_s"]
            hist = ",".join(f"{k}:{v}" for k, v in
                            sorted(res.horizon_hist.items()))
            emit(f"slo/{route}/{tname}/adaptiveK{K_MAX}",
                 rep["ttft"]["p95"] * 1e6,
                 f"{_fields(rep, res)} adaptive=True k_hist={hist} "
                 f"token_identical=True")
            best_fixed = max(v for k, v in goodputs.items()
                             if k != "adaptive")
            emit(f"slo/{route}/{tname}/summary",
                 rep["goodput_tok_s"],
                 f"goodput_adaptive={goodputs['adaptive']:.2f} "
                 f"goodput_best_fixed={best_fixed:.2f} "
                 f"adaptive_vs_best={goodputs['adaptive'] / best_fixed:.3f}")
            if tname == "bursty":
                # the acceptance bar: reacting to the queue must not
                # cost goodput against any static horizon
                assert goodputs["adaptive"] >= best_fixed, (
                    f"{route}/{tname}: adaptive goodput "
                    f"{goodputs['adaptive']:.2f} below best fixed "
                    f"{best_fixed:.2f} ({goodputs})")


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv)
