"""Table 14 (extension): the host-DRAM KV page tier under preemption
churn — re-admission cost, pages migrated, goodput, token identity.

The paged scheduler's answer to page pressure is preemption, and the
single-tier cost of preemption is *recompute*: the victim's KV is
destroyed and re-admission re-prefills prompt + generated prefix from
scratch.  The host tier turns that recompute into *page migration* —
preemption parks full KV pages in host DRAM, re-admission copies them
back and re-prefills only the partial tail — which is the right trade
exactly when a batched device<->host page copy is cheaper than the
chunked re-prefill it replaces.  The virtual cost model makes that
trade explicit (``virtual_host_copy_s`` per migrated page vs a launch
tax + service quantum per re-prefill chunk), so rows are
machine-independent.

Wave A (identity + balance): an all-at-once session wave through a
pool small enough to force preemption churn, on both decode routes
(gather+SDPA and fused Pallas).  Arms: single-tier baseline, then the
host tier under each policy (prefer-device control / spill /
lookahead).  Asserted per route:

  * the baseline really preempts (otherwise the table measures nothing);
  * greedy token identity of EVERY tier arm against the single-tier
    baseline, per session — placement policy changes copies, never
    streams;
  * the spill arms actually migrate (pages_spilled > 0 and
    tier_restores > 0) while the prefer-device control migrates nothing
    and re-prefills exactly like the baseline;
  * memory balance at the end: every device page back on the free list
    after a prefix flush, every host page released after the host
    flush (refcount/pool-balance accounting closes).

Wave B (load): the bursty two-class trace replayed tier-off vs
tier-on (spill).  Reports goodput-under-SLO, interactive-class TTFT
p95, preemptions, pages migrated; asserts token identity and that the
tier strictly reduces re-prefill work (prefill tokens dispatched)
whenever it restored anything — the mechanism by which re-admission
TTFT improves.  A third arm adds restore-gate patience
(``restore_patience=3``): the parked copy is held a few ticks instead
of being superseded by the smaller 1-chunk re-prefill gate the moment
the pool is tight, and realised restores must strictly improve while
every stream stays identical.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, header
from repro.configs import get_config
from repro.models import Model
from repro.serving import SessionRequest, SlotScheduler, generate_trace, slo_report
from repro.serving.trace import bursty_config

SLOTS = 2
PAGE = 4
CHUNK = 4            # makes re-prefill multi-dispatch, so restores can win
TIER_ARMS = ("prefer-device", "spill", "lookahead")


def _cfg():
    return get_config("qwen2.5-3b").reduced().replace(
        vocab_size=512, d_model=64, d_ff=128, n_layers=2,
        n_heads=4, n_kv_heads=2, head_dim=16, dtype="float32")


def _wave_requests(cfg, n):
    """Deterministic all-at-once wave sized to thrash a small pool:
    prompts of 2-4 pages, budgets long enough that resident sessions
    keep allocating decode pages under each other."""
    rng = np.random.RandomState(7)
    reqs = []
    for i in range(n):
        plen = 8 + 3 * (i % 3)            # 8, 11, 14
        n_new = 6 + 2 * (i % 3)           # 6, 8, 10
        prompt = rng.randint(0, cfg.vocab_size, size=plen).astype(np.int32)
        reqs.append(SessionRequest(f"s{i}", prompt, n_new))
    return reqs


def _serve_wave(model, params, reqs, *, max_len, n_pages, **kw):
    sched = SlotScheduler(model, params, n_slots=SLOTS, max_len=max_len,
                          paged=True, page_size=PAGE, n_pages=n_pages,
                          prefill_chunk=CHUNK, prefix_cache=True,
                          timed=False, shared_programs=True, **kw)
    for r in reqs:
        sched.submit(r)
    return sched, sched.run()


def _assert_identity(base, res, reqs, label):
    for r in reqs:
        np.testing.assert_array_equal(
            base.tokens_for(r.session_id), res.tokens_for(r.session_id),
            err_msg=f"{r.session_id} diverged under {label}")


def _wave_a(route, model, params, quick):
    reqs = _wave_requests(model.cfg, 5 if quick else 6)
    max_len = max(len(r.prompt) + r.max_new_tokens for r in reqs) + 1
    # well below full backing (1 + SLOTS*ceil(max_len/PAGE)): two
    # resident sessions cannot both hold their full footprint, and the
    # pressure also reclaims parked sessions' cached prefix pages while
    # they wait — which is what forces resumes through the restore path
    # instead of a full device prefix match
    n_pages = 1 + -(-max_len // PAGE)
    sched, base = _serve_wave(model, params, reqs,
                              max_len=max_len, n_pages=n_pages)
    assert base.preemptions > 0, (
        f"{route}: pool of {n_pages} pages never forced a preemption — "
        f"the tier A/B would measure nothing")
    sched.flush_prefix_cache()
    assert sched.store.allocator.n_free == n_pages - 1, "page leak (base)"
    emit(f"tier/{route}/wave/none", base.now_s * 1e6,
         f"preemptions={base.preemptions} "
         f"prefill_tokens={base.prefill_tokens} spilled=0 restored=0")
    for arm in TIER_ARMS:
        sched, res = _serve_wave(model, params, reqs,
                                 max_len=max_len, n_pages=n_pages,
                                 kv_tier="host", tier_policy=arm,
                                 host_pages=4 * n_pages)
        _assert_identity(base, res, reqs, f"{route}/{arm}")
        if arm == "prefer-device":
            assert res.pages_spilled == 0 and res.tier_restores == 0, (
                f"control arm migrated: {res.pages_spilled} pages")
            assert res.prefill_tokens == base.prefill_tokens, (
                "prefer-device must re-prefill exactly like single-tier")
        else:
            assert res.pages_spilled > 0, f"{arm}: nothing spilled"
            assert res.tier_restores > 0, f"{arm}: nothing restored"
            assert res.prefill_tokens < base.prefill_tokens, (
                f"{arm}: restores did not reduce re-prefill work "
                f"({res.prefill_tokens} vs base {base.prefill_tokens})")
        store = sched.store
        sched.flush_prefix_cache()
        store.flush_host()
        assert store.allocator.n_free == n_pages - 1, f"page leak ({arm})"
        assert store.host_used == 0, (
            f"{arm}: {store.host_used} host pages leaked after flush")
        emit(f"tier/{route}/wave/{arm}", res.now_s * 1e6,
             f"preemptions={res.preemptions} "
             f"prefill_tokens={res.prefill_tokens} "
             f"spilled={res.pages_spilled} restored={res.pages_restored} "
             f"tier_restores={res.tier_restores} "
             f"host_prefix_hits={res.host_prefix_hits} "
             f"token_identical=True")


def _replay(model, params, trace, *, max_len, n_pages, **kw):
    sched = SlotScheduler(model, params, n_slots=SLOTS, max_len=max_len,
                          paged=True, page_size=PAGE, n_pages=n_pages,
                          prefill_chunk=CHUNK, timed=False,
                          shared_programs=True, **kw)
    for r in trace.requests:
        sched.submit(r)
    res = sched.run()
    assert res.arrivals == len(trace.requests), "trace not fully replayed"
    return res


def _wave_b(route, model, params, quick):
    cfg = model.cfg
    trace = generate_trace(bursty_config(
        seed=13, n_requests=10 if quick else 20,
        vocab_size=cfg.vocab_size, rate_rps=25.0,
        burst_len=5, burst_factor=10.0))
    max_len = trace.max_len() + 1
    n_pages = 2 + -(-max_len // PAGE)
    base = _replay(model, params, trace, max_len=max_len, n_pages=n_pages)
    rep0 = slo_report(base, trace.classes)
    tier = _replay(model, params, trace, max_len=max_len, n_pages=n_pages,
                   kv_tier="host", tier_policy="spill",
                   host_pages=4 * n_pages)
    rep1 = slo_report(tier, trace.classes)
    for r in trace.requests:
        np.testing.assert_array_equal(
            base.tokens_for(r.session_id), tier.tokens_for(r.session_id),
            err_msg=f"{r.session_id} diverged tier-on ({route})")
    if tier.tier_restores:
        assert tier.prefill_tokens < base.prefill_tokens, (
            f"{route}: {tier.tier_restores} restores but prefill work "
            f"did not drop ({tier.prefill_tokens} vs "
            f"{base.prefill_tokens})")
    # restore-gate patience: hold a parked copy a bounded number of
    # ticks instead of letting the (smaller) 1-chunk re-prefill gate
    # supersede it the moment the pool is tight — realised restores
    # must strictly improve, streams must not move
    pat = _replay(model, params, trace, max_len=max_len, n_pages=n_pages,
                  kv_tier="host", tier_policy="spill",
                  host_pages=4 * n_pages, restore_patience=3)
    rep2 = slo_report(pat, trace.classes)
    for r in trace.requests:
        np.testing.assert_array_equal(
            base.tokens_for(r.session_id), pat.tokens_for(r.session_id),
            err_msg=f"{r.session_id} diverged under patience ({route})")
    assert pat.tier_restores > tier.tier_restores, (
        f"{route}: patience did not improve realised restores "
        f"({pat.tier_restores} vs {tier.tier_restores})")
    assert pat.prefill_tokens < tier.prefill_tokens, (
        f"{route}: extra restores did not cut re-prefill work")
    for name, res, rep in (("off", base, rep0), ("spill", tier, rep1),
                           ("patience3", pat, rep2)):
        emit(f"tier/{route}/bursty/{name}", rep["ttft"]["p95"] * 1e6,
             f"goodput={rep['goodput_tok_s']:.2f} "
             f"slo_frac={rep['slo_frac']:.3f} "
             f"makespan_s={rep['makespan_s']:.4f} "
             f"preemptions={res.preemptions} "
             f"prefill_tokens={res.prefill_tokens} "
             f"spilled={res.pages_spilled} restored={res.pages_restored} "
             f"token_identical=True")
    emit(f"tier/{route}/bursty/summary", rep1["goodput_tok_s"],
         f"goodput_off={rep0['goodput_tok_s']:.2f} "
         f"goodput_spill={rep1['goodput_tok_s']:.2f} "
         f"prefill_off={base.prefill_tokens} "
         f"prefill_spill={tier.prefill_tokens} "
         f"restores={tier.tier_restores} "
         f"restores_patience3={pat.tier_restores}")


def run(quick: bool = False) -> None:
    header("table14: host-DRAM KV page tier — park/restore vs re-prefill "
           "(identity, balance, goodput; paged gather / pallas)")
    cfg = _cfg()
    params = Model(cfg).init(jax.random.PRNGKey(0))
    for route, model in (("gather", Model(cfg)),
                         ("pallas", Model(cfg, decode_backend="pallas"))):
        _wave_a(route, model, params, quick)
        _wave_b(route, model, params, quick)


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv)
