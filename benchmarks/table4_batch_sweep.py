"""Paper Table 4: the dispatch-tax fraction shrinks with batch size.

Same A/B (eager vs full_jit) at batch 1/2/4/8 on a fixed reduced config:
per-step math grows ~linearly with batch while the dispatch count is
constant, so the measured speedup must fall monotonically — exactly the
paper's b=1 -> b=4 observation (1.259x -> 1.110x ... 1.036x).
"""
from __future__ import annotations

import jax

from benchmarks.common import emit, header
from repro.configs import get_config
from repro.core.protocol import run_ab
from repro.models import Model

BATCHES = (1, 2, 4, 8)


def make_step(batch: int, mode: str, session: int):
    cfg = get_config("qwen2.5-3b").reduced().replace(
        vocab_size=512, d_model=192, d_ff=384, n_layers=8,
        n_heads=4, n_kv_heads=2, head_dim=32)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(session))
    cache = m.init_cache(batch, 64)
    tokens = jax.random.randint(jax.random.PRNGKey(session + 7), (batch, 8),
                                0, cfg.vocab_size)
    _, cache = jax.jit(m.prefill)(params, {"tokens": tokens}, cache)
    run = m.step_program(params, cache).executor(mode)
    state = {"tokens": tokens[:, :1], "cache": cache}

    def step():
        return run(dict(state))["logits"]
    return step


def run(n_sessions: int = 5, quick: bool = False) -> None:
    header("table4: batch sweep of the dispatch-tax A/B")
    n = 2 if quick else n_sessions
    speedups = []
    for b in BATCHES:
        ab = run_ab(lambda s, b=b: make_step(b, "eager", s),
                    lambda s, b=b: make_step(b, "full_jit", s),
                    n_sessions=n, name=f"batch{b}")
        s = ab.summary()
        speedups.append(s["mean_speedup"])
        emit(f"batch_sweep/b{b}", s["baseline_mean_ms"] * 1e3,
             f"eager_ms={s['baseline_mean_ms']:.3f} "
             f"jit_ms={s['treated_mean_ms']:.3f} "
             f"speedup=x{s['mean_speedup']:.3f}")
    emit("batch_sweep/shrinks_with_batch", 0.0,
         f"speedups={['%.2f' % x for x in speedups]} "
         f"b1_gt_b8={speedups[0] > speedups[-1]}")


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv)
