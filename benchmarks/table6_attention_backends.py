"""Paper Table 6 / Fig 7: the attention-backend matrix at the paper's
per-layer decode shape.

Measured per-layer decode-attention wall time on this host for the jnp
backends (sdpa / math / split_kv), plus the Pallas kernel in interpret
mode (correctness-only on CPU — its time is reported but flagged; on TPU
it is the fused path).  Shape: Llama-3-8B decode (32 Q heads, 8 KV
heads, head_dim 128, kv_len 2049), matching the paper's §6 cell, plus
the Qwen-2.5-7B shape the rest of the paper uses.

The paper's reading to reproduce: the spread across reasonable fused
backends (sdpa vs split_kv) is SECOND-ORDER vs the dispatch schedule
(table2); the math fallback is the outlier.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, header
from repro.configs import get_config
from repro.core.protocol import measure_cell
from repro.models import attention as A

SHAPES = {
    # paper §6 backend-pinned shape
    "llama3-8b/ctx2048": dict(Hq=32, Hkv=8, hd=128, S=2048),
    # the paper's main-matrix model shape
    "qwen2.5-7b/ctx2048": dict(Hq=28, Hkv=4, hd=128, S=2048),
}


def run(quick: bool = False) -> None:
    header("table6: decode attention backend matrix (per layer)")
    key = jax.random.PRNGKey(0)
    for shape_name, s in SHAPES.items():
        B, Hq, Hkv, hd, S = 1, s["Hq"], s["Hkv"], s["hd"], s["S"]
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (B, 1, Hq, hd), jnp.bfloat16)
        k = jax.random.normal(ks[1], (B, S, Hkv, hd), jnp.bfloat16)
        v = jax.random.normal(ks[2], (B, S, Hkv, hd), jnp.bfloat16)
        mask = jnp.arange(S) <= S - 2
        cfg = get_config("qwen2.5-7b").replace(n_heads=Hq, n_kv_heads=Hkv,
                                               head_dim=hd)
        results = {}
        for backend in ("sdpa", "math", "split_kv"):
            fn = {"sdpa": A._sdpa_decode, "math": A._math_decode,
                  "split_kv": A._split_kv_decode}[backend]
            jfn = jax.jit(lambda q, k, v, fn=fn: fn(q, k, v, mask, cfg))
            res = measure_cell(lambda: jfn(q, k, v),
                               warmup=3 if quick else 5,
                               steps=10 if quick else 30,
                               name=backend)
            results[backend] = res.p50_s
            emit(f"attn_backend/{shape_name}/{backend}", res.p50_s * 1e6,
                 f"p50_us={res.p50_s*1e6:.1f}")
        # pallas kernel: correctness-grade interpret mode on CPU
        from repro.kernels.decode_attention.ops import decode_attention
        out = decode_attention(q[:, 0], k, v, mask=mask)
        finite = bool(jnp.all(jnp.isfinite(out.astype(jnp.float32))))
        emit(f"attn_backend/{shape_name}/pallas_interpret", 0.0,
             f"cpu=interpret-mode(correctness-only) finite={finite} "
             f"tpu=fused-path")
        spread = max(results.values()) / min(results.values())
        emit(f"attn_backend/{shape_name}/spread", 0.0,
             f"max_over_min=x{spread:.2f} fastest="
             f"{min(results, key=results.get)}")


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv)
