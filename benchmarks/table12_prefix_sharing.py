"""Table 12 (extension): prefix sharing with copy-on-write KV pages.

The paper's deployment lesson — "memory savings matter only when the
runtime realises them" (§7) — applied to the physical-AI fleet workload:
millions of short sessions replaying the same system prompt / scene
preamble.  With the paged block table already indirecting every page,
the shared prefix can simply BE the same physical pages: admission
matches the longest cached page-aligned prefix, aliases it into the new
slot's block table (refcounted), and prefills only the tail.  A fully
cached prompt skips prefill entirely — its last token is replayed
through the decode step after CoW-faulting the last shared page into a
private copy, so shared pages are never written.

Workload: N sessions sharing a page-aligned prompt prefix (distinct
tails) plus exact-duplicate page-aligned prompts (the CoW case), each
route served twice through a warm prefix cache — once with sharing off
(baseline) and once on.  Asserted per route (paged-gather and
paged-pallas; the contiguous layout has no block table and gates
sharing out with NotImplementedError):

  * greedy streams token-identical to the no-sharing baseline;
  * prefill dispatch tokens reduced by >= the shared-prefix fraction of
    the prompt bytes (every admission hits the warm cache);
  * per-step KV blocks identical to the baseline — sharing changes
    which pages back a block, never what a decode step walks;
  * the allocator free list balances back to its initial state once all
    sessions finish and the cache is flushed (refcounts all returned).

Config is f32 so the pallas-route identity column is well-conditioned
(same rationale as table10/table11)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, header, warm_wave
from repro.configs import get_config
from repro.models import Model
from repro.serving import SessionRequest, SlotScheduler

SLOTS = 3
PAGE = 8
SHARED_PAGES = 2                 # the common preamble: 2 full pages
NEW_TOKENS = 5


def _cfg():
    return get_config("qwen2.5-3b").reduced().replace(
        vocab_size=512, d_model=128, d_ff=256, n_layers=2,
        n_heads=4, n_kv_heads=2, head_dim=32, dtype="float32")


def _fleet_requests(cfg, n_mixed, n_dups):
    """n_mixed sessions = shared preamble + distinct tails, plus n_dups
    exact page-aligned duplicates of the preamble (full-match CoW)."""
    key = jax.random.PRNGKey(5)
    preamble = np.asarray(jax.random.randint(
        key, (SHARED_PAGES * PAGE,), 0, cfg.vocab_size))
    reqs = []
    for i in range(n_mixed):
        k = jax.random.fold_in(key, i + 1)
        tail = np.asarray(jax.random.randint(k, (3 + i,), 0,
                                             cfg.vocab_size))
        reqs.append(SessionRequest(f"mix{i}",
                                   np.concatenate([preamble, tail]),
                                   NEW_TOKENS))
    for i in range(n_dups):
        reqs.append(SessionRequest(f"dup{i}", preamble, NEW_TOKENS))
    return reqs


def _serve(model, params, reqs, *, max_len, prefix_cache):
    sched = SlotScheduler(model, params, n_slots=SLOTS, max_len=max_len,
                          paged=True, page_size=PAGE,
                          prefix_cache=prefix_cache)
    warm_wave(sched, reqs)       # compile + populate the prefix cache
    for r in reqs:
        sched.submit(r)
    res = sched.run()
    assert res.step_cache_size in (1, None), "decode step recompiled!"
    return sched, res


def run(quick: bool = False) -> None:
    header("table12: prefix sharing with CoW KV pages — prefill tokens "
           "saved + per-step KV bytes vs the no-sharing baseline")
    cfg = _cfg()
    params = Model(cfg).init(jax.random.PRNGKey(0))
    reqs = _fleet_requests(cfg, *( (3, 1) if quick else (6, 2) ))
    max_len = max(len(r.prompt) + r.max_new_tokens for r in reqs) + 1
    total_prompt = sum(len(r.prompt) for r in reqs)
    shared_frac = (len(reqs) * SHARED_PAGES * PAGE) / total_prompt

    routes = (("paged_gather", Model(cfg)),
              ("paged_pallas", Model(cfg, decode_backend="pallas")))
    for route, model in routes:
        _, base = _serve(model, params, reqs, max_len=max_len,
                         prefix_cache=False)
        sched, res = _serve(model, params, reqs, max_len=max_len,
                            prefix_cache=True)
        for r in reqs:           # sharing must be a pure memory change
            np.testing.assert_array_equal(
                base.tokens_for(r.session_id),
                res.tokens_for(r.session_id),
                err_msg=f"{r.session_id} diverged under sharing ({route})")
        # decode traffic unchanged up to the CoW replays: sharing never
        # changes what a decode step walks, but each fully-cached prompt
        # trades its whole prefill for ONE replay decode step that walks
        # its prefix blocks — account for those exactly
        replay_blocks = sum(len(r.prompt) // PAGE for r in reqs
                            if len(r.prompt) % PAGE == 0)
        assert (sum(res.step_kv_blocks)
                == sum(base.step_kv_blocks) + replay_blocks), (
            route, sum(res.step_kv_blocks), sum(base.step_kv_blocks),
            replay_blocks)
        saved_frac = 1 - res.prefill_tokens / base.prefill_tokens
        emit(f"prefix/{route}/base", 0.0,
             f"prefill_tokens={base.prefill_tokens} "
             f"kv_step_blocks={sum(base.step_kv_blocks)} "
             f"tok_s={base.tokens_per_s:.1f}")
        emit(f"prefix/{route}/shared", 0.0,
             f"prefill_tokens={res.prefill_tokens} "
             f"prefix_tokens_saved={res.prefix_tokens_saved} "
             f"saved_frac={saved_frac:.3f} shared_frac={shared_frac:.3f} "
             f"prefix_hits={res.prefix_hits} cow_copies={res.cow_copies} "
             f"kv_step_blocks={sum(res.step_kv_blocks)} "
             f"tok_s={res.tokens_per_s:.1f} token_identical=True")
        # the acceptance bar: with a warm cache every admission matches,
        # so prefill dispatch shrinks by >= the shared-prefix fraction
        assert res.prefix_hits == len(reqs), (
            f"{route}: only {res.prefix_hits}/{len(reqs)} admissions hit "
            f"the warm prefix cache")
        assert res.cow_copies >= 1, (
            f"{route}: duplicated page-aligned prompts never CoW-faulted")
        assert saved_frac >= shared_frac, (
            f"{route}: prefill tokens reduced x{saved_frac:.3f} < shared "
            f"prefix fraction {shared_frac:.3f}")
        # refcount balance: flushing the cache returns every page
        sched.flush_prefix_cache()
        assert sched.free_pages == sched.n_pages - 1, (
            f"{route}: free list did not balance "
            f"({sched.free_pages}/{sched.n_pages - 1})")


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv)
