"""Paper Table 7: the quantisation matrix — nominal vs realised savings.

Per weight path (bf16 / int8_dequant / int8_fused / int4_dequant /
int4_fused):
  * ANALYTIC per-step weight HBM traffic (the floor-model numerator) —
    this is the paper's point: dequant paths stream MORE than bf16,
    fused paths realise the reduction;
  * measured end-to-end decode p50 on a reduced model on this host
    (directional only on CPU; the traffic column is the TPU claim);
  * the paper's own L4 numbers reproduced through the floor model.
"""
from __future__ import annotations

import jax

from benchmarks.common import emit, header
from repro.configs import get_config
from repro.core import floor as fl
from repro.core.hardware import GPU_L4, TPU_V5E
from repro.core.protocol import measure_cell
from repro.models import Model
from repro.quant import WEIGHT_PATHS, quantize_tree, tree_weight_traffic


def run(quick: bool = False) -> None:
    header("table7: quantisation matrix")
    # (a) the paper's own Table 7 floors (L4, Qwen-2.5-7B, ctx 2048)
    q7b = get_config("qwen2.5-7b")
    for label, wb, t_obs in [("bf16", 2, 62.32), ("int4-nominal", 0.5, None)]:
        cell = fl.floor_cell(q7b, GPU_L4, 2048, weight_dtype_bytes=wb)
        derived = f"t_floor_ms={cell.t_floor_ms:.2f}"
        if t_obs:
            derived += f" paper_t_obs={t_obs} R={cell.r_floor(t_obs*1e-3):.3f}"
        emit(f"quant/paper-l4/{label}", cell.t_floor_ms * 1e3, derived)
    # paper: ExLlamaV2 17.36ms against 13.09ms floor -> R=0.754
    cell = fl.floor_cell(q7b, GPU_L4, 2048, weight_dtype_bytes=0.5)
    emit("quant/paper-l4/exllama-R", 0.0,
         f"paper 17.36ms vs floor {cell.t_floor_ms:.2f}ms "
         f"R={cell.r_floor(17.36e-3):.3f} (paper says 0.754)")

    # (b) our paths: analytic traffic + measured reduced-model decode
    cfg = get_config("qwen2.5-3b").reduced().replace(vocab_size=512)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                cfg.vocab_size)
    bf16_traffic = tree_weight_traffic(params)
    for path in WEIGHT_PATHS:
        qp = quantize_tree(params, path, group=32)
        traffic = tree_weight_traffic(qp)
        cache = m.init_cache(1, 32)
        _, cache0 = jax.jit(m.prefill)(qp, {"tokens": tokens}, cache)
        step = jax.jit(m.decode_step)

        def one(cache0=cache0, qp=qp):
            logits, _ = step(qp, cache0, tokens[:, :1])
            return logits
        res = measure_cell(one, warmup=3, steps=10 if quick else 30,
                           name=path)
        # v5e step floor for the FULL qwen2.5-3b under this path
        full = get_config("qwen2.5-3b")
        wb = {"bf16": 2, "int8_dequant": 3, "int8_fused": 1,
              "int4_dequant": 2.5, "int4_fused": 0.5}[path]
        vcell = fl.floor_cell(full, TPU_V5E, 2048, weight_dtype_bytes=wb)
        emit(f"quant/{path}", res.p50_s * 1e6,
             f"traffic_vs_bf16=x{traffic/bf16_traffic:.2f} "
             f"v5e_floor_ms={vcell.t_floor_ms:.2f} "
             f"cpu_p50_us={res.p50_s*1e6:.0f}")


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv)
