"""Table 10 (extension): paged KV cache — page size x oversubscription.

The paper's serving lesson is that memory savings only matter when the
runtime realises them: once the launch tax is gone (one compiled decode
step), *capacity* — every slot reserving a full ``max_len`` KV row —
caps concurrency, not bandwidth.  The paged cache (slot -> block-table
-> page-pool indirection, repro.serving.scheduler) breaks that
reservation; this sweep measures what the indirection costs and what the
oversubscription buys:

  * page-size sweep at full backing: gather/scatter overhead vs the
    contiguous slotted baseline (same session mix, same slots);
  * oversubscription sweep at fixed page size: the pool shrinks to a
    fraction of ``n_slots * ceil(max_len/page)`` pages; admission gating,
    reclaim, and preemption keep the workload flowing.

Reported per cell: aggregate tokens/s, shared-batch step p50/p95, pool
pages vs full backing, preemption count — and the compiled-step guard
(the decode step must stay ONE compiled program through page churn).

A warmup wave runs through the same scheduler first so the measured wave
sees only steady-state dispatches (the paper's warmup discipline).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, header
from repro.configs import get_config
from repro.launch.serve import mixed_requests
from repro.models import Model
from repro.serving import SessionRequest, SlotScheduler

PAGE_SIZES = (4, 8, 16)
OVERSUB_FRACTIONS = (1.0, 0.75, 0.5)   # pool as a fraction of full backing


def _serve(model, params, reqs, *, slots, max_len, warm=True, **kw):
    sched = SlotScheduler(model, params, n_slots=slots, max_len=max_len,
                          **kw)
    if warm:
        for r in reqs:   # warmup wave: compile prefill lengths + step
            sched.submit(SessionRequest("warm_" + r.session_id,
                                        r.prompt, r.max_new_tokens))
        sched.run()
    for r in reqs:
        sched.submit(r)
    res = sched.run()
    steps = np.concatenate([
        s.step_times_s for s in res.sessions.values()
        if s.step_times_s and not s.session_id.startswith("warm_")])
    p50, p95 = np.percentile(steps, [50, 95]) * 1e3
    return res, p50, p95


def run(quick: bool = False) -> None:
    header("table10: paged KV — page size x oversubscription")
    cfg = get_config("qwen2.5-3b").reduced().replace(
        vocab_size=512, d_model=192, d_ff=384, n_layers=4,
        n_heads=4, n_kv_heads=2, head_dim=32)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    slots = 4
    n_sessions = 6 if quick else 12
    base_prompt, base_new = 8, 8 if quick else 16
    reqs = mixed_requests(cfg, n_sessions, base_prompt=base_prompt,
                          base_new=base_new, seed=0)
    max_len = max(len(r.prompt) + r.max_new_tokens for r in reqs) + 1

    # contiguous slotted baseline (PR 1) for the indirection-cost column
    res, p50, p95 = _serve(model, params, reqs, slots=slots,
                           max_len=max_len)
    emit("paged/contiguous_baseline", p50 * 1e3,
         f"tok_s={res.tokens_per_s:.1f} step_p50_ms={p50:.3f} "
         f"step_p95_ms={p95:.3f} compiled_steps={res.step_cache_size}")
    assert res.step_cache_size in (1, None), "decode step recompiled!"

    page_sizes = PAGE_SIZES[1:2] if quick else PAGE_SIZES
    for page in page_sizes:
        res, p50, p95 = _serve(model, params, reqs, slots=slots,
                               max_len=max_len, paged=True, page_size=page)
        emit(f"paged/page{page}_full", p50 * 1e3,
             f"tok_s={res.tokens_per_s:.1f} step_p50_ms={p50:.3f} "
             f"step_p95_ms={p95:.3f} compiled_steps={res.step_cache_size} "
             f"preemptions={res.preemptions}")
        assert res.step_cache_size in (1, None), "paged decode step recompiled!"

    page = 8
    max_blocks = -(-max_len // page)
    full = slots * max_blocks
    fractions = OVERSUB_FRACTIONS[::2] if quick else OVERSUB_FRACTIONS
    for frac in fractions:
        n_pages = 1 + max(2, int(full * frac))
        res, p50, p95 = _serve(model, params, reqs, slots=slots,
                               max_len=max_len, paged=True, page_size=page,
                               n_pages=n_pages)
        emit(f"paged/oversub{int(frac * 100)}", p50 * 1e3,
             f"tok_s={res.tokens_per_s:.1f} step_p50_ms={p50:.3f} "
             f"step_p95_ms={p95:.3f} pages={n_pages - 1}/{full} "
             f"compiled_steps={res.step_cache_size} "
             f"preemptions={res.preemptions}")
        assert res.step_cache_size in (1, None), "paged decode step recompiled!"


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv)
