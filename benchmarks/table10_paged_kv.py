"""Table 10 (extension): paged KV cache — page size x oversubscription,
gather+SDPA reference vs the fused block-table kernel.

The paper's serving lesson is that memory savings only matter when the
runtime realises them: once the launch tax is gone (one compiled decode
step), *capacity* — every slot reserving a full ``max_len`` KV row —
caps concurrency, not bandwidth.  The paged cache (slot -> block-table
-> page-pool indirection, repro.serving.scheduler) breaks that
reservation; this sweep measures what the indirection costs and what the
oversubscription buys:

  * page-size sweep at full backing: gather/scatter overhead vs the
    contiguous slotted baseline (same session mix, same slots);
  * oversubscription sweep at fixed page size: the pool shrinks to a
    fraction of ``n_slots * ceil(max_len/page)`` pages; admission gating,
    reclaim, and preemption keep the workload flowing.

Every paged cell runs TWICE — through the gather+SDPA reference (the
``paged_view`` materialisation) and through the fused Pallas block-table
kernel (``decode_backend="pallas"``, kernels/paged_decode_attention;
interpret mode on CPU) — asserts the two greedy streams are
token-identical, and reports the analytic per-step KV bytes each route
moves: the fused kernel reads only the live pages once, the gather route
pays 3x the constant virtual view (pool read + view write + SDPA read).

Reported per cell: aggregate tokens/s, shared-batch step p50/p95, pool
pages vs full backing, preemption count, per-step KV bytes per route —
and the compiled-step guard (the decode step must stay ONE compiled
program through page churn).

A warmup wave runs through the same scheduler first so the measured wave
sees only steady-state dispatches (the paper's warmup discipline).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, header, measured_step_walls, warm_wave
from repro.configs import get_config
from repro.kernels.paged_decode_attention.ops import serving_traffic_bytes
from repro.launch.serve import mixed_requests
from repro.models import Model
from repro.serving import SlotScheduler

PAGE_SIZES = (4, 8, 16)
OVERSUB_FRACTIONS = (1.0, 0.75, 0.5)   # pool as a fraction of full backing


def _serve(model, params, reqs, *, slots, max_len, warm=True, **kw):
    sched = SlotScheduler(model, params, n_slots=slots, max_len=max_len,
                          **kw)
    if warm:
        warm_wave(sched, reqs)   # compile prefill lengths + step
    for r in reqs:
        sched.submit(r)
    res = sched.run()
    p50, p95 = np.percentile(measured_step_walls(res), [50, 95]) * 1e3
    return res, p50, p95


def _assert_identical(reqs, ref, fused, cell: str) -> None:
    for r in reqs:
        np.testing.assert_array_equal(
            ref.tokens_for(r.session_id), fused.tokens_for(r.session_id),
            err_msg=f"{r.session_id} diverged fused-vs-gather in {cell}")


def _paged_cell(name, models, params, reqs, cfg, *, slots, max_len, page,
                n_pages=None, extra=""):
    """Run one paged cell through both routes; assert token identity."""
    model_ref, model_fused = models
    kw = dict(slots=slots, max_len=max_len, paged=True, page_size=page,
              n_pages=n_pages)
    res, p50, p95 = _serve(model_ref, params, reqs, **kw)
    fres, fp50, fp95 = _serve(model_fused, params, reqs, **kw)
    _assert_identical(reqs, res, fres, name)
    max_blocks = -(-max_len // page)
    tb = serving_traffic_bytes(fres.step_kv_blocks, cfg, page_size=page,
                               n_slots=slots, max_blocks=max_blocks)
    for route, r, q50, q95 in (("gather", res, p50, p95),
                               ("fused", fres, fp50, fp95)):
        moved = tb["fused"] if route == "fused" else tb["gather_sdpa"]
        emit(f"{name}/{route}", q50 * 1e3,
             f"tok_s={r.tokens_per_s:.1f} step_p50_ms={q50:.3f} "
             f"step_p95_ms={q95:.3f} kv_step_bytes={moved} "
             f"compiled_steps={r.step_cache_size} "
             f"preemptions={r.preemptions}{extra}")
        assert r.step_cache_size in (1, None), \
            f"paged decode step recompiled ({route})!"
    emit(f"{name}/gather_elimination", 0.0,
         f"fused_over_gather_bytes={tb['fused'] / tb['gather_sdpa']:.3f} "
         f"token_identical=True")
    return res, fres


def run(quick: bool = False) -> None:
    header("table10: paged KV — page size x oversubscription, "
           "gather vs fused kernel")
    # f32 so the fused-vs-gather identity column is well-conditioned:
    # the bf16 SDPA rounds probabilities to bf16 before the PV dot (its
    # own backend rounding), while the fused kernel accumulates in f32 —
    # in f32 both routes compute the same real-valued function at the
    # same precision and the greedy streams coincide exactly.
    cfg = get_config("qwen2.5-3b").reduced().replace(
        vocab_size=512, d_model=192, d_ff=384, n_layers=4,
        n_heads=4, n_kv_heads=2, head_dim=32, dtype="float32")
    model = Model(cfg)                                  # gather+SDPA ref
    model_fused = Model(cfg, decode_backend="pallas")   # fused kernel
    params = model.init(jax.random.PRNGKey(0))

    slots = 4
    n_sessions = 6 if quick else 12
    base_prompt, base_new = 8, 8 if quick else 16
    reqs = mixed_requests(cfg, n_sessions, base_prompt=base_prompt,
                          base_new=base_new, seed=0)
    max_len = max(len(r.prompt) + r.max_new_tokens for r in reqs) + 1

    # contiguous slotted baseline (PR 1) for the indirection-cost column
    res, p50, p95 = _serve(model, params, reqs, slots=slots,
                           max_len=max_len)
    emit("paged/contiguous_baseline", p50 * 1e3,
         f"tok_s={res.tokens_per_s:.1f} step_p50_ms={p50:.3f} "
         f"step_p95_ms={p95:.3f} compiled_steps={res.step_cache_size} "
         f"dtype={cfg.dtype}")
    assert res.step_cache_size in (1, None), "decode step recompiled!"

    page_sizes = PAGE_SIZES[1:2] if quick else PAGE_SIZES
    for page in page_sizes:
        _paged_cell(f"paged/page{page}_full", (model, model_fused), params,
                    reqs, cfg, slots=slots, max_len=max_len, page=page)

    page = 8
    max_blocks = -(-max_len // page)
    full = slots * max_blocks
    fractions = OVERSUB_FRACTIONS[::2] if quick else OVERSUB_FRACTIONS
    for frac in fractions:
        n_pages = 1 + max(2, int(full * frac))
        _paged_cell(f"paged/oversub{int(frac * 100)}", (model, model_fused),
                    params, reqs, cfg, slots=slots, max_len=max_len,
                    page=page, n_pages=n_pages,
                    extra=f" pages={n_pages - 1}/{full}")


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv)
