"""Table 9 (extension): continuous batching over a slotted KV cache.

The paper closes the batch-1 gap by keeping the decode step inside one
compiled program; this sweep shows the same step scaling into multi-user
serving: a fixed session mix (mixed prompt/target lengths) is served
through 1/2/4/8 cache slots.  Reported per slot count: aggregate
tokens/s, per-session step-latency p50/p95, and the compiled-step count
(must stay 1 — churn never recompiles).

A warmup wave runs through the same scheduler first so the measured wave
sees only steady-state dispatches (the paper's warmup discipline).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, header, measured_step_walls, warm_wave
from repro.configs import get_config
from repro.launch.serve import mixed_requests
from repro.models import Model
from repro.serving import SlotScheduler

SLOT_COUNTS = (1, 2, 4, 8)


def run(quick: bool = False) -> None:
    header("table9: continuous batching vs slot count")
    cfg = get_config("qwen2.5-3b").reduced().replace(
        vocab_size=512, d_model=192, d_ff=384, n_layers=4,
        n_heads=4, n_kv_heads=2, head_dim=32)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    n_sessions = 6 if quick else 12
    base_prompt, base_new = 8, 8 if quick else 16
    slot_counts = SLOT_COUNTS[:3] if quick else SLOT_COUNTS
    throughputs = []
    for slots in slot_counts:
        reqs = mixed_requests(cfg, n_sessions, base_prompt=base_prompt,
                              base_new=base_new, seed=0)
        max_len = max(len(r.prompt) + r.max_new_tokens for r in reqs) + 1
        sched = SlotScheduler(model, params, n_slots=slots,
                              max_len=max_len)
        warm_wave(sched, reqs)   # compile prefill lengths + step
        for r in reqs:
            sched.submit(r)
        res = sched.run()
        p50, p95 = np.percentile(measured_step_walls(res), [50, 95]) * 1e3
        throughputs.append(res.tokens_per_s)
        emit(f"continuous/slots{slots}", p50 * 1e3,
             f"tok_s={res.tokens_per_s:.1f} step_p50_ms={p50:.3f} "
             f"step_p95_ms={p95:.3f} compiled_steps={res.step_cache_size} "
             f"decode_steps={res.dispatches}")
        assert res.step_cache_size in (1, None), "decode step recompiled!"
    gain = throughputs[-1] / throughputs[0]
    emit("continuous/scaling", 0.0,
         f"tok_s={['%.1f' % t for t in throughputs]} "
         f"x{gain:.2f} from slots{slot_counts[0]} to "
         f"slots{slot_counts[-1]}")


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv)
