"""Paper Table 1 / Table 9: the analytic floor matrix.

Two parts:
 (a) paper-validation: our floor model vs the paper's own three models x
     four GPUs x four contexts (t_floor column of Table 9 reproduced
     analytically — exact, since both sides are closed-form);
 (b) the same matrix for the 10 assigned archs on the TPU ladder — the
     floors the serving stack is measured against in §Roofline/§Perf.
"""
from __future__ import annotations

from benchmarks.common import emit, header
from repro.configs import PAPER_MODELS, list_configs, get_config
from repro.core import floor as fl
from repro.core.hardware import GPU_LADDER, TPU_LADDER

CTXS = (2048, 4096, 8192, 16384)

# paper Table 9 t_floor (ms) for validation, (arch, gpu, ctx) -> ms
PAPER_TABLE9 = {
    ("qwen2.5-7b", "h100-sxm5", 2048): 4.58,
    ("qwen2.5-7b", "a100-80gb", 4096): 7.60,
    ("qwen2.5-7b", "l40s", 8192): 18.18,
    ("mistral-7b-v0.3", "l4", 16384): 55.55,
    ("llama-3.1-8b", "h100-sxm5", 16384): 5.43,
    ("llama-3.1-8b", "l4", 2048): 54.41,
}


def run() -> None:
    header("table1/9: analytic floor matrix")
    for cfg in PAPER_MODELS:
        for chip in GPU_LADDER:
            for ctx in CTXS:
                cell = fl.floor_cell(cfg, chip, ctx)
                want = PAPER_TABLE9.get((cfg.name, chip.name, ctx))
                note = (f"paper={want}ms" if want is not None else "")
                emit(f"floor/{cfg.name}/{chip.name}/ctx{ctx}",
                     cell.t_floor_ms * 1e3,
                     f"t_floor_ms={cell.t_floor_ms:.2f} {note}")
    for name in list_configs(assigned_only=True):
        cfg = get_config(name)
        for chip in TPU_LADDER:
            for ctx in CTXS:
                cell = fl.floor_cell(cfg, chip, ctx)
                emit(f"floor/{name}/{chip.name}/ctx{ctx}",
                     cell.t_floor_ms * 1e3,
                     f"t_floor_ms={cell.t_floor_ms:.3f} "
                     f"W_active={cell.weight_bytes/1e9:.2f}GB "
                     f"K={cell.kv_bytes/1e6:.1f}MB")


if __name__ == "__main__":
    run()
