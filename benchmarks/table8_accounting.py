"""Paper Table 8: cross-arch per-step byte/FLOP accounting.

For every assigned arch at the decode_32k shape: analytic streamed bytes
(weights + active KV), decode FLOPs, arithmetic intensity, and v5e
bw-bound step floor — the accounting the paper builds from
torch.profiler + analytic byte counts, here fully analytic + dry-run
cross-checked (see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

from benchmarks.common import emit, header
from repro.analysis import analytic
from repro.configs import SHAPES, get_config, list_configs
from repro.core import floor as fl
from repro.core.hardware import TPU_V5E


def run() -> None:
    header("table8: per-step accounting (decode_32k, v5e, 256 chips)")
    shape = SHAPES["decode_32k"]
    for name in list_configs(assigned_only=True):
        cfg = get_config(name)
        est = analytic.estimate(cfg, shape, n_chips=256, tp=16, dp=16)
        bw_t = est.hbm_bytes_per_chip / TPU_V5E.hbm_bw
        fl_t = est.flops / (256 * TPU_V5E.peak_flops_bf16)
        ai = est.flops / 256 / est.hbm_bytes_per_chip
        emit(f"accounting/{name}/decode_32k", bw_t * 1e6,
             f"hbm_GB_per_chip={est.hbm_bytes_per_chip/1e9:.2f} "
             f"flops_G={est.flops/1e9:.0f} arith_intensity={ai:.1f} "
             f"mem_t_ms={bw_t*1e3:.2f} compute_t_ms={fl_t*1e3:.3f} "
             f"bound={'memory' if bw_t > fl_t else 'compute'}")
    # the ctx-growth contrast the paper highlights (KV term vs state term)
    for name in ("qwen2.5-3b", "mamba2-2.7b", "zamba2-1.2b"):
        cfg = get_config(name)
        k2 = fl.kv_bytes(cfg, 2048)
        k500 = fl.kv_bytes(cfg, 524288)
        emit(f"accounting/{name}/kv_growth", 0.0,
             f"K(2k)={k2/1e6:.1f}MB K(500k)={k500/1e6:.1f}MB "
             f"ratio=x{k500/max(k2,1):.1f}")


if __name__ == "__main__":
    run()
