"""Shared benchmark plumbing: CSV emission per the harness contract
(``name,us_per_call,derived``)."""
from __future__ import annotations

import io
import sys
from typing import Iterable, Optional


def emit(name: str, us_per_call: float, derived: str = "") -> str:
    line = f"{name},{us_per_call:.3f},{derived}"
    print(line, flush=True)
    return line


def header(title: str):
    print(f"# === {title} ===", flush=True)
