"""Shared benchmark plumbing: CSV emission per the harness contract
(``name,us_per_call,derived``) plus an in-process results registry so
``benchmarks/run.py --json`` can dump machine-readable rows (the
``BENCH_*.json`` perf trajectory tracked across PRs)."""
from __future__ import annotations

import re
from typing import Dict, List, Union

# rows emitted since the last take_results() call (one benchmark table's
# worth when driven by benchmarks/run.py)
RESULTS: List[dict] = []

# a real decimal number: optional sign, digits with optional point,
# optional exponent.  ``float()`` alone is too permissive for the k=v
# protocol — it accepts "nan"/"inf" (which would poison the JSON dump:
# json.dump(allow_nan=False) rejects them) and "1_2" (underscore
# separators a typo'd field would silently parse as 12.0).
_NUMERIC = re.compile(r"[+-]?(\d+\.?\d*|\.\d+)([eE][+-]?\d+)?\Z")


def _parse_fields(derived: str) -> Dict[str, Union[str, float, bool]]:
    """Parse the free-form ``k=v`` pairs of a derived column into typed
    values (floats where they look numeric — including negatives and
    scientific notation like ``p99=1.2e-03`` — and True/False for
    booleans) so the JSON dump is queryable without re-tokenising
    strings.  Non-numeric values (including nan/inf spellings) stay
    strings, keeping the dump valid under ``allow_nan=False``."""
    out: Dict[str, Union[str, float, bool]] = {}
    for part in derived.split():
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        if v in ("True", "False"):
            out[k] = v == "True"
        elif _NUMERIC.match(v):
            out[k] = float(v)
        else:
            out[k] = v
    return out


def emit(name: str, us_per_call: float, derived: str = "") -> str:
    line = f"{name},{us_per_call:.3f},{derived}"
    print(line, flush=True)
    RESULTS.append({"name": name, "us_per_call": us_per_call,
                    "derived": derived, "fields": _parse_fields(derived)})
    return line


def take_results() -> List[dict]:
    """Drain and return the rows emitted since the previous call."""
    out = RESULTS[:]
    RESULTS.clear()
    return out


def header(title: str):
    print(f"# === {title} ===", flush=True)


def warm_wave(sched, reqs) -> None:
    """Run a throwaway wave of ``reqs`` (session ids prefixed ``warm_``)
    through ``sched`` so the measured wave sees only steady-state
    dispatches — the paper's warmup discipline, shared by every serving
    table."""
    import dataclasses
    for r in reqs:
        sched.submit(dataclasses.replace(r,
                                         session_id="warm_" + r.session_id))
    sched.run()


def measured_step_walls(res):
    """Concatenated shared-batch decode-step walls of the measured
    (non-``warm_``) sessions of a ContinuousResult, for percentile
    reporting."""
    import numpy as np
    walls = [s.step_times_s for s in res.sessions.values()
             if s.step_times_s and not s.session_id.startswith("warm_")]
    assert walls, ("no measured step walls — was the scheduler run with "
                   "timed=False, or did every session finish at prefill?")
    return np.concatenate(walls)
