"""Paper Table 2 + §5: the dispatch-tax A/B — measured, on this host.

The TPU/JAX analogue of the CUDA-Graphs A/B: the SAME decode step run as
  eager     (per-op host dispatch  = per-kernel launches)
  stage_jit (per-layer programs    = fused kernels, host loop)
  full_jit  (one program           = graph replay)
under the paper's exact protocol: within-session A/B, 5 warmup + 30
measured steps, p50, N sessions, 10k-resample bootstrap 95% CI.

The paper's fast-vs-slow-silicon axis is reproduced by model scale on
the CPU host: a small model is "H100-like" (dispatch-dominated), a large
model is "L4-like" (compute/bandwidth-dominated).  Pre-registered
expectation (paper §5 logic): full_jit/eager speedup LARGE on the small
config, shrinking monotonically as compute grows.
"""
from __future__ import annotations


import jax

from benchmarks.common import emit, header
from repro.configs import get_config
from repro.core.protocol import run_ab
from repro.models import Model

# "silicon ladder" by model scale (d_model, n_layers): compute per step
# grows ~quadratically while dispatch count stays ~constant
SCALES = {
    "h100-like/d128-L8": dict(d_model=128, n_layers=8, d_ff=256),
    "mid/d256-L8": dict(d_model=256, n_layers=8, d_ff=512),
    "l4-like/d512-L8": dict(d_model=512, n_layers=8, d_ff=1024),
}


def make_step_fns(scale_kw, mode: str, session: int):
    cfg = get_config("qwen2.5-3b").reduced().replace(
        name="ab", vocab_size=512, n_heads=4, n_kv_heads=2, head_dim=32,
        **scale_kw)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(session))
    cache = m.init_cache(1, 64)
    tokens = jax.random.randint(jax.random.PRNGKey(session + 100), (1, 8),
                                0, cfg.vocab_size)
    _, cache = jax.jit(m.prefill)(params, {"tokens": tokens}, cache)
    program = m.step_program(params, cache)
    run = program.executor(mode)
    state = {"tokens": tokens[:, :1], "cache": cache}

    def step():
        out = run(dict(state))
        return out["logits"]
    return step


def run(n_sessions: int = 10, quick: bool = False) -> None:
    header("table2: dispatch-tax A/B (CUDA-Graphs analogue)")
    n = 3 if quick else n_sessions
    results = {}
    for scale_name, kw in SCALES.items():
        ab = run_ab(lambda s, kw=kw: make_step_fns(kw, "eager", s),
                    lambda s, kw=kw: make_step_fns(kw, "full_jit", s),
                    n_sessions=n, name=f"ab/{scale_name}")
        summ = ab.summary()
        results[scale_name] = summ
        lo, hi = summ["speedup_ci95"]
        emit(f"dispatch_ab/{scale_name}/eager",
             summ["baseline_mean_ms"] * 1e3,
             f"p50_ms={summ['baseline_mean_ms']:.3f} cv={summ['baseline_cv']:.3f}")
        emit(f"dispatch_ab/{scale_name}/full_jit",
             summ["treated_mean_ms"] * 1e3,
             f"p50_ms={summ['treated_mean_ms']:.3f} cv={summ['treated_cv']:.3f}")
        emit(f"dispatch_ab/{scale_name}/speedup", 0.0,
             f"x{summ['mean_speedup']:.3f} ci95=[{lo:.3f},{hi:.3f}] n={n}")
        # the stage_jit midpoint (one program per layer)
        ab2 = run_ab(lambda s, kw=kw: make_step_fns(kw, "stage_jit", s),
                     lambda s, kw=kw: make_step_fns(kw, "full_jit", s),
                     n_sessions=max(3, n // 3), name=f"ab2/{scale_name}")
        s2 = ab2.summary()
        emit(f"dispatch_ab/{scale_name}/stage_jit",
             s2["baseline_mean_ms"] * 1e3,
             f"p50_ms={s2['baseline_mean_ms']:.3f} "
             f"full_jit_speedup=x{s2['mean_speedup']:.3f}")
    sp = [results[k]["mean_speedup"] for k in SCALES]
    emit("dispatch_ab/monotone_in_scale", 0.0,
         f"speedups={['%.2f' % s for s in sp]} "
         f"monotone={all(a >= b for a, b in zip(sp, sp[1:]))}")
    return results


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv)
