"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (benchmarks/common.py).
``--quick`` shrinks session counts for CI-speed runs; the default run is
the paper-faithful protocol (N=10 sessions on the headline A/B).
``--json <path>`` additionally writes every emitted row (with the
derived ``k=v`` pairs parsed into typed fields) plus per-table status
and wall time to a machine-readable file, so the perf trajectory
(``BENCH_*.json``) can be tracked across PRs.

Every selected table runs even if an earlier one fails; any failure
makes the process exit nonzero (with a ``# FAILED`` line per broken
table), so a CI stage over a sweep can never silently pass.  Exit codes
distinguish the failure class: 3 when every failed table tripped one of
its own inline assertions (a metric regression — the harness ran fine),
1 when any table crashed outright.  ``--list`` prints the table ids
with one-line descriptions and exits 0.
"""
from __future__ import annotations

import json
import os
import re
import sys
import time
import traceback


DESCRIPTIONS = {
    "table1": "analytic R_floor matrix across archs and chips",
    "table2": "dispatch-mode A/B: eager vs stage_jit vs full_jit tax",
    "table4": "batch-size sweep: decode latency vs batched throughput",
    "table6": "decode attention backends: sdpa / math / split_kv / pallas",
    "table7": "weight quantisation matrix: dequant vs fused kernels",
    "table8": "roofline accounting: bytes moved vs model footprint",
    "fig9": "cost-of-inference ladder across optimisation stages",
    "table9": "continuous batching vs sequential serving",
    "table10": "paged KV: oversubscription, chunked prefill, preemption",
    "table11": "launch overhead: horizon-K fused macro-tick amortisation",
    "table12": "prefix sharing: CoW page dedup across sessions",
    "table13": "SLO metrics under trace load: fixed-K vs adaptive-K",
    "table14": "host-DRAM KV tier: park/restore vs re-prefill",
    "table15": "quantised KV pages + int4 weights: realised vs analytic "
               "traffic per route",
    "table16": "fault injection + graceful degradation: chaos replay A/B",
}


_MODULE_RE = re.compile(r"(table\d+|fig\d+)_\w+\.py$")


def registry_audit(suite_names=None, description_names=None,
                   module_dir=None):
    """staticcheck-style self-audit of the table registry: every
    ``table*.py`` / ``fig*.py`` module must be registered with a
    description, and the three views (modules on disk, ``DESCRIPTIONS``,
    the ``suites`` dict) must agree.  Returns a list of human-readable
    problem lines — empty means consistent.  Each view is optional so
    ``--list`` can audit without importing the suite modules."""
    problems = []
    descs = set(DESCRIPTIONS if description_names is None
                else description_names)
    module_dir = module_dir or os.path.dirname(os.path.abspath(__file__))
    ids = {m.group(1) for f in os.listdir(module_dir)
           if (m := _MODULE_RE.match(f))}
    for mid in sorted(ids - descs):
        problems.append(f"{mid}: module file exists but has no entry in "
                        f"DESCRIPTIONS (--list would omit it)")
    for mid in sorted(descs - ids):
        problems.append(f"{mid}: described in --list but no matching "
                        f"benchmark module file")
    if suite_names is not None:
        suites = set(suite_names)
        for name in sorted(suites - descs):
            problems.append(f"{name}: registered suite has no --list "
                            f"description")
        for name in sorted(descs - suites):
            problems.append(f"{name}: described in --list but not in the "
                            f"suites registry")
    return problems


def _report_audit(problems) -> None:
    for p in problems:
        print(f"# registry: {p}", flush=True)
    print(f"# FAILED: benchmark registry out of sync "
          f"({len(problems)} problem(s))", flush=True)


def main() -> None:
    if "--list" in sys.argv:
        for name, desc in DESCRIPTIONS.items():
            print(f"{name:8s} {desc}")
        problems = registry_audit()
        if problems:
            _report_audit(problems)
            sys.exit(2)
        return
    quick = "--quick" in sys.argv
    only = None
    json_path = None
    argv = sys.argv[1:]
    for i, a in enumerate(argv):
        if a.startswith("--only="):
            only = a.split("=", 1)[1]
        elif a.startswith("--json="):
            json_path = a.split("=", 1)[1]
        elif a == "--json":
            if i + 1 >= len(argv) or argv[i + 1].startswith("-"):
                print("# FAILED: --json requires a path argument",
                      flush=True)
                sys.exit(2)
            json_path = argv[i + 1]

    from benchmarks import (common, fig9_cost_ladder, table1_rfloor_matrix,
                            table2_dispatch_ab, table4_batch_sweep,
                            table6_attention_backends, table7_quant_matrix,
                            table8_accounting, table9_continuous_batching,
                            table10_paged_kv, table11_launch_overhead,
                            table12_prefix_sharing, table13_slo_load,
                            table14_kv_tiering, table15_quant_serving,
                            table16_fault_recovery)
    suites = {
        "table1": table1_rfloor_matrix.run,
        "table2": lambda: table2_dispatch_ab.run(quick=quick),
        "table4": lambda: table4_batch_sweep.run(quick=quick),
        "table6": lambda: table6_attention_backends.run(quick=quick),
        "table7": lambda: table7_quant_matrix.run(quick=quick),
        "table8": table8_accounting.run,
        "fig9": fig9_cost_ladder.run,
        "table9": lambda: table9_continuous_batching.run(quick=quick),
        "table10": lambda: table10_paged_kv.run(quick=quick),
        "table11": lambda: table11_launch_overhead.run(quick=quick),
        "table12": lambda: table12_prefix_sharing.run(quick=quick),
        "table13": lambda: table13_slo_load.run(quick=quick),
        "table14": lambda: table14_kv_tiering.run(quick=quick),
        "table15": lambda: table15_quant_serving.run(quick=quick),
        "table16": lambda: table16_fault_recovery.run(quick=quick),
    }
    problems = registry_audit(suites)
    if problems:
        _report_audit(problems)
        sys.exit(2)
    if only is not None and only not in suites:
        print(f"# FAILED: unknown table {only!r} "
              f"(have: {', '.join(suites)})", flush=True)
        sys.exit(2)
    t0 = time.time()
    failed, crashed = [], []
    report = {"quick": quick, "only": only, "tables": {}}
    for name, fn in suites.items():
        if only and name != only:
            continue
        common.take_results()            # drop stray rows from prior table
        t_table = time.time()
        ok = True
        try:
            fn()
        except AssertionError:
            traceback.print_exc()
            print(f"# FAILED (assertion): {name}", flush=True)
            failed.append(name)
            ok = False
        except Exception:
            traceback.print_exc()
            print(f"# FAILED: {name}", flush=True)
            failed.append(name)
            crashed.append(name)
            ok = False
        report["tables"][name] = {
            "ok": ok,
            "seconds": round(time.time() - t_table, 3),
            "rows": common.take_results(),
        }
    report["total_s"] = round(time.time() - t0, 3)
    report["failed"] = failed
    if json_path:
        with open(json_path, "w") as f:
            # allow_nan=False: a NaN/Inf anywhere in the report is a
            # bug (strict mode would emit invalid JSON silently) — fail
            # the run loudly instead
            json.dump(report, f, indent=2, allow_nan=False)
        print(f"# wrote {json_path}", flush=True)
    for name, entry in report["tables"].items():
        print(f"# {name}: {entry['seconds']:.1f}s"
              f"{'' if entry['ok'] else ' FAILED'}", flush=True)
    print(f"# total {report['total_s']:.1f}s", flush=True)
    if failed:
        print(f"# {len(failed)} table(s) failed: {', '.join(failed)}",
              flush=True)
        # 3 = every failure was an inline-assertion trip (metric
        # regression); 1 = at least one table crashed outright
        sys.exit(1 if crashed else 3)


if __name__ == "__main__":
    main()
