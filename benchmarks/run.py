"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (benchmarks/common.py).
``--quick`` shrinks session counts for CI-speed runs; the default run is
the paper-faithful protocol (N=10 sessions on the headline A/B).

Every selected table runs even if an earlier one fails; any failure
makes the process exit nonzero (with a ``# FAILED`` line per broken
table), so a CI stage over a sweep can never silently pass.
"""
from __future__ import annotations

import sys
import time
import traceback


def main() -> None:
    quick = "--quick" in sys.argv
    only = None
    for a in sys.argv[1:]:
        if a.startswith("--only="):
            only = a.split("=", 1)[1]

    from benchmarks import (fig9_cost_ladder, table1_rfloor_matrix,
                            table2_dispatch_ab, table4_batch_sweep,
                            table6_attention_backends, table7_quant_matrix,
                            table8_accounting, table9_continuous_batching,
                            table10_paged_kv)
    suites = {
        "table1": table1_rfloor_matrix.run,
        "table2": lambda: table2_dispatch_ab.run(quick=quick),
        "table4": lambda: table4_batch_sweep.run(quick=quick),
        "table6": lambda: table6_attention_backends.run(quick=quick),
        "table7": lambda: table7_quant_matrix.run(quick=quick),
        "table8": table8_accounting.run,
        "fig9": fig9_cost_ladder.run,
        "table9": lambda: table9_continuous_batching.run(quick=quick),
        "table10": lambda: table10_paged_kv.run(quick=quick),
    }
    if only is not None and only not in suites:
        print(f"# FAILED: unknown table {only!r} "
              f"(have: {', '.join(suites)})", flush=True)
        sys.exit(2)
    t0 = time.time()
    failed = []
    for name, fn in suites.items():
        if only and name != only:
            continue
        try:
            fn()
        except Exception:
            traceback.print_exc()
            print(f"# FAILED: {name}", flush=True)
            failed.append(name)
    print(f"# total {time.time() - t0:.1f}s", flush=True)
    if failed:
        print(f"# {len(failed)} table(s) failed: {', '.join(failed)}",
              flush=True)
        sys.exit(1)


if __name__ == "__main__":
    main()
