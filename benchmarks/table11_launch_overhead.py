"""Table 11 (extension): launch overhead vs horizon K — the paper's
CUDA-Graphs A/B recast for JAX serving.

The paper's headline mechanism: batch-1 decode is memory-DOMINATED but
launch-LIMITED — CUDA Graphs buys 1.259x on H100 because per-step
dispatch overhead, not bandwidth, caps fast GPUs.  Our ``full_jit``
decode step is the single-step graph equivalent; this table measures
the next rung: **horizon-K fused macro-ticks** (``steps_per_tick=K``),
where ONE compiled program advances every live slot K tokens with
on-device sampling and a single (n_slots, K) token transfer.

For K in {1, 2, 4, 8, 16} across all three serving routes (contiguous
slotted, paged gather+SDPA, paged fused-Pallas), a lockstep session mix
(uniform prompt/budget, sessions == slots, budgets divisible by every
K) is served twice through one scheduler (warmup wave + measured wave)
and the table reports:

  * aggregate tok/s and per-token step wall p50 (macro walls amortised
    over their K device steps);
  * decode dispatches and tokens-per-dispatch — the host round-trip
    amortisation, which for a lockstep mix is EXACTLY K (asserted:
    ``amortisation >= K``, the acceptance bar at K=8);
  * measured host-side per-token overhead (Python + dispatch time
    before the sync, and the sync wall itself) and its ratio to K=1.

Greedy token identity against the K=1 stream is asserted per route —
the fused horizon must be a pure scheduling change, never a numeric
one.  The config is f32 so the identity column is well-conditioned on
the pallas route (same rationale as table10).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, header, measured_step_walls, warm_wave
from repro.configs import get_config
from repro.models import Model
from repro.serving import SessionRequest, SlotScheduler

HORIZONS = (1, 2, 4, 8, 16)
HORIZONS_QUICK = (1, 8)
SLOTS = 4
PROMPT_LEN = 8
NEW_TOKENS = 17          # 16 decode tokens: divisible by every horizon
PAGE = 8


def _cfg():
    return get_config("qwen2.5-3b").reduced().replace(
        vocab_size=512, d_model=128, d_ff=256, n_layers=2,
        n_heads=4, n_kv_heads=2, head_dim=32, dtype="float32")


def _lockstep_requests(cfg, n):
    """Uniform sessions: one prefill compile, lanes stay in lockstep so
    tokens-per-dispatch amortisation is exactly the horizon."""
    key = jax.random.PRNGKey(3)
    reqs = []
    for i in range(n):
        k = jax.random.fold_in(key, i)
        prompt = np.asarray(jax.random.randint(k, (PROMPT_LEN,), 0,
                                               cfg.vocab_size))
        reqs.append(SessionRequest(f"lock{i}", prompt, NEW_TOKENS))
    return reqs


def _serve(model, params, reqs, *, max_len, steps_per_tick, paged):
    kw = dict(paged=True, page_size=PAGE) if paged else {}
    sched = SlotScheduler(model, params, n_slots=SLOTS, max_len=max_len,
                          steps_per_tick=steps_per_tick, **kw)
    warm_wave(sched, reqs)   # compile prefill + the (backend, K) program
    for r in reqs:
        sched.submit(r)
    res = sched.run()
    assert res.step_cache_size in (1, None), \
        f"horizon-{steps_per_tick} decode program recompiled!"
    p50 = float(np.percentile(measured_step_walls(res), 50)) * 1e3
    return res, p50


def run(quick: bool = False) -> None:
    header("table11: launch overhead vs horizon K (CUDA-Graphs A/B "
           "recast) — contiguous / paged-gather / paged-pallas")
    cfg = _cfg()
    params = Model(cfg).init(jax.random.PRNGKey(0))
    reqs = _lockstep_requests(cfg, SLOTS)
    max_len = PROMPT_LEN + NEW_TOKENS + 1
    decode_tokens = SLOTS * (NEW_TOKENS - 1)   # first tokens from prefill
    horizons = HORIZONS_QUICK if quick else HORIZONS

    routes = (
        ("contiguous", Model(cfg), False),
        ("paged_gather", Model(cfg), True),
        ("paged_pallas", Model(cfg, decode_backend="pallas"), True),
    )
    for route, model, paged in routes:
        base = None
        for K in horizons:
            res, p50 = _serve(model, params, reqs, max_len=max_len,
                              steps_per_tick=K, paged=paged)
            tpd = decode_tokens / res.dispatches   # tokens per dispatch
            host_ms_tok = res.host_dispatch_s / decode_tokens * 1e3
            sync_ms_tok = res.host_sync_s / decode_tokens * 1e3
            if K == 1:
                base = (res, tpd, host_ms_tok + sync_ms_tok)
            else:
                for r in reqs:   # greedy identity vs the K=1 stream
                    np.testing.assert_array_equal(
                        base[0].tokens_for(r.session_id),
                        res.tokens_for(r.session_id),
                        err_msg=f"{r.session_id} diverged at K={K} "
                                f"({route})")
            amort = tpd / base[1]
            host_amort = (base[2] / (host_ms_tok + sync_ms_tok)
                          if host_ms_tok + sync_ms_tok > 0 else float("inf"))
            speedup = res.tokens_per_s / base[0].tokens_per_s
            emit(f"launch/{route}/K{K}", p50 * 1e3,
                 f"tok_s={res.tokens_per_s:.1f} step_p50_ms={p50:.3f} "
                 f"dispatches={res.dispatches} tokens_per_dispatch={tpd:.1f} "
                 f"dispatch_amort={amort:.2f} "
                 f"host_ms_per_tok={host_ms_tok + sync_ms_tok:.4f} "
                 f"host_amort={host_amort:.2f} speedup={speedup:.2f} "
                 f"token_identical=True")
            # the acceptance bar: per-token host round-trips amortise by
            # >= the horizon factor (exact for a lockstep mix)
            assert amort >= K, (
                f"{route} K={K}: tokens-per-dispatch amortisation "
                f"x{amort:.2f} below the horizon factor")


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv)
