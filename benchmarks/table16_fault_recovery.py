"""Table 16: fault injection + graceful degradation — the chaos A/B.

A serving stack for physical-AI fleets fails in the field: a pinned
host buffer's DMA times out, a parked KV blob is returned corrupt, a
burst of admissions squeezes the page pool, a kernel regression emits
NaN logits, a client disconnects mid-stream.  The robustness layer
(serving/faults.py + the scheduler's guards) must turn each of those
into a *bounded, accounted* degradation — retry with backoff, checksum
reject + re-prefill, quarantine, terminal abort — without perturbing
any other lane's token stream.

This table replays the bursty two-class trace fault-free, then again
with a seeded fault plan armed (same virtual clock, same arrivals), on
both paged decode routes (gather+SDPA and fused Pallas).  Asserted per
route:

  * the plan actually bites: >= 3 distinct fault kinds fire;
  * every session the plan did NOT terminate recovers token-identical
    to the fault-free baseline — injected copy failures and poisoned
    logits degrade to re-prefill/quarantine-requeue, never to a
    different stream;
  * every terminated session (abort) carries a terminal status, a
    terminal event, and a token stream that is a strict prefix of its
    baseline stream;
  * retries are charged to the virtual clock (retry_backoff_s > 0
    whenever a copy retried);
  * device and host pools balance after the flushes — no fault path
    leaks a page or a parked blob;
  * the same --chaos-seed reproduces the identical plan text, fault
    counters, and token streams, byte for byte.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, header
from repro.configs import get_config
from repro.models import Model
from repro.serving import SlotScheduler, generate_trace, slo_report
from repro.serving.faults import (FaultInjector, FaultPlanConfig,
                                  generate_fault_plan, plan_to_text)
from repro.serving.trace import bursty_config

SLOTS = 2
PAGE = 4
CHUNK = 4
CHAOS_SEED = 5       # fires save/restore failures, pressure, nan, abort


def _cfg():
    return get_config("qwen2.5-3b").reduced().replace(
        vocab_size=512, d_model=64, d_ff=128, n_layers=2,
        n_heads=4, n_kv_heads=2, head_dim=16, dtype="float32")


def _replay(model, params, trace, *, max_len, n_pages, injector=None):
    sched = SlotScheduler(
        model, params, n_slots=SLOTS, max_len=max_len, paged=True,
        page_size=PAGE, n_pages=n_pages, prefill_chunk=CHUNK,
        prefix_cache=True, timed=False, shared_programs=True,
        kv_tier="host", tier_policy="spill", host_pages=4 * n_pages,
        fault_injector=injector, self_audit=injector is not None)
    for r in trace.requests:
        sched.submit(r)
    return sched, sched.run()


def _terminal_events(res):
    return {sid for kind, sid, *_ in res.events
            if kind in ("aborted", "failed", "expired")}


def _route(route, model, params, quick):
    cfg = model.cfg
    trace = generate_trace(bursty_config(
        seed=13, n_requests=10 if quick else 16,
        vocab_size=cfg.vocab_size, rate_rps=25.0,
        burst_len=5, burst_factor=10.0))
    max_len = trace.max_len() + 1
    # tight pool: preemption churn parks blobs (surface for corrupt /
    # restore_fail) and keeps the admission gate busy (pool_pressure)
    n_pages = 2 + -(-max_len // PAGE)
    sched, base = _replay(model, params, trace,
                          max_len=max_len, n_pages=n_pages)
    assert base.pages_spilled > 0, (
        f"{route}: fault-free run never parked — the chaos plan would "
        f"have no copy path to attack")
    rep0 = slo_report(base, trace.classes)
    emit(f"fault/{route}/baseline", rep0["makespan_s"] * 1e6,
         f"goodput={rep0['goodput_tok_s']:.2f} "
         f"slo_frac={rep0['slo_frac']:.3f} "
         f"preemptions={base.preemptions} spilled={base.pages_spilled}")

    plan = generate_fault_plan(
        FaultPlanConfig(seed=CHAOS_SEED, n_faults=8 if quick else 12,
                        horizon_s=round(base.now_s, 6)),
        session_ids=[r.session_id for r in trace.requests])
    sched, chaos = _replay(model, params, trace, max_len=max_len,
                           n_pages=n_pages,
                           injector=FaultInjector(plan))
    assert len(chaos.fault_counts) >= 3, (
        f"{route}: plan only exercised {chaos.fault_counts} — need >= 3 "
        f"distinct kinds for the A/B to mean anything")
    terminal = _terminal_events(chaos)
    for r in trace.requests:
        b = base.tokens_for(r.session_id)
        c = chaos.tokens_for(r.session_id)
        s = chaos.sessions[r.session_id]
        if s.status == "ok":
            np.testing.assert_array_equal(
                b, c, err_msg=f"{r.session_id} diverged under chaos "
                              f"({route}) without a terminal event")
            assert r.session_id not in terminal
        else:
            assert r.session_id in terminal, (
                f"{r.session_id}: status {s.status} but no terminal event")
            np.testing.assert_array_equal(
                b[:len(c)], c,
                err_msg=f"{r.session_id}: terminated stream is not a "
                        f"prefix of its baseline ({route})")
    if chaos.save_retries or chaos.restore_retries:
        assert chaos.retry_backoff_s > 0, (
            f"{route}: retries ran but charged nothing to the clock")
    store = sched.store
    sched.flush_prefix_cache()
    store.flush_host()
    assert store.allocator.n_free == n_pages - 1, (
        f"{route}: device pages leaked under chaos")
    assert store.host_used == 0, (
        f"{route}: {store.host_used} host pages leaked under chaos")
    rep1 = slo_report(chaos, trace.classes)
    emit(f"fault/{route}/chaos", rep1["makespan_s"] * 1e6,
         f"goodput={rep1['goodput_tok_s']:.2f} "
         f"slo_frac={rep1['slo_frac']:.3f} "
         f"faults={chaos.faults_injected} "
         f"kinds={len(chaos.fault_counts)} "
         f"retries={chaos.save_retries + chaos.restore_retries} "
         f"backoff_ms={chaos.retry_backoff_s * 1e3:.2f} "
         f"degraded={chaos.degraded_restores} "
         f"corrupt={chaos.corrupt_blobs} "
         f"quarantines={chaos.quarantines} "
         f"dropped={chaos.aborted_sessions + chaos.failed_sessions + chaos.expired_sessions} "
         f"balanced=True")

    # byte-for-byte replay: same seed -> same schedule, same counters,
    # same streams
    plan2 = generate_fault_plan(
        FaultPlanConfig(seed=CHAOS_SEED, n_faults=8 if quick else 12,
                        horizon_s=round(base.now_s, 6)),
        session_ids=[r.session_id for r in trace.requests])
    assert plan_to_text(plan2) == plan_to_text(plan), (
        f"{route}: fault plan generation is not deterministic")
    _, chaos2 = _replay(model, params, trace, max_len=max_len,
                        n_pages=n_pages, injector=FaultInjector(plan2))
    assert chaos2.fault_counts == chaos.fault_counts, (
        f"{route}: replay fired a different fault schedule")
    for r in trace.requests:
        np.testing.assert_array_equal(
            chaos.tokens_for(r.session_id),
            chaos2.tokens_for(r.session_id),
            err_msg=f"{r.session_id}: chaos replay diverged ({route})")
    assert chaos2.now_s == chaos.now_s, (
        f"{route}: replay clock diverged")
    emit(f"fault/{route}/replay", chaos2.now_s * 1e6,
         f"faults={chaos2.faults_injected} identical=True")


def run(quick: bool = False) -> None:
    header("table16: fault injection + graceful degradation — chaos "
           "replay vs fault-free baseline (paged gather / pallas)")
    cfg = _cfg()
    params = Model(cfg).init(jax.random.PRNGKey(0))
    for route, model in (("gather", Model(cfg)),
                         ("pallas", Model(cfg, decode_backend="pallas"))):
        _route(route, model, params, quick)


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv)
