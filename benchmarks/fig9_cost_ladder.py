"""Paper Fig 9: cost-per-Mtok ladder — does the hardware ladder track
the cost ladder for batch-1 streaming decode?

Per (arch x TPU tier x quant path): step floor -> tokens/s/chip ->
$/Mtok at list prices.  The paper's inversion to look for: a cheaper
tier with the right (fused) quant path beating a faster tier at bf16.
Also reproduces the paper's own H100-vs-L4 endpoint from its measured
step times.
"""
from __future__ import annotations

from benchmarks.common import emit, header
from repro.configs import get_config
from repro.core import floor as fl
from repro.core.hardware import GPU_H100, GPU_L4, TPU_LADDER


def run() -> None:
    header("fig9: cost-per-Mtok ladder")
    # paper endpoint: H100+Graphs 11.78ms @$3.50/h vs L4+ExLlamaV2
    # 17.36ms @$0.30/h
    for name, chip, ms, in [("h100+graphs", GPU_H100, 11.78),
                            ("l4+exllamav2-int4", GPU_L4, 17.36)]:
        usd_per_mtok = chip.usd_per_hour / 3600.0 / (1.0 / (ms / 1e3)) * 1e6
        emit(f"cost/paper/{name}", ms * 1e3,
             f"$per_Mtok={usd_per_mtok:.2f}")
    # our ladder: floors per tier x paths for a representative arch set
    for arch in ("qwen2.5-3b", "qwen2-moe-a2.7b", "phi4-mini-3.8b",
                 "mamba2-2.7b"):
        cfg = get_config(arch)
        rows = []
        for chip in TPU_LADDER:
            for path, wb in (("bf16", 2), ("int4_fused", 0.5)):
                cell = fl.floor_cell(cfg, chip, 2048, weight_dtype_bytes=wb)
                tok_s = 1.0 / cell.t_floor_s
                usd = chip.usd_per_hour / 3600.0 / tok_s * 1e6
                rows.append((usd, chip.name, path, cell.t_floor_ms))
                emit(f"cost/{arch}/{chip.name}/{path}",
                     cell.t_floor_ms * 1e3,
                     f"tok_s={tok_s:.0f} $per_Mtok={usd:.3f}")
        rows.sort()
        best = rows[0]
        emit(f"cost/{arch}/cheapest", 0.0,
             f"{best[1]}/{best[2]} ${best[0]:.3f}/Mtok "
             f"(floor {best[3]:.2f}ms) — ladder inverted="
             f"{best[1] != TPU_LADDER[-1].name}")


if __name__ == "__main__":
    run()
