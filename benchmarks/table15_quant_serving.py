"""Table 15 (extension): quantised KV pages + int4 weights on the paged
serving routes — realised vs analytic traffic reduction per route.

The paper's deployment headline (§7) is that quantisation only pays
when the runtime *realises* the traffic reduction: on L4, bnb-nf4 and
AWQ recover almost none of the 4x weight-traffic cut while
GPTQ+ExLlamaV2's tuned kernels get 3.6x.  This table reproduces that
realised-savings gap inside our own serving stack, on the KV axis:

  * the FUSED route (``decode_backend="pallas"``) dequantises int8
    codes in-register inside the paged kernel's block loads — per-step
    KV traffic drops to live tokens at *stored* width (codes + scales),
    the analytic floor;
  * the GATHER route materialises a dequantised model-dtype view of the
    whole virtual span before the SDPA reads it (bnb-style) — stored
    bytes shrink ~3.6x but the step's read traffic barely moves.

Arms per route (gather / pallas), all greedy, all f32 model dtype so
the two routes compute the identical real-valued function and their
token streams must coincide EXACTLY even under quantisation:

  * f32 KV baseline, then int8 KV — asserted: route-vs-route token
    identity within each arm; greedy top-1 agreement of the int8 stream
    vs the f32 baseline >= ``AGREEMENT_TOL`` (mean per-session
    longest-common-prefix fraction — quantised greedy streams diverge
    permanently at the first flipped argmax, so prefix fraction is the
    honest agreement metric); fused realised KV-bytes reduction >= 1.5x
    and STRICTLY greater than the gather route's; the fused route's
    int8 traffic equals the analytic floor while the gather route's
    sits above it.
  * int8 KV + int4 fused weights (the full quantised serving stack
    under continuous batching) — per-step weight stream vs bf16.
  * int8 KV through the host-DRAM tier under forced preemption churn —
    parked quantised blobs (codes + scales) must restore bit-exactly:
    token identity vs the single-tier int8 run, device free list and
    host pool balanced after the flushes.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit, header
from repro.configs import get_config
from repro.kernels.paged_decode_attention.ops import serving_traffic_bytes
from repro.launch.serve import mixed_requests
from repro.models import Model
from repro.quant import quantize_tree, tree_weight_traffic
from repro.serving import SessionRequest, SlotScheduler

PAGE = 4
SLOTS = 3
# documented tolerance: mean per-session longest-common-prefix fraction
# of the int8-KV greedy stream vs the f32 baseline.  Int8 KV noise may
# legitimately flip a near-tie argmax mid-stream (after which greedy
# decoding never re-converges), so exact identity is the wrong contract;
# >= 0.5 mean prefix agreement is what the per-(token, head)-scale
# scheme comfortably clears on this config.
AGREEMENT_TOL = 0.5


def _cfg():
    # f32 so fused-vs-gather is the same real function at the same
    # precision (table10's identity discipline): codes * scale in f32
    # in-kernel == the dequantised f32 view the gather route reads.
    return get_config("qwen2.5-3b").reduced().replace(
        vocab_size=512, d_model=192, d_ff=384, n_layers=3,
        n_heads=4, n_kv_heads=2, head_dim=32, dtype="float32")


def _serve(model, params, reqs, *, max_len, kv_dtype=None, n_pages=None,
           **kw):
    sched = SlotScheduler(model, params, n_slots=kw.pop("n_slots", SLOTS),
                          max_len=max_len, paged=True, page_size=PAGE,
                          n_pages=n_pages, kv_dtype=kv_dtype, timed=False,
                          shared_programs=True, **kw)
    for r in reqs:
        sched.submit(r)
    return sched, sched.run()


def _assert_identical(reqs, a, b, label):
    for r in reqs:
        np.testing.assert_array_equal(
            a.tokens_for(r.session_id), b.tokens_for(r.session_id),
            err_msg=f"{r.session_id} diverged: {label}")


def _agreement(base, res, reqs) -> float:
    """Mean per-session longest-common-prefix fraction vs baseline."""
    fracs = []
    for r in reqs:
        a = np.asarray(base.tokens_for(r.session_id))
        b = np.asarray(res.tokens_for(r.session_id))
        n = min(len(a), len(b))
        neq = np.nonzero(a[:n] != b[:n])[0]
        lcp = int(neq[0]) if len(neq) else n
        fracs.append(lcp / max(len(a), 1))
    return float(np.mean(fracs))


def _traffic(res, cfg, max_blocks, kv_quant):
    return serving_traffic_bytes(res.step_kv_blocks, cfg, page_size=PAGE,
                                 n_slots=SLOTS, max_blocks=max_blocks,
                                 kv_quant=kv_quant)


def _kv_arms(models, params, reqs, cfg, max_len):
    """f32 vs int8 KV on both routes: identity, agreement, realised
    traffic reduction per route."""
    import jax.numpy as jnp
    max_blocks = -(-max_len // PAGE)
    base, quant, red = {}, {}, {}
    for route, model in models.items():
        _, base[route] = _serve(model, params, reqs, max_len=max_len)
        _, quant[route] = _serve(model, params, reqs, max_len=max_len,
                                 kv_dtype=jnp.int8)
        assert quant[route].step_cache_size in (1, None), \
            f"{route}: int8 paged decode step recompiled"
    # routes must agree exactly within each arm (f32 math both sides)
    _assert_identical(reqs, base["gather"], base["pallas"], "f32 routes")
    _assert_identical(reqs, quant["gather"], quant["pallas"],
                      "int8 routes (fused in-kernel dequant vs "
                      "dequantised-view gather)")
    for route in models:
        agree = _agreement(base[route], quant[route], reqs)
        assert agree >= AGREEMENT_TOL, (
            f"{route}: int8-KV greedy agreement {agree:.3f} < "
            f"{AGREEMENT_TOL} (documented tolerance)")
        tb_f32 = _traffic(base[route], cfg, max_blocks, "none")
        tb_i8 = _traffic(quant[route], cfg, max_blocks, "int8")
        key = "fused" if route == "pallas" else "gather_sdpa"
        red[route] = tb_f32[key] / tb_i8[key]
        # the fused route achieves the analytic floor by construction;
        # the gather route's realised traffic sits far above it
        assert tb_i8["fused"] == tb_i8["floor"]
        assert tb_i8["gather_sdpa"] > tb_i8["floor"]
        emit(f"quant/{route}/kv_int8", quant[route].now_s * 1e6,
             f"kv_step_bytes={tb_i8[key]} kv_step_bytes_f32={tb_f32[key]} "
             f"floor_bytes={tb_i8['floor']} realised_reduction="
             f"{red[route]:.3f} agreement={agree:.3f} "
             f"route_identical=True")
    assert red["pallas"] >= 1.5, (
        f"fused realised KV reduction {red['pallas']:.2f}x < 1.5x")
    assert red["pallas"] > red["gather"], (
        f"realised-savings gap inverted: fused {red['pallas']:.2f}x <= "
        f"gather {red['gather']:.2f}x")
    emit("quant/realised_gap", 0.0,
         f"fused_reduction={red['pallas']:.3f} "
         f"gather_reduction={red['gather']:.3f} "
         f"gap={red['pallas'] / red['gather']:.3f}")
    return base, quant


def _weight_arm(models, params, reqs, cfg, max_len, quant_runs):
    """int4 fused weights + int8 KV under continuous batching."""
    import jax.numpy as jnp
    params_q = quantize_tree(params, "int4_fused")
    wb = tree_weight_traffic(params)
    wq = tree_weight_traffic(params_q)
    assert wq < wb, "int4 weights did not shrink the per-step stream"
    runs = {}
    for route, model in models.items():
        _, runs[route] = _serve(model, params_q, reqs, max_len=max_len,
                                kv_dtype=jnp.int8)
    # int4-weight logits are a different (quantised) function, so no
    # bf16-agreement contract here — but the two ROUTES still share one
    # function and must stay token-identical
    _assert_identical(reqs, runs["gather"], runs["pallas"],
                      "int4-weight routes")
    agree = _agreement(quant_runs["pallas"], runs["pallas"], reqs)
    emit("quant/pallas/int4_weights", runs["pallas"].now_s * 1e6,
         f"weight_step_bytes={wq:.0f} weight_step_bytes_base={wb:.0f} "
         f"weight_reduction={wb / wq:.3f} agreement_vs_int8kv="
         f"{agree:.3f} route_identical=True")


def _tier_arm(models, params, cfg, quick):
    """int8 KV blobs (codes + scales) through the host-DRAM tier under
    forced preemption: park/restore must be bit-exact."""
    import jax.numpy as jnp
    rng = np.random.RandomState(11)
    reqs = []
    for i in range(4 if quick else 6):
        plen = 8 + 3 * (i % 3)
        prompt = rng.randint(0, cfg.vocab_size, size=plen).astype(np.int32)
        reqs.append(SessionRequest(f"t{i}", prompt, 6 + 2 * (i % 3)))
    max_len = max(len(r.prompt) + r.max_new_tokens for r in reqs) + 1
    n_pages = 1 + -(-max_len // PAGE)   # far below 2-slot full backing
    kw = dict(max_len=max_len, kv_dtype=jnp.int8, n_pages=n_pages,
              n_slots=2, prefill_chunk=PAGE, prefix_cache=True)
    for route, model in models.items():
        _, single = _serve(model, params, reqs, **kw)
        assert single.preemptions > 0, (
            f"{route}: pool of {n_pages} pages never forced a preemption")
        sched, tier = _serve(model, params, reqs, kv_tier="host",
                             tier_policy="spill", host_pages=4 * n_pages,
                             **kw)
        assert tier.pages_spilled > 0, f"{route}: nothing parked"
        assert tier.tier_restores > 0, f"{route}: nothing restored"
        _assert_identical(reqs, single, tier,
                          f"{route} int8 host-tier (codes+scales "
                          f"park/restore must be bit-exact)")
        store = sched.store
        sched.flush_prefix_cache()
        store.flush_host()
        assert store.allocator.n_free == n_pages - 1, \
            f"{route}: device page leak"
        assert store.host_used == 0, f"{route}: host page leak"
        emit(f"quant/{route}/kv_int8_host_tier", tier.now_s * 1e6,
             f"preemptions={tier.preemptions} spilled={tier.pages_spilled} "
             f"restored={tier.pages_restored} "
             f"tier_restores={tier.tier_restores} token_identical=True "
             f"balanced=True")


def run(quick: bool = False) -> None:
    header("table15: quantised KV + int4 weights on the paged routes — "
           "realised vs analytic traffic (gather / pallas)")
    cfg = _cfg()
    models = {"gather": Model(cfg),
              "pallas": Model(cfg, decode_backend="pallas")}
    params = models["gather"].init(jax.random.PRNGKey(0))
    n_sessions = 5 if quick else 9
    reqs = mixed_requests(cfg, n_sessions, base_prompt=8,
                          base_new=8 if quick else 12, seed=0)
    max_len = max(len(r.prompt) + r.max_new_tokens for r in reqs) + 1
    _, quant_runs = _kv_arms(models, params, reqs, cfg, max_len)
    _weight_arm(models, params, reqs, cfg, max_len, quant_runs)
    _tier_arm(models, params, cfg, quick)


if __name__ == "__main__":
    import sys
    run(quick="--quick" in sys.argv)
